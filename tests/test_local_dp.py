"""shard_map explicit-DP trainer: pjit equivalence, deferred reduction,
compressed convergence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import ASSIGNED, smoke_shape
from repro.data import make_stream
from repro.models import build_model
from repro.optim import AdamWConfig, Schedule
from repro.train import make_train_step, train_state_init
from repro.train.local_dp import make_local_dp_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(ASSIGNED[1].reduced(), n_layers=2)
    model = build_model(cfg)
    opt = AdamWConfig(schedule=Schedule(peak_lr=1e-2, warmup_steps=5,
                                        decay_steps=100))
    mesh = Mesh(np.array(jax.devices()).reshape(1), ("data",))
    stream = make_stream(cfg, smoke_shape("train"))
    return cfg, model, opt, mesh, stream


def test_matches_pjit_trainer(setup, key):
    cfg, model, opt, mesh, stream = setup
    s1 = train_state_init(model, opt, key)
    s2 = jax.tree.map(lambda x: x, s1)
    batch = stream.batch(0)
    ref = jax.jit(make_train_step(model, opt, accum_steps=2))
    s1n, m1 = ref(s1, batch)
    with mesh:
        dp = make_local_dp_train_step(model, opt, mesh, accum_steps=2)
        s2n, m2 = dp(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-6
    for a, b in zip(jax.tree.leaves(s1n["params"]),
                    jax.tree.leaves(s2n["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


@pytest.mark.parametrize("compress", [False, True])
def test_converges(setup, key, compress):
    cfg, model, opt, mesh, stream = setup
    with mesh:
        step = make_local_dp_train_step(model, opt, mesh,
                                        compress=compress)
        s = train_state_init(model, opt, key)
        first = None
        for i in range(30):
            s, m = step(s, stream.batch(i))
            if first is None:
                first = float(m["loss"])
    assert float(m["loss"]) < first * 0.2, (compress, first,
                                            float(m["loss"]))
