"""MoE: routing/dispatch correctness vs a naive per-token oracle, capacity
dropping, aux losses."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import KIMI_K2
from repro.models import moe as M
from repro.models.layers import apply_mlp


def _cfg(**kw):
    base = KIMI_K2.reduced()   # 4 experts, top-2, swiglu, shared expert
    return dataclasses.replace(base, d_model=16, moe_d_ff=32, **kw)


def _naive_moe(p, x, cfg):
    """Per-token oracle: full routing, no capacity limit."""
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    out = jnp.zeros_like(x, jnp.float32)
    for e in range(cfg.moe_num_experts):
        pe = {"w1": p["w1"][e], "w2": p["w2"][e], "w3": p["w3"][e]}
        ye = apply_mlp(pe, x, cfg.mlp_variant).astype(jnp.float32)
        w_e = jnp.sum(jnp.where(idx == e, gate, 0.0), -1)
        out = out + ye * w_e[..., None]
    if "shared" in p:
        out = out + apply_mlp(p["shared"], x, cfg.mlp_variant)
    return out.astype(x.dtype)


def test_moe_matches_naive_oracle_when_no_drops(key):
    cfg = _cfg(moe_capacity_factor=8.0)    # capacity >> tokens: no drops
    p = M.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.5
    got, aux = M.apply_moe(p, x, cfg)
    want = _naive_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    assert float(aux["moe_dropped"]) <= 1e-6


def test_capacity_drops_monotone(key):
    cfg_lo = _cfg(moe_capacity_factor=0.25)
    cfg_hi = _cfg(moe_capacity_factor=2.0)
    p = M.init_moe(key, cfg_lo, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg_lo.d_model))
    _, aux_lo = M.apply_moe(p, x, cfg_lo)
    _, aux_hi = M.apply_moe(p, x, cfg_hi)
    assert float(aux_lo["moe_dropped"]) > float(aux_hi["moe_dropped"]) - 1e-6
    assert float(aux_lo["moe_dropped"]) > 0.0


def test_lb_loss_minimal_for_uniform_router(key):
    """A uniform router gives lb_loss == 1 (the Switch minimum)."""
    cfg = _cfg()
    p = M.init_moe(key, cfg, jnp.float32)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jax.random.normal(key, (4, 64, cfg.d_model))
    _, aux = M.apply_moe(p, x, cfg)
    assert abs(float(aux["moe_lb_loss"]) - 1.0) < 0.2


def test_gate_renormalization(key):
    """Top-k gates sum to 1 per token (pre-capacity)."""
    cfg = _cfg(moe_capacity_factor=8.0)
    p = M.init_moe(key, cfg, jnp.float32)
    x = jnp.zeros((1, 8, cfg.d_model))
    # zero input -> expert outputs all equal -> output equals one expert's
    got, _ = M.apply_moe(p, x, cfg)
    want = _naive_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_subgroup_independence(key):
    """Results identical whether tokens are routed in 1 or 2 groups when
    capacity is not binding."""
    cfg = _cfg(moe_capacity_factor=8.0)
    p = M.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    y1, _ = M.apply_moe(p, x, cfg, subgroup=32)
    y2, _ = M.apply_moe(p, x, cfg, subgroup=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
