"""Training substrate: loss decrease, grad-accum equivalence, chunked CE
vs dense CE, packing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, smoke_shape
from repro.data import SyntheticConfig, make_stream, pack_documents
from repro.models import build_model, make_batch
from repro.optim import AdamWConfig, Schedule
from repro.train import make_train_step, train_state_init
from repro.train.step import chunked_cross_entropy, cross_entropy_loss


def _tiny_cfg():
    return dataclasses.replace(ASSIGNED[1].reduced(), n_layers=2)


def test_loss_decreases_on_affine_task(key):
    cfg = _tiny_cfg()
    model = build_model(cfg)
    opt = AdamWConfig(schedule=Schedule(peak_lr=1e-2, warmup_steps=5,
                                        decay_steps=100))
    state = train_state_init(model, opt, key)
    stream = make_stream(cfg, smoke_shape("train"))
    step = jax.jit(make_train_step(model, opt))
    first = last = None
    for i in range(40):
        state, metrics = step(state, stream.batch(i))
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first * 0.5, (first, last)
    assert float(metrics["acc"]) > 0.5


def test_grad_accum_equivalence(key):
    cfg = _tiny_cfg()
    model = build_model(cfg)
    opt = AdamWConfig()
    state1 = train_state_init(model, opt, key)
    state2 = jax.tree.map(lambda x: x, state1)
    stream = make_stream(cfg, smoke_shape("train"))
    batch = stream.batch(0)
    s1, m1 = jax.jit(make_train_step(model, opt, accum_steps=1))(state1, batch)
    s2, m2 = jax.jit(make_train_step(model, opt, accum_steps=2))(state2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-5)


@pytest.mark.parametrize("chunk", [7, 16, 64])
def test_chunked_ce_matches_dense(key, chunk):
    b, s, d, v = 2, 33, 16, 50
    ks = jax.random.split(key, 3)
    feats = jax.random.normal(ks[0], (b, s, d))
    w = jax.random.normal(ks[1], (d, v))
    targets = jax.random.randint(ks[2], (b, s), 0, v)
    mask = (jax.random.uniform(ks[2], (b, s)) > 0.3).astype(jnp.float32)
    nll_c, acc_c = chunked_cross_entropy(feats, w, targets, mask,
                                         chunk=chunk)
    logits = jnp.einsum("bsd,dv->bsv", feats, w)
    nll_d, acc_d = cross_entropy_loss(logits, targets, mask)
    np.testing.assert_allclose(float(nll_c), float(nll_d), rtol=1e-5)
    np.testing.assert_allclose(float(acc_c), float(acc_d), rtol=1e-6)


def test_chunked_ce_softcap_grads(key):
    """Chunked CE must be differentiable with the softcap path (gemma2)."""
    feats = jax.random.normal(key, (1, 16, 8))
    w = jax.random.normal(key, (8, 20))
    targets = jnp.zeros((1, 16), jnp.int32)

    def loss(f):
        return chunked_cross_entropy(f, w, targets, softcap=30.0,
                                     chunk=8)[0]
    g = jax.grad(loss)(feats)
    assert bool(jnp.isfinite(g).all())
    assert float(jnp.abs(g).max()) > 0


def test_pack_documents():
    docs = [np.arange(1, 6), np.arange(10, 13), np.arange(20, 28),
            np.arange(30, 32)]
    tokens, mask, seg = pack_documents(docs, seq_len=8, pad_id=0)
    # every token preserved exactly once
    all_tokens = sorted(t for t in tokens.flatten() if t != 0)
    want = sorted(int(x) for d in docs for x in d)
    assert all_tokens == want
    # first token of each doc is unmasked; padding unmasked
    for r in range(tokens.shape[0]):
        segs = seg[r]
        for j in range(8):
            if tokens[r, j] == 0 and segs[j] == 0:
                assert mask[r, j] == 0.0
            elif j == 0 or segs[j] != segs[j - 1]:
                assert mask[r, j] == 0.0, (r, j)
            else:
                assert mask[r, j] == 1.0


def test_long_doc_split():
    tokens, mask, seg = pack_documents([np.arange(1, 20)], seq_len=8)
    assert (np.count_nonzero(tokens) == 19)
