"""Data pipeline: determinism, restart/elastic replay, task learnability
structure."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, smoke_shape
from repro.data import SyntheticConfig, SyntheticStream


def _stream(kind="affine", **kw):
    cfg = ASSIGNED[1].reduced()
    return SyntheticStream(cfg, smoke_shape("train"),
                           SyntheticConfig(kind=kind), **kw)


def test_determinism_across_instances():
    a = _stream().batch(7)
    b = _stream().batch(7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_steps_differ():
    s = _stream()
    assert not np.array_equal(np.asarray(s.batch(0)["tokens"]),
                              np.asarray(s.batch(1)["tokens"]))


def test_affine_chain_property():
    d = SyntheticConfig()
    toks = np.asarray(_stream().batch(0)["tokens"])
    v = d.affine_vocab
    want = (d.affine_a * toks[:, :-1] + d.affine_b) % v
    np.testing.assert_array_equal(toks[:, 1:], want)


def test_host_sharding_disjoint():
    """Two processes see different rows; together they cover the batch."""
    cfg = ASSIGNED[1].reduced()
    shape = smoke_shape("train")
    s0 = SyntheticStream(cfg, shape, SyntheticConfig(),
                         process_index=0, process_count=2)
    s1 = SyntheticStream(cfg, shape, SyntheticConfig(),
                         process_index=1, process_count=2)
    b0, b1 = s0.batch(3), s1.batch(3)
    assert b0["tokens"].shape[0] == shape.global_batch // 2
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_zipf_is_skewed():
    s = _stream(kind="zipf")
    toks = np.asarray(s.batch(0)["tokens"]).flatten()
    # Zipf: low token ids dominate
    assert (toks < 10).mean() > 0.35


def test_modality_fields():
    cfg = get_config("internvl2-2b").reduced()
    s = SyntheticStream(cfg, smoke_shape("train"), SyntheticConfig())
    b = s.batch(0)
    assert "patches" in b and b["patches"].ndim == 3
    cfg = get_config("seamless-m4t-medium").reduced()
    s = SyntheticStream(cfg, smoke_shape("train"), SyntheticConfig())
    b = s.batch(0)
    assert "frames" in b and b["frames"].shape[-1] == cfg.d_model
