"""Mamba-2 SSD: chunked form vs sequential recurrence, padding identity,
decode step, full block."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MAMBA2_2P7B
from repro.models import ssm as S


def _inputs(key, bt=2, s=64, h=3, p=8, n=4):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (bt, s, h, p)) * 0.5
    dt_a = -jnp.abs(jax.random.normal(ks[1], (bt, s, h))) * 0.2
    b = jax.random.normal(ks[2], (bt, s, n)) * 0.5
    c = jax.random.normal(ks[3], (bt, s, n)) * 0.5
    return x, dt_a, b, c


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_equals_sequential(key, chunk):
    x, dt_a, b, c = _inputs(key)
    y1, s1 = S.ssd_chunked(x, dt_a, b, c, chunk)
    y2, s2 = S.ssd_reference(x, dt_a, b, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


def test_initial_state_chaining(key):
    """Processing [first half; second half with carry] == full sequence."""
    x, dt_a, b, c = _inputs(key, s=64)
    y_full, s_full = S.ssd_chunked(x, dt_a, b, c, 16)
    y1, s1 = S.ssd_chunked(x[:, :32], dt_a[:, :32], b[:, :32], c[:, :32], 16)
    y2, s2 = S.ssd_chunked(x[:, 32:], dt_a[:, 32:], b[:, 32:], c[:, 32:],
                           16, initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=2e-4)


def test_block_forward_and_padding(key):
    cfg = MAMBA2_2P7B.reduced()
    p = S.init_ssm(key, cfg, jnp.float32)
    # s=40 not a multiple of chunk 32 -> identity-padding path
    x = jax.random.normal(key, (2, 40, cfg.d_model)) * 0.1
    y = S.ssm_forward(p, x, cfg)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
    # padding must not change earlier outputs: compare vs s=32 prefix
    y32 = S.ssm_forward(p, x[:, :32], cfg)
    np.testing.assert_allclose(np.asarray(y[:, :32]), np.asarray(y32),
                               atol=1e-5)


def test_decode_matches_forward(key):
    cfg = MAMBA2_2P7B.reduced()
    p = S.init_ssm(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 24, cfg.d_model)) * 0.1
    y_full, cache = S.ssm_forward(p, x[:, :16], cfg, return_state=True)
    outs = []
    for t in range(16, 24):
        y_t, cache = S.ssm_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(y_t)
    want = S.ssm_forward(p, x, cfg)[:, 16:]
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_decay_is_contraction(key):
    """dt*A < 0 => zero-input state decays monotonically."""
    x, dt_a, b, c = _inputs(key, s=32)
    init = jnp.ones((2, 3, 8, 4))
    _, s_out = S.ssd_chunked(jnp.zeros_like(x), dt_a, jnp.zeros_like(b),
                             c, 16, initial_state=init)
    assert float(jnp.max(jnp.abs(s_out))) < 1.0
