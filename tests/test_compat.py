"""repro.compat — capability detection, dtype-registry fallbacks,
shard_map resolution, and the interpret-mode pallas_call path (ISSUE 1
acceptance: the whole suite must run on a CPU-only host)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat


# --------------------------------------------------------------------- #
# version / backend probing
# --------------------------------------------------------------------- #

def test_jax_version_tuple():
    v = compat.jax_version()
    assert isinstance(v, tuple) and len(v) >= 2
    assert all(isinstance(p, int) for p in v)
    assert v >= (0, 4)


def test_backend_platform_known():
    assert compat.backend_platform() in ("cpu", "gpu", "tpu")
    assert compat.is_tpu() == (compat.backend_platform() == "tpu")


# --------------------------------------------------------------------- #
# dtype registry
# --------------------------------------------------------------------- #

def test_registry_covers_all_paper_formats():
    names = compat.available_formats()
    assert set(names) == {"float8_e4m3fn", "float8_e5m2", "float6_e2m3fn",
                          "float6_e3m2fn", "float4_e2m1fn"}


def test_registry_containers_are_jax_usable():
    """Every container must actually hold a JAX array — the whole point
    of the fallback ladder."""
    for name in compat.available_formats():
        spec = compat.dtype_spec(name)
        arr = jnp.zeros((4,), dtype=spec.container)
        assert arr.shape == (4,), name
        assert spec.bits in (4, 6, 8)
        assert spec.max_finite > 0


def test_emulated_specs_always_carry_round_dtype():
    """Invariant: an emulated container MUST host-round, else 'fp8 on a
    JAX without fp8' would silently measure the container's precision."""
    for name in compat.available_formats():
        spec = compat.dtype_spec(name)
        if spec.emulated:
            assert spec.round_dtype is not None, name
        else:
            assert spec.round_dtype is None, name


def test_fp6_always_emulated_fp8_native_or_emulated():
    """fp6 has no jnp dtype in any JAX release — must carry a host
    rounding dtype.  fp8 e4m3/e5m2 have been native for years."""
    for name in ("float6_e2m3fn", "float6_e3m2fn"):
        spec = compat.dtype_spec(name)
        assert spec.emulated and spec.round_dtype is not None, name
    assert compat.dtype_spec("float8_e4m3fn").native


def test_fp4_fallback_selection():
    """On JAX without jnp.float4_e2m1fn the registry must degrade fp4 to
    a host-rounded e4m3 container; on newer JAX it must be native.
    Either way values survive the round trip exactly (every e2m1 value
    is representable in e4m3)."""
    spec = compat.dtype_spec("float4_e2m1fn")
    has_native = getattr(jnp, "float4_e2m1fn", None) is not None
    if not has_native:
        assert spec.emulated
        assert np.dtype(spec.container).itemsize == 1
        assert spec.round_dtype is not None
    # fp4's exact value set must survive container storage
    import ml_dtypes
    vals = np.asarray([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, -6.0],
                      np.float32)
    rounded = vals.astype(ml_dtypes.float4_e2m1fn).astype(np.float32)
    np.testing.assert_array_equal(rounded, vals)
    stored = jnp.asarray(rounded).astype(spec.container).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(stored), vals)


def test_dtype_spec_unknown_name():
    with pytest.raises(KeyError):
        compat.dtype_spec("float3_e1m1")


def test_describe_distinguishes_native_and_emulated():
    descs = {n: compat.dtype_spec(n).describe()
             for n in compat.available_formats()}
    assert descs["float8_e4m3fn"] == "native"
    assert "emulated" in descs["float6_e2m3fn"]


# --------------------------------------------------------------------- #
# shard_map resolution
# --------------------------------------------------------------------- #

def test_resolve_shard_map_source():
    fn, src = compat.resolve_shard_map()
    assert callable(fn)
    assert src in ("jax.shard_map", "jax.experimental.shard_map")


@pytest.mark.parametrize("check_kwarg", [{}, {"check_vma": False},
                                         {"check_rep": False}])
def test_shard_map_runs_with_either_check_spelling(check_kwarg):
    """The wrapper must accept both the new (check_vma) and old
    (check_rep) kwarg and execute on a world=1 mesh."""
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    f = compat.shard_map(lambda x: jax.lax.psum(x, "d"), mesh=mesh,
                         in_specs=P("d"), out_specs=P(), **check_kwarg)
    out = f(jnp.arange(4, dtype=jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.arange(4), atol=0)


def test_shard_map_decorator_form():
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))

    @functools.partial(compat.shard_map, mesh=mesh, in_specs=P(),
                       out_specs=P(), check_vma=False)
    def double(x):
        return x * 2.0

    out = double(jnp.ones((4,)))
    np.testing.assert_allclose(np.asarray(out), 2.0)


# --------------------------------------------------------------------- #
# pallas interpret-mode fallback
# --------------------------------------------------------------------- #

def test_interpret_default_matches_platform():
    assert compat.pallas_interpret_default() == (not compat.is_tpu())


def test_tpu_compiler_params_buildable():
    cp = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert cp is not None


def test_pallas_call_interpret_qmatmul_matches_reference(key):
    """End-to-end acceptance: qmatmul through the compat pallas_call
    (interpret mode on CPU) matches the bf16 dequant reference."""
    from repro.kernels.qmatmul import qmatmul_mkn
    from repro.serve.quant import dequantize_blockwise, quantize_blockwise

    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (128, 128), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(k2, (128, 128), jnp.float32)
    qw, scales = quantize_blockwise(w.T, "float8_e4m3fn")

    got = qmatmul_mkn(x, qw, scales)          # interpret auto-selected
    w_deq = dequantize_blockwise(qw, scales, jnp.bfloat16)
    want = (x.astype(jnp.float32) @ w_deq.astype(jnp.float32).T
            ).astype(jnp.bfloat16)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=0.05, atol=0.05)


def test_pallas_call_interpret_qmatmul_fp4_container(key):
    """fp4 rides the registry's container on this backend and still
    produces a usable matmul (coarser values, same pipeline)."""
    from repro.kernels.qmatmul import qmatmul_mkn
    from repro.serve.quant import dequantize_blockwise, quantize_blockwise

    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (128, 128), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(k2, (128, 128), jnp.float32)
    qw, scales = quantize_blockwise(w.T, "float4_e2m1fn")

    got = qmatmul_mkn(x, qw, scales)
    w_deq = dequantize_blockwise(qw, scales, jnp.bfloat16)
    want = (x.astype(jnp.float32) @ w_deq.astype(jnp.float32).T
            ).astype(jnp.bfloat16)
    # vs the *dequant* reference the kernel is exact-ish; fp4 coarseness
    # lives in quantize_blockwise, not the kernel
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=0.05, atol=0.05)


# --------------------------------------------------------------------- #
# capability report
# --------------------------------------------------------------------- #

def test_report_contents():
    rep = compat.report()
    assert rep.jax_version == jax.__version__
    assert rep.platform == compat.backend_platform()
    assert rep.pallas_mode in ("native-mosaic", "interpret")
    assert set(rep.formats) == set(compat.available_formats())
    text = str(rep)
    assert "compat,jax=" in text
    assert "float4_e2m1fn" in text
    assert len(rep.lines()) == 2 + len(rep.formats)
