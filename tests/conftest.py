import os

# Tests must see the real single CPU device (the dry-run alone forces 512
# fake devices, in its own subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
