"""flash_decode kernel vs the decode_attention oracle: GQA, ring caches,
windows, softcaps, heterogeneous positions, S-padding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels as K
from repro.models.attention import decode_attention


def _setup(key, b=2, S=256, hq=4, hkv=2, d=64, filled=None):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d))
    kc = jax.random.normal(ks[1], (b, S, hkv, d))
    vc = jax.random.normal(ks[2], (b, S, hkv, d))
    slot = jnp.broadcast_to(jnp.arange(S), (b, S)).astype(jnp.int32)
    if filled is not None:                  # only first `filled` slots live
        slot = jnp.where(jnp.arange(S)[None, :] < filled, slot, -1)
    return q, kc, vc, slot


@pytest.mark.parametrize("hq,hkv,bk", [(4, 4, 128), (4, 2, 64),
                                       (8, 1, 128)])
def test_flash_decode_matches_oracle(key, hq, hkv, bk):
    q, kc, vc, slot = _setup(key, hq=hq, hkv=hkv)
    pos = jnp.full((2,), 255, jnp.int32)
    got = K.flash_decode(q, kc, vc, slot, pos, bk=bk)
    want = decode_attention(q, kc, vc, slot, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


@pytest.mark.parametrize("window,softcap", [(64, None), (None, 20.0),
                                            (32, 10.0)])
def test_flash_decode_flags(key, window, softcap):
    q, kc, vc, slot = _setup(key)
    pos = jnp.full((2,), 200, jnp.int32)
    got = K.flash_decode(q, kc, vc, slot, pos, window=window,
                         softcap=softcap, bk=64)
    want = decode_attention(q, kc, vc, slot, pos, window=window,
                            softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


def test_flash_decode_heterogeneous_positions(key):
    q, kc, vc, slot = _setup(key)
    pos = jnp.asarray([50, 250], jnp.int32)   # rows at different depths
    got = K.flash_decode(q, kc, vc, slot, pos, bk=64)
    want = decode_attention(q, kc, vc, slot, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


def test_flash_decode_partial_cache_and_padding(key):
    """Empty slots (slot_pos=-1) and S not a multiple of bk."""
    q, kc, vc, slot = _setup(key, S=200, filled=77)
    pos = jnp.full((2,), 76, jnp.int32)
    got = K.flash_decode(q, kc, vc, slot, pos, bk=128)
    want = decode_attention(q, kc, vc, slot, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


def test_flash_decode_ring_wrap(key):
    """Ring-buffer layout: slots hold non-monotonic absolute positions."""
    b, S, h, d = 1, 64, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kc = jax.random.normal(ks[1], (b, S, h, d))
    vc = jax.random.normal(ks[2], (b, S, h, d))
    # positions 100..163 wrapped into 64 slots: slot i holds pos p, p%64==i
    base = jnp.arange(S)
    slot = jnp.where(base < 36, base + 128, base + 64)[None, :]
    slot = slot.astype(jnp.int32)
    pos = jnp.full((b,), 163, jnp.int32)
    got = K.flash_decode(q, kc, vc, slot, pos, window=40, bk=32)
    want = decode_attention(q, kc, vc, slot, pos, window=40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


@pytest.mark.parametrize("S,bk,window,softcap", [
    (200, 128, 64, None),        # padded tail + window
    (200, 128, None, 20.0),      # padded tail + softcap
    (130, 64, 48, 12.0),         # padded tail + both
])
def test_flash_decode_padding_with_flags(key, S, bk, window, softcap):
    """S not divisible by bk combined with window/softcap: the padding
    block must mask cleanly even when every flag is in play."""
    q, kc, vc, slot = _setup(key, S=S)
    pos = jnp.full((2,), S - 1, jnp.int32)
    got = K.flash_decode(q, kc, vc, slot, pos, window=window,
                         softcap=softcap, bk=bk)
    want = decode_attention(q, kc, vc, slot, pos, window=window,
                            softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


def test_flash_decode_ring_wrap_padded(key):
    """Ring wrap AND S not a multiple of bk (the padding edge): wrapped
    slot positions in a 96-slot cache, 64-wide kernel blocks."""
    b, S, h, d = 2, 96, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kc = jax.random.normal(ks[1], (b, S, h, d))
    vc = jax.random.normal(ks[2], (b, S, h, d))
    # positions 150..245 wrapped into 96 slots (150 % 96 == 54)
    base = jnp.arange(S)
    slot = jnp.where(base < 54, base + 192, base + 96)[None, :]
    slot = jnp.broadcast_to(slot, (b, S)).astype(jnp.int32)
    pos = jnp.asarray([245, 200], jnp.int32)   # row 1 mid-ring
    for window, softcap in [(None, None), (50, None), (64, 18.0)]:
        got = K.flash_decode(q, kc, vc, slot, pos, window=window,
                             softcap=softcap, bk=64)
        want = decode_attention(q, kc, vc, slot, pos, window=window,
                                softcap=softcap)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)
