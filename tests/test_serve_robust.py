"""Serving under fire: fault injection + isolated recovery, admission
control with deadlines and backpressure, cancellation, and the traffic
scenario harness — across every arch family.

The robustness contract these tests pin:

* a fault in one slot finishes ONLY that request (``status="faulted"``),
  every surviving stream is bit-identical to an uninjected run, and the
  slot is reusable immediately (``clear_slot`` recovery) — per family x
  kv_format;
* the sentinel detects what it can (non-finite logits, e8m0 overflow,
  inf recurrent state) and the documented gap stays documented: a
  ``kv_bitflip`` that decodes finite is SILENT (status ok, diverged
  tokens);
* every submitted request ends in exactly one terminal status — the
  accounting identity holds through shed, deadline, cancel, and fault
  paths, under deterministic virtual-clock traffic replay with zero
  recompiles.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitize import CompileCounter
from repro.configs import get_config
from repro.models import build_model
from repro.serve import (AdmissionConfig, QueueFull, STATUSES,
                         ServeEngine, bursty_trace, poisson_trace,
                         replay)

# same idiom as test_serve_unified: moe_capacity_factor=8.0 keeps MoE
# token dropping out of the oracle comparison; "attn" joins the matrix
# because fault isolation must hold on the plain ring-KV path too
ARCHS = {
    "attn": ("gptneox-1b", {}),
    "ssm": ("mamba2-2.7b", {}),
    "hybrid": ("jamba-v0.1-52b", {"moe_capacity_factor": 8.0}),
    "enc-dec": ("seamless-m4t-medium", {}),
    "vlm": ("internvl2-2b", {}),
}


def _build(family):
    name, over = ARCHS[family]
    cfg = get_config(name).reduced()
    if over:
        cfg = dataclasses.replace(cfg, **over)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def models():
    return {f: _build(f) for f in ARCHS}


def _modal_inputs(cfg, seed=7):
    rng = np.random.RandomState(seed)
    frames = patches = None
    if cfg.is_encoder_decoder:
        frames = rng.randn(9, cfg.d_model).astype(np.float32) * 0.02
    if cfg.frontend == "vision":
        patches = rng.randn(5, cfg.d_model).astype(np.float32) * 0.02
    return frames, patches


def _submit(eng, cfg, prompt, max_new_tokens, **kw):
    frames, patches = _modal_inputs(cfg)
    return eng.submit(prompt, max_new_tokens=max_new_tokens,
                      frames=frames, patches=patches, **kw)


def _by_id(results):
    return {r.request_id: r for r in results}


# --------------------------------------------------------------------- #
# fault isolation: poisoned slot out, survivors bit-identical, slot back
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("kv_format", [None, "float8_e4m3fn",
                                       "float4_e2m1fn"])
@pytest.mark.parametrize("family", list(ARCHS))
def test_fault_isolation_per_family(models, family, kv_format):
    cfg, model, params = models[family]
    mk = lambda: ServeEngine(model, params, batch=2, max_seq=64,
                             kv_format=kv_format, decode_block=4,
                             prefill_chunk=8)
    pa, pb = [1, 2, 3, 4, 5, 6, 7], [9, 8, 7]

    oracle = mk()
    _submit(oracle, cfg, pa, 12)
    _submit(oracle, cfg, pb, 12)
    want = {r.request_id: r.tokens for r in oracle.run()}

    eng = mk()
    a = _submit(eng, cfg, pa, 12)
    b = _submit(eng, cfg, pb, 12)
    eng.decode_loop()                      # admit both, 1+4 tokens each
    eng.inject_fault(a, "logits_nan", delay=1)
    res = _by_id(eng.run())

    # the poisoned slot: one more clean token after arming, then the
    # sentinel trips — partial stream is a prefix of the oracle
    assert res[a].status == "faulted"
    assert len(res[a].tokens) == 6
    assert res[a].tokens == want[a][:6]
    # the survivor never notices: bit-identical to the uninjected run
    assert res[b].status == "ok"
    assert res[b].tokens == want[b]
    acc = eng.accounting()
    assert acc["balanced"] and acc["faulted"] == 1 and acc["ok"] == 1

    # recovery: the faulted slot is re-initialized through clear_slot —
    # the same prompt through the same engine reproduces the oracle
    c = _submit(eng, cfg, pa, 12)
    res2 = _by_id(eng.run())
    assert res2[c].status == "ok"
    assert res2[c].tokens == want[a]
    assert eng.watchdog_report()["ok"]


def test_logits_inf_detected():
    cfg, model, params = _build("attn")
    eng = ServeEngine(model, params, batch=1, max_seq=64, decode_block=4)
    a = eng.submit([3, 1, 4, 1, 5], max_new_tokens=10)
    eng.decode_loop()
    eng.inject_fault(a, "logits_inf", delay=0)
    res = eng.run()[0]
    assert res.status == "faulted"
    assert len(res.tokens) == 5            # admission + first block only


# --------------------------------------------------------------------- #
# cache-fault taxonomy: detected kinds fault, the silent gap stays pinned
# --------------------------------------------------------------------- #

def _run_with_cache_fault(model, params, kind, kv_format=None):
    eng = ServeEngine(model, params, batch=1, max_seq=64,
                      kv_format=kv_format, decode_block=4)
    a = eng.submit([2, 7, 1, 8, 2, 8], max_new_tokens=12)
    eng.decode_loop()
    eng.inject_fault(a, kind)
    return eng.run()[0], eng


@pytest.mark.parametrize("kv_format", ["float8_e4m3fn", "float4_e2m1fn"])
def test_e8m0_overflow_detected(kv_format):
    """An overflowed scale byte (0xFF -> 2^128) decodes to inf: the
    sentinel sees it on the next attention read, no matter the packed
    value format."""
    cfg, model, params = _build("attn")
    res, eng = _run_with_cache_fault(model, params, "e8m0_overflow",
                                     kv_format=kv_format)
    assert res.status == "faulted"
    assert len(res.tokens) < 12
    assert eng.accounting()["balanced"]


def test_state_inf_detected_on_ssm(models):
    cfg, model, params = models["ssm"]
    res, eng = _run_with_cache_fault(model, params, "state_inf")
    assert res.status == "faulted"
    assert len(res.tokens) < 12
    # recovered slot serves clean again
    eng.submit([2, 7, 1, 8, 2, 8], max_new_tokens=4)
    assert eng.run()[-1].status == "ok"


def test_kv_bitflip_is_silent_corruption():
    """The documented sentinel gap: an XOR'd e8m0 scale byte decodes to
    a wrong-but-FINITE scale, so the run finishes ``ok`` while the
    stream silently diverges from the uninjected oracle.  This test
    exists to keep the gap visible — if the sentinel ever catches it,
    the taxonomy table in repro.serve.faults is stale."""
    cfg, model, params = _build("attn")
    oracle = ServeEngine(model, params, batch=1, max_seq=64,
                         kv_format="float4_e2m1fn", decode_block=4)
    oracle.submit([2, 7, 1, 8, 2, 8], max_new_tokens=12)
    want = oracle.run()[0].tokens
    res, eng = _run_with_cache_fault(model, params, "kv_bitflip",
                                     kv_format="float4_e2m1fn")
    assert res.status == "ok"              # sentinel cannot see it
    assert len(res.tokens) == 12
    assert res.tokens != want              # ...but the data is wrong
    assert res.tokens[:5] == want[:5]      # prefix (pre-injection) holds


def test_spec_kv_bitflip_survivor_isolation():
    """The silent-corruption gap, on the SPECULATIVE path: a bitflip
    over one slot's packed KV bytes — including the ring region where
    drafted-but-rejected rows would land — finishes ``ok`` with a
    diverged stream, while the surviving slot's stream stays
    bit-identical to an uninjected speculative run.  Rejected draft
    rows are never written to the target cache, so the flip has nothing
    speculative to corrupt beyond what the non-speculative engine
    already exposes (see repro.serve.faults)."""
    from repro.serve import SpecConfig

    cfg, model, params = _build("attn")
    spec = SpecConfig(draft_tokens=3, ngram_table=64)

    def mk():
        return ServeEngine(model, params, batch=2, max_seq=64,
                           kv_format="float4_e2m1fn", decode_block=8,
                           spec=spec)

    pa, pb = [2, 7, 1, 8, 2, 8], [3, 1, 4, 1, 5]
    oracle = mk()
    a = oracle.submit(pa, max_new_tokens=12)
    b = oracle.submit(pb, max_new_tokens=12)
    want = _by_id(oracle.run())

    eng = mk()
    a = eng.submit(pa, max_new_tokens=12)
    b = eng.submit(pb, max_new_tokens=12)
    eng.decode_loop()                      # admit + first verify block
    n_clean = len(eng.out_tokens[0])
    eng.inject_fault(a, "kv_bitflip")
    res = _by_id(eng.run())
    assert res[a].status == "ok"           # sentinel cannot see it
    assert len(res[a].tokens) == 12
    assert res[a].tokens != want[a].tokens           # silently wrong
    assert res[a].tokens[:n_clean] == want[a].tokens[:n_clean]
    # the survivor never notices, token for token
    assert res[b].status == "ok"
    assert res[b].tokens == want[b].tokens
    assert eng.spec_report()["blocks"] > 0 # speculation actually ran
    assert eng.accounting()["balanced"]


def test_cache_faults_require_matching_cache():
    cfg, model, params = _build("attn")
    dense = ServeEngine(model, params, batch=1, max_seq=64,
                        decode_block=4)
    a = dense.submit([1, 2, 3], max_new_tokens=32)
    dense.decode_loop()
    with pytest.raises(ValueError, match="quantized KV"):
        dense.inject_fault(a, "e8m0_overflow")
    with pytest.raises(ValueError, match="recurrent"):
        dense.inject_fault(a, "state_inf")
    with pytest.raises(ValueError, match="unknown fault kind"):
        dense.inject_fault(a, "cosmic_ray")


# --------------------------------------------------------------------- #
# cancellation
# --------------------------------------------------------------------- #

def test_cancel_inflight_and_queued():
    cfg, model, params = _build("attn")
    eng = ServeEngine(model, params, batch=1, max_seq=64, decode_block=4)
    a = eng.submit([1, 2, 3, 4], max_new_tokens=16)
    b = eng.submit([5, 6], max_new_tokens=16)
    eng.decode_loop()                      # a in flight, b queued
    assert eng.cancel(b) is True           # queued: never touches device
    assert eng.cancel(a) is True           # in flight: partial tokens
    res = _by_id(eng.results)
    assert res[b].status == "shed" and res[b].tokens == []
    assert res[a].status == "shed" and len(res[a].tokens) == 5
    assert eng.cancel(a) is False          # already finished
    assert eng.cancel(999) is False
    with pytest.raises(ValueError, match="not in"):
        eng.cancel(a, status="vaporized")
    acc = eng.accounting()
    assert acc["balanced"] and acc["in_flight"] == 0 and acc["queued"] == 0
    # the cancelled slot admits the next request cleanly
    eng.submit([7, 8, 9], max_new_tokens=4)
    assert eng.run()[-1].status == "ok"
    assert eng.watchdog_report()["ok"]


# --------------------------------------------------------------------- #
# admission control: bounded queue, policies, deadlines, scheduling
# --------------------------------------------------------------------- #

def test_submit_validates_max_new_tokens():
    """Regression: max_new_tokens=0 used to sample a token anyway and
    write remaining=-1 into the slot state."""
    cfg, model, params = _build("attn")
    eng = ServeEngine(model, params, batch=1, max_seq=64)
    for bad in (0, -3):
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit([1, 2, 3], max_new_tokens=bad)
    assert eng.accounting()["submitted"] == 0   # nothing half-entered
    a = eng.submit([1, 2, 3], max_new_tokens=1)
    res = _by_id(eng.run())
    assert res[a].status == "ok" and len(res[a].tokens) == 1


def test_admission_policies():
    cfg, model, params = _build("attn")

    def mk(policy):
        return ServeEngine(
            model, params, batch=1, max_seq=64, decode_block=4,
            admission=AdmissionConfig(queue_limit=1, policy=policy))

    # reject: the NEW request is shed, earlier ones keep their place
    eng = mk("reject")
    ids = [eng.submit([1, 2, 3], max_new_tokens=4) for _ in range(3)]
    res = _by_id(eng.run())
    assert res[ids[0]].status == "ok"
    assert [res[i].status for i in ids[1:]] == ["shed", "shed"]

    # shed_oldest: fresh arrivals displace the oldest queued request
    eng = mk("shed_oldest")
    ids = [eng.submit([1, 2, 3], max_new_tokens=4) for _ in range(3)]
    res = _by_id(eng.run())
    assert [res[i].status for i in ids] == ["shed", "shed", "ok"]

    # block: QueueFull raises and consumes NOTHING — same id succeeds
    # on retry after the queue drains
    eng = mk("block")
    a = eng.submit([1, 2, 3], max_new_tokens=4)
    with pytest.raises(QueueFull):
        eng.submit([4, 5, 6], max_new_tokens=4)
    assert eng.accounting()["submitted"] == 1
    eng.run()
    b = eng.submit([4, 5, 6], max_new_tokens=4)
    assert b == a + 1                      # no id burned by the refusal
    assert _by_id(eng.run())[b].status == "ok"


def test_shortest_prompt_first_scheduling():
    cfg, model, params = _build("attn")
    eng = ServeEngine(
        model, params, batch=1, max_seq=64, decode_block=4,
        admission=AdmissionConfig(scheduler="spf"))
    long = eng.submit(list(range(1, 17)), max_new_tokens=4)
    mid = eng.submit(list(range(1, 9)), max_new_tokens=4)
    short = eng.submit([1, 2, 3], max_new_tokens=4)
    res = _by_id(eng.run())
    t = {i: res[i].first_token_t for i in (short, mid, long)}
    assert t[short] < t[mid] < t[long]


def test_deadlines_with_virtual_clock():
    """Deterministic deadline accounting on an injected clock: an
    expired queued request never spends prefill, an expired in-flight
    request is cancelled with its partial tokens."""
    cfg, model, params = _build("attn")
    now = [0.0]
    eng = ServeEngine(
        model, params, batch=1, max_seq=64, decode_block=4,
        admission=AdmissionConfig(deadline_ms=100.0),
        clock=lambda: now[0])
    a = eng.submit([1, 2, 3, 4], max_new_tokens=64)
    b = eng.submit([5, 6, 7], max_new_tokens=4)
    eng.decode_loop()                      # a in flight, b queued
    now[0] = 10.0                          # blow both deadlines
    eng.run()
    res = _by_id(eng.results)
    assert res[a].status == "deadline_exceeded"
    assert len(res[a].tokens) >= 5         # partials delivered
    assert res[b].status == "deadline_exceeded"
    assert res[b].tokens == []             # no prefill was spent on b
    acc = eng.accounting()
    assert acc["balanced"] and acc["deadline_exceeded"] == 2
    # a fresh request under the same config gets a fresh deadline
    c = eng.submit([8, 9], max_new_tokens=4)
    assert _by_id(eng.run())[c].status == "ok"


def test_run_stall_guard(monkeypatch):
    """Regression: a non-admittable queue used to spin forever at the
    bare ``continue``; now it raises with a diagnosis."""
    cfg, model, params = _build("attn")
    eng = ServeEngine(model, params, batch=1, max_seq=64)
    eng.submit([1, 2, 3], max_new_tokens=4)
    monkeypatch.setattr(eng.queue, "take", lambda now: (None, []))
    with pytest.raises(RuntimeError, match="stalled"):
        eng.run()


def test_truncated_status_and_flush():
    cfg, model, params = _build("attn")
    eng = ServeEngine(model, params, batch=1, max_seq=64, decode_block=4)
    eng.submit([1, 2, 3], max_new_tokens=32)
    res = eng.run(max_steps=4)
    assert res[0].status == "truncated" and res[0].truncated
    assert 0 < len(res[0].tokens) < 32
    assert set(STATUSES) >= {"ok", "truncated", "shed",
                             "deadline_exceeded", "faulted"}
    assert eng.accounting()["balanced"]


# --------------------------------------------------------------------- #
# traffic harness: deterministic traces, exact accounting, no recompiles
# --------------------------------------------------------------------- #

def test_traces_are_deterministic():
    a = poisson_trace(n=12, rate=50.0, vocab_size=500, seed=5)
    b = poisson_trace(n=12, rate=50.0, vocab_size=500, seed=5)
    assert a == b and len(a.arrivals) == 12
    c = poisson_trace(n=12, rate=50.0, vocab_size=500, seed=6)
    assert c != a
    assert all(x.t <= y.t for x, y in zip(a.arrivals, a.arrivals[1:]))
    assert all(0 <= t < 500 for arr in a.arrivals for t in arr.prompt)


def test_replay_overload_accounting_and_compile_once():
    """Virtual-clock replay of an overloaded bursty trace: exact status
    accounting, deterministic across replays, and the (policy, K) sweep
    reuses the warmed executables with zero recompiles."""
    cfg, model, params = _build("attn")
    eng = ServeEngine(model, params, batch=2, max_seq=64,
                      decode_block=4, prefill_chunk=8)
    sc = bursty_trace(n_bursts=2, burst_size=6, gap_s=0.5,
                      vocab_size=cfg.vocab_size, seed=3,
                      prompt_lens=(4, 8), output_lens=(4, 8))
    adm = AdmissionConfig(queue_limit=2, policy="reject")
    first = replay(eng, sc, k=4, admission=adm, step_cost_s=1e-3)
    assert first.accounting_ok
    assert first.submitted == 12
    assert first.by_status.get("shed", 0) > 0      # genuinely overloaded
    assert sum(first.by_status.values()) == first.submitted
    with CompileCounter() as compiles:
        again = replay(eng, sc, k=4, admission=adm, step_cost_s=1e-3)
        swept = replay(
            eng, sc, k=4, step_cost_s=1e-3,
            admission=AdmissionConfig(queue_limit=2,
                                      policy="shed_oldest"))
    assert compiles.count == 0
    assert again == first                  # virtual clock: bit-for-bit
    assert swept.accounting_ok and swept.policy == "shed_oldest"


def test_replay_deadline_trace():
    cfg, model, params = _build("attn")
    eng = ServeEngine(model, params, batch=2, max_seq=64,
                      decode_block=4, prefill_chunk=8)
    sc = poisson_trace(n=8, rate=200.0, vocab_size=cfg.vocab_size,
                       seed=9, output_lens=(16,), deadline_ms=20.0)
    rep = replay(eng, sc, k=4, step_cost_s=5e-3)   # 16 tok > 20ms budget
    assert rep.accounting_ok
    assert rep.by_status.get("deadline_exceeded", 0) > 0
    assert rep.goodput_tok_s >= 0.0


# --------------------------------------------------------------------- #
# watchdog
# --------------------------------------------------------------------- #

def test_watchdog_flags_divergence():
    cfg, model, params = _build("attn")
    eng = ServeEngine(model, params, batch=2, max_seq=64, decode_block=4)
    eng.submit([1, 2, 3], max_new_tokens=16)
    eng.decode_loop()
    assert eng.watchdog_report()["ok"]
    # lost finish: host tenant on a deactivated device slot
    eng.state = dict(eng.state,
                     active=jnp.zeros_like(eng.state["active"]))
    rep = eng.watchdog_report()
    assert not rep["ok"]
    assert any("lost finish" in f for f in rep["findings"])
    # orphan: device-active slot with no host request
    eng.state = dict(eng.state,
                     active=jnp.ones_like(eng.state["active"]))
    rep = eng.watchdog_report()
    assert any("orphaned" in f for f in rep["findings"])
