"""Multi-device serving cases, run in a subprocess by
``tests/test_serve_sharded.py``.

``--xla_force_host_platform_device_count`` only takes effect before the
first jax backend initialization, and ``tests/conftest.py`` imports jax
at collection time — so every case that needs 4 devices runs here, in a
fresh interpreter whose environment the pytest wrapper pins
(``XLA_FLAGS``, ``JAX_PLATFORMS=cpu``, ``PYTHONPATH=src``) before
Python starts.  Invoked by file path (tests/ is not a package):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python tests/sharded_cases.py greedy_attn

Each case prints ``CASE_OK <name>`` on success; any assertion failure
propagates as a nonzero exit the wrapper reports verbatim.
"""

import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np

import jax

from repro.configs import get_config
from repro.launch.mesh import make_serving_mesh
from repro.models import build_model
from repro.serve import ServeEngine

MESHES = (None, (2,), (2, 2))
PROMPTS = ([5, 7, 11, 13, 17], [3, 1, 4, 1, 5, 9, 2, 6], [2, 71, 82])


def _build(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _serve(model, params, mesh_shape, decode_block=4, prefill_chunk=4,
           seed=0, **kw):
    """One scripted serving run; returns the per-request token streams.

    A fresh numpy rng per call: both sides of an identity comparison
    must see bit-identical frames/patches (drawing from one shared rng
    sequentially would feed the two runs different inputs)."""
    cfg = model.cfg
    rng = np.random.default_rng(seed)
    eng = ServeEngine(model, params, batch=2, max_seq=64,
                      decode_block=decode_block,
                      prefill_chunk=prefill_chunk,
                      mesh=make_serving_mesh(mesh_shape), **kw)
    for p in PROMPTS:
        pk = {}
        if cfg.is_encoder_decoder:
            pk["frames"] = rng.standard_normal(
                (9, cfg.d_model)).astype(np.float32)
        if cfg.frontend == "vision":
            pk["patches"] = rng.standard_normal(
                (6, cfg.d_model)).astype(np.float32)
        eng.submit(p, max_new_tokens=10, **pk)
    return [r.tokens for r in
            sorted(eng.run(max_steps=200), key=lambda r: r.request_id)]


def _assert_identity(arch, **kw):
    """Greedy streams bit-identical across every mesh shape, plus
    fused-vs-per-step on the 2x2 mesh (decode_block=1 is the per-step
    dispatch pattern through the same scan body)."""
    cfg, model, params = _build(arch)
    ref = _serve(model, params, None, **kw)
    for shape in MESHES[1:]:
        got = _serve(model, params, shape, **kw)
        assert got == ref, (
            f"{arch} {kw}: mesh {shape} diverged from single-device "
            f"greedy decode:\n ref={ref}\n got={got}")
    per_step = _serve(model, params, (2, 2), decode_block=1, **kw)
    assert per_step == ref, (
        f"{arch} {kw}: per-step dispatch on 2x2 mesh diverged from the "
        f"fused loop:\n ref={ref}\n got={per_step}")


def greedy_attn():
    """Attention family across every KV storage format: the quantized
    ring pools (packed codes + e8m0 scales) shard and decode exactly."""
    for kv_format in (None, "float8_e4m3fn", "float4_e2m1fn"):
        _assert_identity("gptneox-1b", kv_format=kv_format)
    # true bit-packed weight storage through the sharded store
    _assert_identity("gptneox-1b", weight_format="float4_e2m1fn")


def greedy_ssm_hybrid():
    """SSM conv/state carries (sectioned layout) and the hybrid
    attn+SSM stack through the same sharded fused loop."""
    _assert_identity("mamba2-2.7b")
    _assert_identity("jamba-v0.1-52b")


def greedy_encdec_vlm():
    """Slot-resident enc_out + quantized cross-KV, and VLM patch-prefix
    admission, on the sharded pool."""
    _assert_identity("seamless-m4t-medium")
    _assert_identity("internvl2-2b")


def logits_and_prefill():
    """(a) sharded-vs-unsharded prefill logits agree numerically (same
    math, different partitioning — reassociated psums, so allclose not
    bit-equal); (b) chunked prefill into the sharded pool is
    chunk-size-invariant bit-exactly (greedy streams)."""
    cfg, model, params = _build("gptneox-1b")
    prompt = [5, 7, 11, 13, 17, 19, 23, 29]

    def prefill_logits(mesh_shape):
        eng = ServeEngine(model, params, batch=2, max_seq=64,
                          decode_block=4, prefill_chunk=4,
                          mesh=make_serving_mesh(mesh_shape))
        logits = eng._prefill_into_slot(
            0, type("R", (), {"prompt": prompt, "frames": None,
                              "patches": None})())
        return np.asarray(jax.device_get(logits))

    ref = prefill_logits(None)
    got = prefill_logits((2, 2))
    np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)

    streams = [_serve(model, params, (2, 2), prefill_chunk=pc)
               for pc in (2, 4, 8)]
    assert streams[0] == streams[1] == streams[2], (
        f"sharded chunked prefill is chunk-size-dependent: {streams}")


def sanitize_sharded():
    """The mesh-native engine passes the full sanitizer stack on a real
    2x2 mesh: compile-exactly-once, zero implicit transfers in the
    fused loop, and no all-gather larger than the sample-point logits
    in the partitioned scan HLO."""
    from repro.analysis.sanitize import sanitize_serving

    mesh = make_serving_mesh((2, 2))
    for kw in ({}, {"kv_format": "float4_e2m1fn"}):
        rep = sanitize_serving(arch="gptneox-1b", mesh=mesh, **kw)
        assert rep["compiled_exactly_once"], rep
        assert rep["zero_implicit_loop_transfers"], rep
        assert rep["tokens_match_warmup"], rep
        assert rep["no_oversized_gathers"], rep
        assert rep["mesh"] == "2x2", rep


def spec_matrix():
    """Speculative decode (n-gram drafting) on the sharded pool stays
    bit-identical to the single-device NON-speculative engine — greedy
    across KV formats, plus a sampled stream (folded keys are position-
    keyed, so neither the mesh nor the draft/verify dispatch pattern
    may perturb them)."""
    from repro.serve import SpecConfig

    cfg, model, params = _build("gptneox-1b")
    spec = SpecConfig(draft_tokens=3, ngram_table=64)
    for kv_format in (None, "float8_e4m3fn"):
        ref = _serve(model, params, None, kv_format=kv_format)
        for shape in MESHES[1:]:
            got = _serve(model, params, shape, kv_format=kv_format,
                         spec=spec)
            assert got == ref, (
                f"spec kv={kv_format}: mesh {shape} diverged from "
                f"single-device non-spec:\n ref={ref}\n got={got}")
    sampled_kw = dict(temperature=0.8, top_k=8)
    ref = _serve(model, params, None, **sampled_kw)
    got = _serve(model, params, (2, 2), spec=spec, **sampled_kw)
    assert got == ref, (
        f"sampled spec on 2x2 mesh diverged:\n ref={ref}\n got={got}")


def contracts_sharded():
    """jaxpr contracts (packed-upcast, host-callback, cache-width) hold
    for the sharded entry points traced on a real 2x2 mesh."""
    from repro.analysis.contracts import check_entry_points

    findings = check_entry_points(mesh=make_serving_mesh((2, 2)))
    assert not findings, [f"{f.rule}: {f.message}" for f in findings]


CASES = {fn.__name__: fn for fn in (
    greedy_attn, greedy_ssm_hybrid, greedy_encdec_vlm,
    logits_and_prefill, spec_matrix, sanitize_sharded,
    contracts_sharded)}


def main(argv):
    assert len(jax.devices()) >= 4, (
        f"expected >=4 host devices, got {jax.devices()} — XLA_FLAGS "
        "was set after jax initialized?")
    names = argv or sorted(CASES)
    for name in names:
        CASES[name]()
        print(f"CASE_OK {name}", flush=True)


if __name__ == "__main__":
    main(sys.argv[1:])
