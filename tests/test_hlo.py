"""HLO analysis: collective parser, structure profile, and the loop-aware
cost model (validated against ground-truth FLOP counts)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_analysis import (analyze_compiled, parse_collectives,
                                     parse_structure, shape_bytes)
from repro.core.hlo_cost import analyze_hlo_text


def test_shape_bytes():
    assert shape_bytes("f32[4,8]") == 128
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("f8e4m3fn[16]") == 16
    assert shape_bytes("(f32[2,2], s32[3])") == 28
    assert shape_bytes("pred[]") == 1


def test_parse_collectives_synthetic():
    hlo = """
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
  %ag.1 = f32[64,128]{1,0} all-gather(f32[4,128]{1,0} %y), dimensions={0}
  %ars = f32[8] all-reduce-start(f32[8] %z)
  %ard = f32[8] all-reduce-done(f32[8] %ars)
"""
    stats = parse_collectives(hlo)
    assert stats.count_by_kind["all-reduce"] == 2     # start counted once
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.bytes_by_kind["all-reduce"] == 1024 * 4 + 32
    assert stats.bytes_by_kind["all-gather"] == 4 * 128 * 4


def test_parse_structure():
    hlo = """
  %f = f32[8] fusion(f32[8] %a), kind=kLoop, calls=%fc
  %d = f32[8,8] dot(f32[8,4] %x, f32[4,8] %y), metadata={op_name="m/dot"}
  %r = f32[64] reshape(f32[8,8] %d), metadata={op_name="m/dot"}
  %w = (s32[]) while((s32[]) %t), condition=%c, body=%b
"""
    s = parse_structure(hlo)
    assert s.n_fusions == 1 and s.n_dots == 1 and s.n_while == 1
    assert s.n_reshapes == 1
    assert s.remat_duplicate_ops == 1     # op_name "m/dot" seen twice


def test_loop_aware_flops_scan_matmul(key):
    n, trips = 128, 9

    def f(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=trips)
        return out

    x = jax.random.normal(key, (n, n))
    compiled = jax.jit(f).lower(x).compile()
    cost = analyze_hlo_text(compiled.as_text())
    want = trips * 2 * n ** 3
    assert abs(cost.flops - want) / want < 0.05, (cost.flops, want)


def test_loop_aware_beats_xla_costanalysis(key):
    """The whole reason hlo_cost exists: XLA counts loop bodies once."""
    n, trips = 64, 50

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ x), None
        out, _ = jax.lax.scan(body, x, None, length=trips)
        return out

    x = jax.random.normal(key, (n, n))
    compiled = jax.jit(f).lower(x).compile()
    stats = analyze_compiled(compiled)
    want = trips * 2 * n ** 3
    assert abs(stats.flops - want) / want < 0.1
    # raw XLA number misses the loop multiplier
    assert stats.xla_flops < stats.flops / 5


def test_nested_scan_flops(key):
    n, inner, outer = 32, 4, 6

    def f(x):
        def outer_body(c, _):
            def inner_body(d, _):
                return d @ x, None
            d, _ = jax.lax.scan(inner_body, c, None, length=inner)
            return d, None
        out, _ = jax.lax.scan(outer_body, x, None, length=outer)
        return out

    x = jax.random.normal(key, (n, n))
    compiled = jax.jit(f).lower(x).compile()
    cost = analyze_hlo_text(compiled.as_text())
    want = outer * inner * 2 * n ** 3
    assert abs(cost.flops - want) / want < 0.1


def test_bytes_nonzero_and_dominated_by_args(key):
    def f(x):
        return jnp.sum(x * 2.0)
    x = jax.random.normal(key, (1024, 1024))
    compiled = jax.jit(f).lower(x).compile()
    cost = analyze_hlo_text(compiled.as_text())
    assert cost.bytes >= x.nbytes            # at least reads the input
    assert cost.bytes < 8 * x.nbytes         # but not wildly inflated
