"""Unified per-slot decode state: EVERY arch family (SSM, hybrid,
enc-dec, VLM) through the fused K-step scan and chunked pooled prefill.

The slot-state protocol (``repro.models.slotstate``) makes the engine
arch-agnostic: pooled ring KV, SSM conv/state, slot-resident encoder
output + quantized cross-KV are all addressed by slot index and advanced
by one ``active`` predicate.  These tests pin the acceptance contract:
fused == per-step greedy bit-identity per family x kv_format, sampled
equivalence, and chunked prefill == full-prompt oracle for the stateful
legs (SSM carry, hybrid ring wrap, enc-dec encode-once, VLM patches).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine

# moe_capacity_factor=8.0 on the MoE archs: capacity never binds, so
# token dropping can't differ between the full-prompt oracle and the
# chunk-local prefill groups (the same idiom as test_decode_consistency
# — with drops, Switch-style routing is legitimately group-dependent).
ARCHS = {
    "ssm": ("mamba2-2.7b", {}),
    "hybrid": ("jamba-v0.1-52b", {"moe_capacity_factor": 8.0}),
    "enc-dec": ("seamless-m4t-medium", {}),
    "vlm": ("internvl2-2b", {}),
}


def _build(family):
    name, over = ARCHS[family]
    cfg = get_config(name).reduced()
    if over:
        cfg = dataclasses.replace(cfg, **over)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def models():
    return {f: _build(f) for f in ARCHS}


def _modal_inputs(cfg, seed=7):
    """(frames, patches) for the family, deterministic."""
    rng = np.random.RandomState(seed)
    frames = patches = None
    if cfg.is_encoder_decoder:
        frames = rng.randn(9, cfg.d_model).astype(np.float32) * 0.02
    if cfg.frontend == "vision":
        patches = rng.randn(5, cfg.d_model).astype(np.float32) * 0.02
    return frames, patches


def _tokens(results):
    return [r.tokens for r in sorted(results, key=lambda r: r.request_id)]


def _oracle(model, params, prompt, steps, frames=None, patches=None):
    """Full-prompt lm_prefill + per-step greedy decode — the reference
    the pooled chunked path must reproduce bit-exactly."""
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    n_pat = 0
    if frames is not None:
        batch["frames"] = jnp.asarray(frames[None], jnp.float32)
    if patches is not None:
        batch["patches"] = jnp.asarray(patches[None], jnp.float32)
        n_pat = patches.shape[0]
    logits, cache = model.prefill(params, batch, 64)
    out = [int(jnp.argmax(logits[0]))]
    pos = n_pat + len(prompt)
    for _ in range(steps - 1):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([out[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32), active=jnp.asarray([True]))
        out.append(int(jnp.argmax(lg[0])))
        pos += 1
    return out


# --------------------------------------------------------------------- #
# fused K-step scan == per-step dispatch, per family x kv_format
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("kv_format", [None, "float8_e4m3fn",
                                       "float4_e2m1fn"])
@pytest.mark.parametrize("family", list(ARCHS))
def test_fused_matches_per_step(models, family, kv_format):
    cfg, model, params = models[family]
    frames, patches = _modal_inputs(cfg)
    outs = []
    for block in (7, 1):                 # fused K=7 vs per-step
        eng = ServeEngine(model, params, batch=2, max_seq=64,
                          kv_format=kv_format, decode_block=block,
                          prefill_chunk=8)
        eng.submit([1, 2, 3, 4, 5, 6, 7], max_new_tokens=12,
                   frames=frames, patches=patches)
        eng.submit([9, 8, 7], max_new_tokens=4,       # finishes mid-K
                   frames=frames, patches=patches)
        outs.append(_tokens(eng.run()))
    assert outs[0] == outs[1]
    assert [len(t) for t in outs[0]] == [12, 4]


@pytest.mark.parametrize("family", list(ARCHS))
def test_fused_sampled_matches_per_step(models, family):
    """Per-slot (request id, position) key folding: SAMPLED streams are
    identical between the fused scan and per-step dispatch for every
    family, independent of batch composition."""
    cfg, model, params = models[family]
    frames, patches = _modal_inputs(cfg)
    a = ServeEngine(model, params, batch=2, max_seq=64, temperature=0.8,
                    top_k=8, seed=3, decode_block=5)
    b = ServeEngine(model, params, batch=1, max_seq=64, temperature=0.8,
                    top_k=8, seed=3, decode_block=1)
    a.submit([4, 5, 6], max_new_tokens=7, frames=frames, patches=patches)
    a.submit([9, 9], max_new_tokens=3, frames=frames, patches=patches)
    b.submit([4, 5, 6], max_new_tokens=7, frames=frames, patches=patches)
    assert _tokens(a.run())[0] == _tokens(b.run())[0]


# --------------------------------------------------------------------- #
# chunked pooled prefill == full-prompt oracle (the stateful legs)
# --------------------------------------------------------------------- #

def test_chunked_prefill_ssm_state_carry(models):
    """SSM chunked prefill: conv tail + ssd state carried across chunk
    boundaries (20-token prompt, chunk 8 -> two full chunks + a
    partially-valid tail whose invalid positions must be identity
    steps)."""
    cfg, model, params = models["ssm"]
    prompt = [int(2 + (i * 11) % 300) for i in range(20)]
    eng = ServeEngine(model, params, batch=2, max_seq=64,
                      decode_block=4, prefill_chunk=8)
    eng.submit(prompt, max_new_tokens=6)
    got = eng.run()[0].tokens
    assert got == _oracle(model, params, prompt, 6)


def test_chunked_prefill_hybrid_ring_wrap():
    """Hybrid (jamba) with a sliding window SMALLER than the prompt: the
    attention layer's ring wraps during chunked prefill while the SSM
    layers carry state — both must match the full-prompt oracle."""
    cfg = dataclasses.replace(
        get_config("jamba-v0.1-52b").reduced(),
        sliding_window=16, moe_capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    prompt = [int(1 + (i * 7) % 200) for i in range(24)]   # 24 > window
    eng = ServeEngine(model, params, batch=1, max_seq=64,
                      decode_block=4, prefill_chunk=8)
    eng.submit(prompt, max_new_tokens=6)
    got = eng.run()[0].tokens
    assert got == _oracle(model, params, prompt, 6)


def test_chunked_prefill_encdec_matches_oracle(models):
    """enc-dec: encode ONCE into slot-resident enc_out + cross-KV, then
    chunk the decoder prompt; engine pads frames to the pool's fixed
    enc_len, so matching the unpadded oracle also proves the key-valid
    masking throughout encoder self-attention and cross-attention."""
    cfg, model, params = models["enc-dec"]
    frames, _ = _modal_inputs(cfg)
    prompt = [int(3 + (i * 5) % 250) for i in range(13)]
    eng = ServeEngine(model, params, batch=2, max_seq=64,
                      decode_block=3, prefill_chunk=8)
    eng.submit(prompt, max_new_tokens=6, frames=frames)
    got = eng.run()[0].tokens
    assert got == _oracle(model, params, prompt, 6, frames=frames)


def test_chunked_prefill_vlm_patches_matches_oracle(models):
    """VLM: patch-prefix embeddings streamed through the chunked prefill
    (embeds executable), then the text prompt — one trunk, one oracle."""
    cfg, model, params = models["vlm"]
    _, patches = _modal_inputs(cfg)
    prompt = [int(3 + (i * 5) % 250) for i in range(13)]
    eng = ServeEngine(model, params, batch=2, max_seq=64,
                      decode_block=3, prefill_chunk=8)
    eng.submit(prompt, max_new_tokens=6, patches=patches)
    got = eng.run()[0].tokens
    assert got == _oracle(model, params, prompt, 6, patches=patches)


# --------------------------------------------------------------------- #
# quantized cross-KV + per-layer mixed formats
# --------------------------------------------------------------------- #

def test_cross_kv_quantized_stats(models):
    """Cross-attention KV is a quantized ring cache like self-attention
    KV: kv_cache_stats counts its bytes, and fp4 storage is sub-byte."""
    cfg, model, params = models["enc-dec"]
    dense = ServeEngine(model, params, batch=2, max_seq=64)
    quant = ServeEngine(model, params, batch=2, max_seq=64,
                        kv_format="float4_e2m1fn")
    assert dense.kv_stats["cross_kv_bytes"] > 0
    assert quant.kv_stats["cross_kv_bytes"] > 0
    assert (quant.kv_stats["cross_kv_bytes"]
            < dense.kv_stats["cross_kv_bytes"] / 2)
    assert quant.kv_stats["bytes_per_elem"] < 1.0
    # cross layers are reported per-position alongside self-attn KV
    assert any(name.endswith(".cross")
               for name in quant.kv_stats["per_layer"])


def test_mixed_per_layer_kv_formats():
    """cfg.kv_formats: fp4 on gemma2's sliding-window locals, fp8 on
    globals — measured per-layer B/elem differs, and the engine serves
    greedily identical tokens to the unquantized engine's format run."""
    cfg = get_config("gemma2-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    fmts = tuple("float4_e2m1fn" if blk.window else "float8_e4m3fn"
                 for blk in cfg.block_pattern())
    eng = ServeEngine(model, params, batch=1, max_seq=64,
                      kv_format=fmts, decode_block=4, prefill_chunk=8)
    per_layer = eng.kv_stats["per_layer"]
    bpe = {name: d["bytes_per_elem"] for name, d in per_layer.items()}
    assert bpe["pos0"] < 0.7 < 1.0 < bpe["pos1"] <= 1.25
    # fused == per-step still holds under mixed formats
    outs = []
    for block in (4, 1):
        e = ServeEngine(model, params, batch=1, max_seq=64,
                        kv_format=fmts, decode_block=block,
                        prefill_chunk=8)
        e.submit([5, 4, 3, 2, 1], max_new_tokens=8)
        outs.append(_tokens(e.run()))
    assert outs[0] == outs[1]


def test_supports_chunked_prefill_everywhere():
    """There is no fallback path left: every config reports chunked
    prefill support (the engine has no width-1 prefill to fall back
    to)."""
    from repro.configs import REGISTRY

    for name in REGISTRY:
        assert build_model(get_config(name).reduced()) \
            .supports_chunked_prefill, name
