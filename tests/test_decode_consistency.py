"""Serving-vs-training consistency: prefill + token-by-token decode must
reproduce the teacher-forced forward logits for every architecture family
(the strongest end-to-end correctness check in the suite)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build_model, make_batch
from repro.configs.base import ShapeConfig

# One representative per family; MoE archs get a no-drop capacity factor
# (capacity dropping legitimately differs between grouping layouts).
CASES = [
    ("mamba2-2.7b", {}),                       # ssm
    ("qwen2.5-3b", {}),                        # dense GQA + bias
    ("gemma2-2b", {}),                         # local/global + softcaps
    ("gemma-2b", {}),                          # MQA
    ("jamba-v0.1-52b", {"moe_capacity_factor": 8.0}),   # hybrid + MoE
    ("kimi-k2-1t-a32b", {"moe_capacity_factor": 8.0}),  # MoE top-8
    ("internvl2-2b", {}),                      # VLM early fusion
]


@pytest.mark.parametrize("arch,overrides", CASES)
def test_decode_matches_forward(arch, overrides, key):
    cfg = dataclasses.replace(get_config(arch).reduced(), **overrides)
    model = build_model(cfg)
    params = model.init(key)
    S, P = 48, 32
    shape = ShapeConfig("t", "train", S, 2)
    batch = make_batch(cfg, shape, key)
    full_logits, _ = jax.jit(model.forward)(params, batch)

    tokens = batch["tokens"]
    prefill_batch = dict(batch, tokens=tokens[:, :P])
    pre_logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, S + 8))(params, prefill_batch)
    # trunk position of text token P-1 == -(len(text) - (P-1)) from end
    text_len = tokens.shape[1]
    trunk_idx = full_logits.shape[1] - text_len + (P - 1)
    errs = [float(jnp.abs(pre_logits - full_logits[:, trunk_idx]).max())]

    step = jax.jit(model.decode_step)
    offset = full_logits.shape[1] - text_len    # patch prefix for VLM
    for t in range(P, text_len):
        pos = jnp.full((2,), offset + t, jnp.int32)
        lg, cache = step(params, cache, tokens[:, t], pos)
        errs.append(float(jnp.abs(lg - full_logits[:, offset + t]).max()))
    assert max(errs) < 5e-4, f"{arch}: decode diverges {max(errs):.2e}"


def test_encdec_decode_matches_forward(key):
    cfg = get_config("seamless-m4t-medium").reduced()
    model = build_model(cfg)
    params = model.init(key)
    S, P = 32, 16
    shape = ShapeConfig("t", "train", S, 2)
    batch = make_batch(cfg, shape, key)
    full_logits, _ = jax.jit(model.forward)(params, batch)
    tokens = batch["tokens"]
    pre_logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, S + 8))(
        params, dict(batch, tokens=tokens[:, :P]))
    errs = [float(jnp.abs(pre_logits - full_logits[:, P - 1]).max())]
    step = jax.jit(model.decode_step)
    for t in range(P, S):
        lg, cache = step(params, cache, tokens[:, t],
                         jnp.full((2,), t, jnp.int32))
        errs.append(float(jnp.abs(lg - full_logits[:, t]).max()))
    assert max(errs) < 5e-4, f"enc-dec decode diverges {max(errs):.2e}"


def test_ring_buffer_long_decode(key):
    """gemma2 local layers use a ring cache: decoding far past the window
    must still match the teacher-forced forward."""
    cfg = get_config("gemma2-2b").reduced()   # window = 32
    model = build_model(cfg)
    params = model.init(key)
    S = 80                                     # > 2x window
    tokens = jax.random.randint(key, (1, S), 0, cfg.vocab_size, jnp.int32)
    full_logits, _ = jax.jit(model.forward)(params, {"tokens": tokens})
    P = 8
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, S))(
        params, {"tokens": tokens[:, :P]})
    step = jax.jit(model.decode_step)
    errs = []
    for t in range(P, S):
        lg, cache = step(params, cache, tokens[:, t],
                         jnp.full((1,), t, jnp.int32))
        errs.append(float(jnp.abs(lg - full_logits[:, t]).max()))
    assert max(errs) < 5e-4, f"ring cache diverges: {max(errs):.2e}"
