"""Property tests for the speculative ring-cache write/rollback pair.

The contract under test (``repro.models.attention.cache_write_rows`` /
``cache_rollback``): committing per-row position blocks — with rejected
tails either masked out (the target-cache commit flow) or eagerly
written and then rolled back (the draft-cache flow) — reproduces the
cache an oracle builds by writing only the finally-accepted history,
for every packed KV format, through ring wrap-around, with every
cross-KV / recurrent / payload leaf outside the rolled-back pointers
untouched.

"Byte-for-byte" means: ``slot_pos`` arrays exactly equal, and every
payload byte (packed codes + e8m0 scales) equal wherever ``slot_pos``
marks a live entry.  Bytes under invalidated (-1) pointers are
explicitly DON'T-CARE — rollback is a pointer move, not a payload wipe
(the next write at the slot replaces the bytes; the attention mask
never reads them) — and the don't-care region is exactly what the
masked comparison excludes.

Deterministic adversarial scripts (accept-all, reject-all, alternating,
per-row skew, wrap-around) always run; hypothesis drives randomized
scripts on top when installed (CI installs it; the container may not).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.models import attention as attn
from repro.serve.faults import _ring_parts

FORMATS = ["float8_e4m3fn", "float6_e2m3fn", "float4_e2m1fn"]
B, NKV, DH = 2, 2, 8
T_MAX = 40

_rng = np.random.default_rng(11)
# the "true" K/V history: a fixed function of (row, position) so the
# oracle and the speculative path quantize identical inputs
TRUE_K = _rng.standard_normal((B, T_MAX + 8, NKV, DH)).astype(np.float32)
TRUE_V = _rng.standard_normal((B, T_MAX + 8, NKV, DH)).astype(np.float32)


def _true_kv(positions):
    """Gather true (k, v) rows for per-row absolute positions (B, s)."""
    rows = np.arange(B)[:, None]
    return (jnp.asarray(TRUE_K[rows, positions]),
            jnp.asarray(TRUE_V[rows, positions]))


def _garbage_kv(s, salt):
    g = np.random.default_rng(1000 + salt)
    return (jnp.asarray(g.standard_normal((B, s, NKV, DH)), jnp.float32),
            jnp.asarray(g.standard_normal((B, s, NKV, DH)), jnp.float32))


def _oracle(fmt, cap, p_final):
    """Write ONLY the accepted history 0..p_final[row]-1, in chunks."""
    cache = attn.init_kv_cache(B, cap, NKV, DH, jnp.bfloat16,
                               kv_format=fmt)
    hi = int(p_final.max())
    for start in range(0, hi, 4):
        s = min(4, hi - start)
        positions = np.broadcast_to(np.arange(start, start + s),
                                    (B, s)).copy()
        valid = jnp.asarray(positions < p_final[:, None])
        k, v = _true_kv(positions)
        cache = attn.cache_write_rows(cache, k, v,
                                      jnp.asarray(positions), valid,
                                      kv_format=fmt)
    return cache


def _assert_cache_equal(got, want):
    sp_g, sp_w = np.asarray(got["slot_pos"]), np.asarray(want["slot_pos"])
    np.testing.assert_array_equal(sp_g, sp_w)
    live = sp_w >= 0
    for leaf in ("k_q", "k_s", "v_q", "v_s"):
        g, w = np.asarray(got[leaf]), np.asarray(want[leaf])
        assert (g[live] == w[live]).all(), (
            f"{leaf} bytes diverge under live slot_pos entries")


def _run_script(fmt, cap, script, eager):
    """Drive one speculative history through the cache primitives.

    script: list of (s, (e_row0, e_row1)) — block width and per-row
    accepted length.  ``eager=False`` is the target-commit flow (write
    accepted rows only, via the valid mask); ``eager=True`` is the
    draft flow (write ALL rows — accepted get true bytes, rejected get
    garbage — then roll the rejected tail back).  Returns the final
    cache and per-row final positions.
    """
    cache = attn.init_kv_cache(B, cap, NKV, DH, jnp.bfloat16,
                               kv_format=fmt)
    p = np.zeros(B, np.int64)
    for blk, (s, es) in enumerate(script):
        e = np.minimum(np.minimum(np.asarray(es, np.int64), s),
                       T_MAX - p)                     # stop at T_MAX
        positions = p[:, None] + np.arange(s)[None, :]
        accept = jnp.asarray(np.arange(s)[None, :] < e[:, None])
        k, v = _true_kv(positions)
        if eager:
            gk, gv = _garbage_kv(s, blk)
            k = jnp.where(np.asarray(accept)[:, :, None, None], k, gk)
            v = jnp.where(np.asarray(accept)[:, :, None, None], v, gv)
            cache = attn.cache_write_rows(cache, k, v,
                                          jnp.asarray(positions),
                                          kv_format=fmt)
            cache = attn.cache_rollback(cache, jnp.asarray(positions),
                                        ~accept)
        else:
            cache = attn.cache_write_rows(cache, k, v,
                                          jnp.asarray(positions), accept,
                                          kv_format=fmt)
        p = p + e
    return cache, p


def _check(fmt, cap, script, eager):
    got, p_final = _run_script(fmt, cap, script, eager)
    _assert_cache_equal(got, _oracle(fmt, cap, p_final))


SCRIPTS = {
    # every draft verifies: full blocks, clean ring wrap at cap=12
    "accept_all": [(4, (4, 4))] * 10,
    # nothing verifies: pure write/rollback churn, no progress
    "reject_all": [(3, (0, 0))] * 4 + [(4, (4, 4))] * 10,
    # alternating accept/reject, rows in phase
    "alternating": [(4, (2, 2)), (3, (0, 0)), (4, (4, 4)),
                    (2, (1, 1)), (4, (3, 3))] * 4,
    # rows diverge hard: row 0 races ahead, row 1 crawls then finishes
    "row_skew": [(4, (4, 1)), (4, (4, 0)), (3, (3, 2)),
                 (4, (2, 4))] * 6,
}


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("name", sorted(SCRIPTS))
@pytest.mark.parametrize("eager", [False, True])
def test_rollback_scripts(fmt, name, eager):
    """Commit flow with ring wrap-around (cap < history length), and
    draft flow on an ample ring (capacity >= history, the draft-cache
    configuration — eager rejected writes never land on live slots)."""
    cap = 48 if eager else 12
    _check(fmt, cap, SCRIPTS[name], eager)


@pytest.mark.parametrize("fmt", FORMATS)
def test_stale_rollback_is_noop(fmt):
    """The rollback guard: invalidating a position whose ring slot has
    since been overwritten by a LATER (wrapped) position — or was never
    written — must leave the cache bit-identical.  This is what makes
    rollback safe to issue for inactive rows and for tails that a
    subsequent commit already replaced."""
    cap = 12
    cache, p_final = _run_script(fmt, cap, SCRIPTS["accept_all"], False)
    before = {k_: np.asarray(v_) for k_, v_ in cache.items()}
    # positions a full ring-lap behind the live span (the ring holds
    # the last cap positions), plus positions far beyond anything
    # written
    for base in (p_final - 2 * cap, p_final + 5):
        positions = jnp.asarray(base[:, None] + np.arange(4)[None, :])
        rolled = attn.cache_rollback(cache, positions,
                                     jnp.ones((B, 4), bool))
        for leaf, want in before.items():
            np.testing.assert_array_equal(np.asarray(rolled[leaf]), want)


def test_model_rollback_touches_only_self_attn_pointers():
    """Model-level rollback (the draft-cache entry point) moves ONLY the
    self-attention ring ``slot_pos`` pointers: cross-KV rings (never
    speculatively written), recurrent SSM parts, and every payload leaf
    stay bit-identical — across an enc-dec stack, a hybrid attn+SSM
    stack, and a period-stacked sliding-window stack."""
    for name, kw in (("seamless-m4t-medium", {"enc_len": 16}),
                     ("jamba-v0.1-52b", {}), ("gemma2-2b", {})):
        cfg = get_config(name).reduced()
        model = build_model(cfg)
        cache = model.init_cache(2, 32, **kw)
        # seed EVERY ring part (self + cross) with live pointers so a
        # too-eager rollback would visibly clear them
        for pname, part, tree in _ring_parts(cache):
            sp = tree["slot_pos"]
            live = jnp.broadcast_to(
                jnp.arange(sp.shape[-1], dtype=jnp.int32), sp.shape)
            cache[pname][part] = dict(tree, slot_pos=live)
        positions = jnp.broadcast_to(jnp.arange(3, 7), (2, 4))
        out = model.rollback_chunk(cache, positions,
                                   jnp.ones((2, 4), bool))
        flat_in = jax.tree_util.tree_flatten_with_path(cache)[0]
        flat_out = jax.tree_util.tree_flatten_with_path(out)[0]
        rolled = []
        for (path_i, leaf_i), (path_o, leaf_o) in zip(flat_in, flat_out):
            assert path_i == path_o
            key = jax.tree_util.keystr(path_i)
            if np.array_equal(np.asarray(leaf_i), np.asarray(leaf_o)):
                continue
            rolled.append(key)
            # only a self-attn kv slot_pos may change, and only to -1
            # at exactly the rolled positions
            assert "slot_pos" in key and "'kv'" in key, (
                f"{cfg.name}: rollback modified non-self-attn leaf "
                f"{key}")
            got = np.asarray(leaf_o)
            want = np.asarray(leaf_i).copy()
            want[..., 3:7] = -1
            np.testing.assert_array_equal(got, want)
        assert rolled, f"{cfg.name}: rollback moved no pointers at all"


try:
    import hypothesis
    from hypothesis import strategies as hyp_st
except ImportError:                                # pragma: no cover
    hypothesis = None

if hypothesis is not None:
    _script_st = hyp_st.lists(
        hyp_st.tuples(hyp_st.integers(1, 4),
                      hyp_st.tuples(hyp_st.integers(0, 4),
                                    hyp_st.integers(0, 4))),
        min_size=3, max_size=24)

    @hypothesis.settings(max_examples=10, deadline=None, database=None)
    @hypothesis.given(script=_script_st, fmt=hyp_st.sampled_from(FORMATS),
                      eager=hyp_st.booleans())
    def test_rollback_property(script, fmt, eager):
        """PROPERTY: any accept/reject script, any packed format, both
        flows — the speculative cache equals the oracle."""
        _check(fmt, 48 if eager else 12, script, eager)
else:                                              # pragma: no cover
    def test_rollback_property():
        pytest.skip("hypothesis not installed")
