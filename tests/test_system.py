"""End-to-end behaviour: train -> checkpoint -> restart -> serve on one
architecture, plus the fault-tolerance machinery (watchdog, heartbeat)."""

import dataclasses
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_shape
from repro.data import make_stream
from repro.distributed import Heartbeat, StepWatchdog
from repro.models import build_model
from repro.optim import AdamWConfig, Schedule
from repro.serve import ServeEngine
from repro.train import (TrainLoopConfig, make_train_step, run_train_loop,
                         train_state_init)


def test_train_checkpoint_serve_pipeline(tmp_path, key):
    """The full lifecycle on CPU: train a reduced model, checkpoint,
    restore into a fresh process-state, serve batched requests."""
    cfg = dataclasses.replace(get_config("gptneox-1b").reduced(),
                              n_layers=2)
    model = build_model(cfg)
    opt = AdamWConfig(schedule=Schedule(peak_lr=5e-3, warmup_steps=5,
                                        decay_steps=60))
    state = train_state_init(model, opt, key)
    stream = make_stream(cfg, smoke_shape("train"))
    step = jax.jit(make_train_step(model, opt))
    ckdir = str(tmp_path / "ck")
    state, history = run_train_loop(
        step, state, stream,
        TrainLoopConfig(total_steps=30, checkpoint_every=15,
                        checkpoint_dir=ckdir, log_every=10,
                        async_checkpoint=False))
    assert history[-1]["loss"] < history[0]["loss"]

    # restore into a new state and serve
    from repro.checkpoint import Checkpointer
    ck = Checkpointer(ckdir)
    like = train_state_init(model, opt, key)
    restored, step_no = ck.restore_latest(like=like)
    assert step_no == 30
    eng = ServeEngine(model, restored["params"], batch=2, max_seq=64)
    eng.submit([1, 2, 3], max_new_tokens=4)
    eng.submit([4, 5, 6, 7], max_new_tokens=4)
    results = eng.run()
    assert len(results) == 2
    assert all(len(r.tokens) == 4 for r in results)


def test_watchdog_flags_straggler():
    events = []
    wd = StepWatchdog(deadline_factor=5.0,
                      on_straggler=lambda e: events.append(e))
    for i in range(6):
        wd.start_step(i)
        time.sleep(0.002)
        wd.end_step()
    wd.start_step(6)
    time.sleep(0.1)                      # 50x the median: a straggler
    ev = wd.end_step()
    assert ev is not None and events and events[0].step == 6


def test_heartbeat(tmp_path):
    hb = Heartbeat(str(tmp_path), process_index=3)
    hb.beat(42)
    step, ts = hb.last()
    assert step == 42
    assert not hb.stale(timeout_s=60)
    assert hb.stale(timeout_s=0)


@pytest.mark.slow
def test_dryrun_single_cell_subprocess(tmp_path):
    """The real multi-pod dry-run, smallest cell, in a subprocess (it
    forces 512 host devices)."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "seamless-m4t-medium", "--shape", "decode_32k",
         "--out", str(tmp_path)],
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    import json, glob
    files = glob.glob(str(tmp_path / "*.json"))
    assert len(files) == 1
    d = json.load(open(files[0]))
    assert d["flops_per_device"] > 0
    assert d["roofline"]["dominant"] in ("compute", "memory", "collective")
