"""Differential conformance suite for speculative decoding.

The tentpole contract: the speculative loop (draft -> one batched
verify -> commit accepted prefix -> pointer rollback) NEVER changes
what the engine emits, only how many dispatches it takes.  Emitted
tokens are always the true sampled tokens from the verify logits, so
greedy AND sampled streams must be bit-identical to the non-speculative
fused loop — per arch family x kv_format x mesh, through ring wraps,
mid-block finishes, faults, and arbitrary accept/reject patterns.

The scripted ``draft_fn`` hook turns acceptance into a controlled
input: a hypothesis-driven property test feeds adversarial per-position
match/mismatch patterns (accept-all, reject-all, alternating, random)
against an oracle stream precomputed from the non-speculative engine,
and asserts output invariance for every pattern.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitize import sanitize_spec
from repro.configs import get_config
from repro.models import build_model
from repro.serve import AdmissionConfig, ServeEngine, SpecConfig

# same idiom as test_serve_robust: moe_capacity_factor=8.0 keeps MoE
# token dropping out of the differential comparison (ample capacity
# makes routing per-token independent of batch composition)
ARCHS = {
    "attn": ("gptneox-1b", {}),
    "ssm": ("mamba2-2.7b", {}),
    "hybrid": ("jamba-v0.1-52b", {"moe_capacity_factor": 8.0}),
}

KV_FORMATS = [None, "float8_e4m3fn", "float4_e2m1fn"]

PROMPTS = [[1, 2, 3, 4, 5, 6, 7], [9, 8, 7]]


def _build(family):
    name, over = ARCHS[family]
    cfg = get_config(name).reduced()
    if over:
        cfg = dataclasses.replace(cfg, **over)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def models():
    return {f: _build(f) for f in ARCHS}


def _tokens(results):
    return [r.tokens for r in sorted(results, key=lambda r: r.request_id)]


def _by_id(results):
    return {r.request_id: r for r in results}


# --------------------------------------------------------------------- #
# greedy identity matrix: family x kv_format
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("family", list(ARCHS))
@pytest.mark.parametrize("kv_format", KV_FORMATS)
def test_spec_greedy_matches_nonspec(models, family, kv_format):
    """Greedy speculative decode must be token-identical to the
    non-speculative fused loop, including a slot that finishes
    mid-speculative-block (shorter second request)."""
    cfg, model, params = models[family]
    outs = []
    for spec in (SpecConfig(draft_tokens=3, ngram_table=64), None):
        eng = ServeEngine(model, params, batch=2, max_seq=64,
                          kv_format=kv_format, decode_block=6,
                          prefill_chunk=4, spec=spec)
        eng.submit(PROMPTS[0], max_new_tokens=12)
        eng.submit(PROMPTS[1], max_new_tokens=5)   # finishes mid-block
        res = eng.run()
        assert all(r.status == "ok" for r in res)
        outs.append(_tokens(res))
    assert outs[0] == outs[1]
    assert [len(t) for t in outs[0]] == [12, 5]


@pytest.mark.parametrize("family", list(ARCHS))
def test_spec_sampled_matches_nonspec(models, family):
    """Per-(request, position) key folding makes SAMPLED speculative
    streams identical too: the verify-row fold reproduces exactly the
    per-step folds the non-speculative loop would have made."""
    cfg, model, params = models[family]
    outs = []
    for spec in (SpecConfig(draft_tokens=4, ngram_table=64), None):
        eng = ServeEngine(model, params, batch=2, max_seq=64,
                          temperature=0.8, top_k=8, seed=3,
                          decode_block=5, spec=spec)
        eng.submit(PROMPTS[0], max_new_tokens=9)
        eng.submit(PROMPTS[1], max_new_tokens=6)
        outs.append(_tokens(eng.run()))
    assert outs[0] == outs[1]


def test_spec_sampled_batch_composition_independent(models):
    """A sampled speculative stream does not depend on what shares the
    pool: batch-2 speculative == batch-1 non-speculative per-step."""
    cfg, model, params = models["attn"]
    a = ServeEngine(model, params, batch=2, max_seq=64, temperature=0.8,
                    top_k=8, seed=3, decode_block=5,
                    spec=SpecConfig(draft_tokens=3, ngram_table=64))
    b = ServeEngine(model, params, batch=1, max_seq=64, temperature=0.8,
                    top_k=8, seed=3, decode_block=1)
    a.submit([4, 5, 6], max_new_tokens=7)
    a.submit([9, 9], max_new_tokens=3)             # batch companion
    b.submit([4, 5, 6], max_new_tokens=7)
    assert _tokens(a.run())[0] == _tokens(b.run())[0]


def test_spec_ring_wrap_matches_nonspec():
    """Speculate far past a sliding window so local-layer ring buffers
    wrap INSIDE a verify block and rejected tails roll back across the
    wrap boundary."""
    cfg = get_config("gemma2-2b").reduced()        # window 32
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    outs = []
    for spec in (SpecConfig(draft_tokens=3, ngram_table=64), None):
        eng = ServeEngine(model, params, batch=1, max_seq=64,
                          decode_block=8, prefill_chunk=8, spec=spec)
        eng.submit(list(range(1, 11)), max_new_tokens=45)  # 10+45 > 32
        outs.append(_tokens(eng.run()))
    assert outs[0] == outs[1]
    assert len(outs[0][0]) == 45


def test_spec_single_token_request(models):
    """max_new_tokens=1 is served entirely by admission: the spec loop
    must emit nothing for it and the stream must match non-spec."""
    cfg, model, params = models["attn"]
    outs = []
    for spec in (SpecConfig(draft_tokens=3, ngram_table=64), None):
        eng = ServeEngine(model, params, batch=2, max_seq=64,
                          decode_block=4, spec=spec)
        eng.submit([5, 4, 3], max_new_tokens=1)
        eng.submit([2, 2, 2], max_new_tokens=6)
        outs.append(_tokens(eng.run()))
    assert outs[0] == outs[1]
    assert len(outs[0][0]) == 1 and len(outs[0][1]) == 6


# --------------------------------------------------------------------- #
# scripted drafts: adversarial accept/reject patterns vs the oracle
# --------------------------------------------------------------------- #

D = 3                    # draft tokens for the scripted-pattern tests
MAX_SEQ = 64


@pytest.fixture(scope="module")
def oracle(models):
    """Non-speculative greedy streams + a device (slot, position) table
    of them: tbl[slot, p] = the token the oracle samples at position p
    (admission token at p = trunk_len, loop token j at trunk_len + j)."""
    cfg, model, params = models["attn"]
    eng = ServeEngine(model, params, batch=2, max_seq=MAX_SEQ,
                      decode_block=4)
    eng.submit(PROMPTS[0], max_new_tokens=12)
    eng.submit(PROMPTS[1], max_new_tokens=9)
    streams = _tokens(eng.run())
    tbl = np.full((2, MAX_SEQ), -7, np.int32)      # -7 never matches
    for slot, (prompt, toks) in enumerate(zip(PROMPTS, streams)):
        for j, t in enumerate(toks):
            tbl[slot, len(prompt) + j] = t
    return cfg, model, params, streams, jnp.asarray(tbl)


def _scripted_engine(model, params, tbl, pattern):
    """Spec engine whose drafts are scripted by ``pattern`` (b, MAX_SEQ)
    bool: True at [slot, p] -> the draft proposed for position p is the
    oracle token (accept), False -> a guaranteed-wrong token (reject)."""
    pat = jnp.asarray(pattern, bool)
    vocab = 512

    def draft_fn(st):
        # verify row d consumes draft d at position pos + 1 + d
        q = st["pos"][:, None] + 1 + jnp.arange(D)[None, :]
        q = jnp.minimum(q, MAX_SEQ - 1)
        rows = jnp.arange(pat.shape[0])[:, None]
        right = tbl[rows, q]
        wrong = (right + 1) % vocab                # differs even at -7
        return jnp.where(pat[rows, q], right, wrong).astype(jnp.int32)

    return ServeEngine(model, params, batch=2, max_seq=MAX_SEQ,
                       decode_block=2 * (D + 1),
                       spec=SpecConfig(draft_tokens=D, ngram_table=64,
                                       draft_fn=draft_fn))


def _run_scripted(oracle, pattern):
    cfg, model, params, streams, tbl = oracle
    eng = _scripted_engine(model, params, tbl, pattern)
    eng.submit(PROMPTS[0], max_new_tokens=12)
    eng.submit(PROMPTS[1], max_new_tokens=9)
    res = eng.run()
    assert all(r.status == "ok" for r in res)
    assert _tokens(res) == streams
    return eng


def test_scripted_accept_all_and_reject_all(oracle):
    """The two extremes bound acceptance accounting: reject-all commits
    exactly one (true) token per block (mean accepted length 1.0);
    accept-all commits full blocks wherever the budget allows."""
    full = _run_scripted(oracle, np.ones((2, MAX_SEQ), bool))
    none = _run_scripted(oracle, np.zeros((2, MAX_SEQ), bool))
    r_full, r_none = full.spec_report(), none.spec_report()
    assert r_none["mean_accepted_len"] == 1.0
    assert r_full["mean_accepted_len"] > 2.5
    assert r_full["blocks"] < r_none["blocks"]
    # loop tokens: 11 + 8 (admission emits each stream's first token)
    assert r_full["accepted_tokens"] == r_none["accepted_tokens"] == 19


def test_scripted_alternating_and_skew(oracle):
    """Alternating accept/reject and per-slot skewed patterns must not
    perturb the streams either."""
    alt = np.zeros((2, MAX_SEQ), bool)
    alt[:, ::2] = True
    _run_scripted(oracle, alt)
    skew = np.zeros((2, MAX_SEQ), bool)
    skew[0] = True                    # slot 0 races ahead, slot 1 crawls
    _run_scripted(oracle, skew)


try:
    import hypothesis
    from hypothesis import strategies as hyp_st
except ImportError:                                # pragma: no cover
    hypothesis = None

if hypothesis is not None:
    @hypothesis.settings(max_examples=8, deadline=None, database=None)
    @hypothesis.given(bits=hyp_st.lists(hyp_st.booleans(),
                                        min_size=2 * MAX_SEQ,
                                        max_size=2 * MAX_SEQ))
    def test_scripted_pattern_property(oracle, bits):
        """PROPERTY: for ANY per-(slot, position) accept/reject pattern
        the speculative engine reproduces the oracle streams exactly —
        drafts decide dispatch count, never content."""
        pattern = np.asarray(bits, bool).reshape(2, MAX_SEQ)
        _run_scripted(oracle, pattern)
else:                                              # pragma: no cover
    def test_scripted_pattern_property():
        pytest.skip("hypothesis not installed")


# --------------------------------------------------------------------- #
# faults inside a speculative block
# --------------------------------------------------------------------- #

def test_spec_fault_matches_nonspec(models):
    """A logits fault armed mid-stream fires at the same absolute token
    position under speculation: same partial prefix, same ``faulted``
    status, survivor bit-identical — even when the poisoned row lands
    inside a verify block's accepted prefix."""
    cfg, model, params = models["attn"]
    want = None
    for spec in (None, SpecConfig(draft_tokens=3, ngram_table=64)):
        eng = ServeEngine(model, params, batch=2, max_seq=64,
                          decode_block=6, spec=spec)
        a = eng.submit(PROMPTS[0], max_new_tokens=20)
        b = eng.submit(PROMPTS[1], max_new_tokens=20)
        eng.decode_loop()              # admit + first fused block
        # normalize to one absolute stream position: the engines have
        # emitted different counts after one block (that is the point
        # of speculation), so compute the arming delay per engine
        target = 10
        eng.inject_fault(a, "logits_nan",
                         delay=target - len(eng.out_tokens[0]))
        res = _by_id(eng.run())
        got = {rid: (r.status, r.tokens) for rid, r in res.items()}
        assert got[a][0] == "faulted" and len(got[a][1]) == target
        assert got[b][0] == "ok" and len(got[b][1]) == 20
        if want is None:
            want = got
        else:
            assert got == want
        assert eng.accounting()["balanced"]
        assert eng.watchdog_report()["ok"]


# --------------------------------------------------------------------- #
# seeded determinism across admission schedulers (FIFO vs SPF)
# --------------------------------------------------------------------- #

def test_spec_sampled_streams_scheduler_independent(models):
    """Two engines with identical seeds but different admission
    schedulers (FIFO vs shortest-prompt-first) admit requests in
    different orders into different slots — the per-request SAMPLED
    streams must still be identical, because keys fold from (request
    seed, position), never from slot index or dispatch pattern."""
    cfg, model, params = models["attn"]
    reqs = [([1, 2, 3, 4, 5, 6, 7], 6), ([8, 8], 6), ([5, 4, 3, 2], 6)]
    outs = {}
    for sched in ("fifo", "spf"):
        eng = ServeEngine(
            model, params, batch=1, max_seq=64, temperature=0.8,
            top_k=8, seed=3, decode_block=4,
            spec=SpecConfig(draft_tokens=3, ngram_table=64),
            admission=AdmissionConfig(queue_limit=8, scheduler=sched))
        ids = [eng.submit(p, max_new_tokens=n) for p, n in reqs]
        res = _by_id(eng.run())
        outs[sched] = [res[i].tokens for i in ids]
    assert outs["fifo"] == outs["spf"]
    # and both equal the non-speculative FIFO reference
    ref = ServeEngine(model, params, batch=1, max_seq=64,
                      temperature=0.8, top_k=8, seed=3, decode_block=4,
                      admission=AdmissionConfig(queue_limit=8))
    ids = [ref.submit(p, max_new_tokens=n) for p, n in reqs]
    res = _by_id(ref.run())
    assert outs["fifo"] == [res[i].tokens for i in ids]


# --------------------------------------------------------------------- #
# n-gram acceptance + draft-model leg
# --------------------------------------------------------------------- #

def test_ngram_acceptance_on_repetitive_stream(models):
    """A cyclic prompt seeds the per-slot n-gram table with the cycle;
    greedy continuations of reduced models are near-periodic, so the
    mean accepted length must beat the no-speculation floor of 1.0 —
    while the stream stays oracle-identical (the matrix test above
    already pins identity; this pins that speculation actually bites)."""
    cfg, model, params = models["attn"]
    eng = ServeEngine(model, params, batch=1, max_seq=128,
                      decode_block=8,
                      spec=SpecConfig(draft_tokens=3, ngram_table=128))
    eng.submit([1, 2, 3, 4] * 4, max_new_tokens=40)
    res = eng.run()
    assert res[0].status == "ok" and len(res[0].tokens) == 40
    rep = eng.spec_report()
    assert rep["enabled"] and rep["blocks"] > 0
    assert rep["mean_accepted_len"] > 1.0


def test_draft_model_self_draft_accepts_everything(models):
    """The target model drafting for itself proposes its own greedy
    continuations, so acceptance near-saturates (the draft leg's
    decode-step logits and the verify logits are the same math in
    different shapes — a numerical tie at the argmax can occasionally
    truncate a block) and the stream is identical to the
    non-speculative loop."""
    cfg, model, params = models["attn"]
    eng = ServeEngine(model, params, batch=1, max_seq=64,
                      decode_block=8, prefill_chunk=4,
                      spec=SpecConfig(draft_tokens=3, ngram_table=64,
                                      draft_model=model,
                                      draft_params=params))
    ref = ServeEngine(model, params, batch=1, max_seq=64,
                      decode_block=8, prefill_chunk=4)
    for e in (eng, ref):
        e.submit(PROMPTS[0], max_new_tokens=13)
    assert _tokens(eng.run()) == _tokens(ref.run())
    rep = eng.spec_report()
    assert rep["mean_accepted_len"] >= 3.0     # vs the 1.0 no-hit floor


def test_draft_model_random_weights_still_conformant(models):
    """An unrelated (randomly initialized) draft model mostly
    MIS-predicts — the rejected-tail rollback path runs constantly —
    yet the emitted streams must be untouched."""
    cfg, model, params = models["attn"]
    dcfg = dataclasses.replace(get_config("gptneox-1b").reduced(),
                               name="draft-tiny")
    dmodel = build_model(dcfg)
    dparams = dmodel.init(jax.random.PRNGKey(9))   # disagrees w/ target
    outs = []
    for spec in (SpecConfig(draft_tokens=3, ngram_table=64,
                            draft_model=dmodel, draft_params=dparams),
                 None):
        eng = ServeEngine(model, params, batch=2, max_seq=64,
                          decode_block=8, prefill_chunk=4, spec=spec)
        eng.submit(PROMPTS[0], max_new_tokens=12)
        eng.submit(PROMPTS[1], max_new_tokens=7)
        outs.append(_tokens(eng.run()))
    assert outs[0] == outs[1]


def test_spec_config_and_draft_validation(models):
    """Config/engine validation: speculation knobs and the draft-model
    restrictions fail loudly, not at trace time."""
    cfg, model, params = models["attn"]
    scfg, smodel, sparams = models["ssm"]
    with pytest.raises(ValueError, match="draft_tokens"):
        SpecConfig(draft_tokens=0)
    with pytest.raises(ValueError, match="go together"):
        SpecConfig(draft_model=model)
    with pytest.raises(ValueError, match="decoder-only attention"):
        ServeEngine(model, params, batch=1, max_seq=64,
                    spec=SpecConfig(draft_model=smodel,
                                    draft_params=sparams))
    vcfg = dataclasses.replace(get_config("gptneox-1b").reduced(),
                               name="draft-vocab", vocab_size=256)
    vmodel = build_model(vcfg)
    with pytest.raises(ValueError, match="vocab"):
        ServeEngine(model, params, batch=1, max_seq=64,
                    spec=SpecConfig(draft_model=vmodel,
                                    draft_params=vmodel.init(
                                        jax.random.PRNGKey(2))))
    from repro.launch.mesh import make_serving_mesh
    with pytest.raises(NotImplementedError, match="single-device"):
        ServeEngine(model, params, batch=1, max_seq=64,
                    mesh=make_serving_mesh((1,)),
                    spec=SpecConfig(draft_model=model,
                                    draft_params=params))


def test_spec_state_fields(models):
    """The speculation slot-state fields exist exactly when speculation
    is on (trace-safety: the fused loop's carry layout is decided at
    engine build, never data-dependent)."""
    cfg, model, params = models["attn"]
    spec = SpecConfig(draft_tokens=3, ngram_context=3, ngram_table=64)
    eng = ServeEngine(model, params, batch=2, max_seq=64, spec=spec)
    ref = ServeEngine(model, params, batch=2, max_seq=64)
    assert eng.state["spec_hist"].shape == (2, 3)
    assert eng.state["spec_ngram"].shape == (2, 64)
    assert eng.state["spec_accept"].shape == (2,)
    for f in ("spec_hist", "spec_ngram", "spec_accept", "spec_blocks"):
        assert f not in ref.state
    assert not ref.spec_report()["enabled"]


# --------------------------------------------------------------------- #
# sanitizers + mesh
# --------------------------------------------------------------------- #

@pytest.mark.slow
def test_sanitize_spec_clean():
    """The speculative serving path compiles each executable exactly
    once, runs the timed loop with zero implicit transfers, and its
    emitted streams match both a warmed re-run and the non-speculative
    engine."""
    rep = sanitize_spec()
    assert rep["compiled_exactly_once"], rep
    assert rep["zero_implicit_loop_transfers"], rep
    assert rep["tokens_match_warmup"], rep
    assert rep["tokens_match_nonspec"], rep
    assert rep["spec_report"]["blocks"] > 0


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CASES = os.path.join(REPO, "tests", "sharded_cases.py")


def _run_case(*names):
    """Run sharded conformance cases in a subprocess where XLA_FLAGS can
    still carve the host CPU into fake devices (same harness as
    test_serve_sharded)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, CASES, *names],
                          capture_output=True, text=True, env=env,
                          timeout=1800)
    assert proc.returncode == 0, (
        f"sharded spec case(s) {names} failed:\n--- stdout ---\n"
        f"{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    for name in names:
        assert f"CASE_OK {name}" in proc.stdout


@pytest.mark.slow
def test_spec_sharded_conformance():
    """Speculative decode on a (2,2) serving mesh stays bit-identical
    to the single-device non-speculative engine (greedy + sampled)."""
    _run_case("spec_matrix")
