"""AdamW: reference-math equivalence, factored second moment, clipping,
schedule shape."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, Schedule, adamw_init, adamw_update,
                         global_norm, opt_state_specs)
from jax.sharding import PartitionSpec as P


def _manual_adamw(p, g, m, v, step, cfg):
    lr = float(cfg.schedule(jnp.asarray(step)))
    gn = float(np.sqrt((np.asarray(g) ** 2).sum()))
    clip = min(1.0, cfg.clip_norm / max(gn, 1e-12))
    g = g * clip
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** step)
    vh = v / (1 - cfg.b2 ** step)
    upd = mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
    return p - lr * upd, m, v


def test_adamw_matches_reference(key):
    cfg = AdamWConfig(weight_decay=0.1, clip_norm=10.0)
    p = {"w": jax.random.normal(key, (4, 4))}
    g = {"w": jax.random.normal(jax.random.fold_in(key, 1), (4, 4))}
    state = adamw_init(cfg, p)
    new_p, new_state = adamw_update(cfg, p, g, state)
    want, m, v = _manual_adamw(np.asarray(p["w"]), np.asarray(g["w"]),
                               0.0, 0.0, 1, cfg)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, atol=1e-5)
    np.testing.assert_allclose(np.asarray(new_state["m"]["w"]), m,
                               atol=1e-6)


def test_clip_norm_applied(key):
    cfg = AdamWConfig(clip_norm=1e-3, weight_decay=0.0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    state = adamw_init(cfg, p)
    new_p, _ = adamw_update(cfg, p, g, state)
    # clipped grad norm 1e-3 => m = 0.1*g_clip tiny => update bounded
    assert float(jnp.abs(new_p["w"]).max()) < cfg.schedule.peak_lr * 1.1


def test_factored_v_memory_and_direction(key):
    cfg = AdamWConfig(factored_v=True, factored_min_dim=4)
    p = {"w": jax.random.normal(key, (128, 256))}
    state = adamw_init(cfg, p)
    assert set(state["v"]["w"].keys()) == {"row", "col"}
    assert state["v"]["w"]["row"].shape == (128,)
    assert state["v"]["w"]["col"].shape == (256,)
    g = {"w": jax.random.normal(jax.random.fold_in(key, 1), (128, 256))}
    new_p, new_state = adamw_update(cfg, p, g, state)
    # update must descend along -g on average
    dp = np.asarray(new_p["w"] - p["w"]).flatten()
    corr = np.dot(dp, -np.asarray(g["w"]).flatten())
    assert corr > 0


def test_bf16_m_state(key):
    cfg = AdamWConfig(m_dtype="bfloat16")
    p = {"w": jax.random.normal(key, (8, 8))}
    state = adamw_init(cfg, p)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jax.random.normal(key, (8, 8))}
    new_p, new_state = adamw_update(cfg, p, g, state)
    assert new_state["m"]["w"].dtype == jnp.bfloat16
    assert bool(jnp.isfinite(new_p["w"]).all())


def test_schedule_warmup_and_decay():
    s = Schedule(peak_lr=1.0, warmup_steps=10, decay_steps=110,
                 min_ratio=0.1)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(s(jnp.asarray(110))) == pytest.approx(0.1, abs=1e-3)
    assert float(s(jnp.asarray(60))) == pytest.approx(0.55, abs=0.01)


def test_opt_state_specs_mirror(key):
    cfg = AdamWConfig(factored_v=True, factored_min_dim=4)
    shapes = {"w": jax.ShapeDtypeStruct((128, 256), jnp.float32)}
    pspecs = {"w": P("model", "data")}
    ospecs = opt_state_specs(cfg, shapes, pspecs)
    assert ospecs["m"]["w"] == P("model", "data")
    assert ospecs["v"]["w"]["row"] == P("model")
    assert ospecs["v"]["w"]["col"] == P("data")
    assert ospecs["step"] == P()
