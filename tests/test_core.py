"""Core characterization layer: probes (smoke on CPU), roofline, energy,
autotune, timing, report."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GB203, GH100, HOST_CPU, TPU_V5E, build_report,
                        get_device_model, model_flops_dense, time_fn)
from repro.core.energy import ENERGY_PER_FLOP_PJ, estimate, matmul_energy
from repro.core.hlo_analysis import CollectiveStats, CompiledStats, \
    HloStructure


def _stats(flops=1e12, bytes_=1e9, coll=1e8):
    cs = CollectiveStats(total_bytes=coll)
    return CompiledStats(flops=flops, bytes_accessed=bytes_,
                         collectives=cs, structure=HloStructure())


def test_roofline_dominance():
    r = build_report("c", _stats(flops=1e15, bytes_=1.0, coll=1.0),
                     TPU_V5E, chips=256)
    assert r.dominant == "compute"
    r = build_report("m", _stats(flops=1.0, bytes_=1e12, coll=1.0),
                     TPU_V5E, chips=256)
    assert r.dominant == "memory"
    r = build_report("x", _stats(flops=1.0, bytes_=1.0, coll=1e12),
                     TPU_V5E, chips=256)
    assert r.dominant == "collective"


def test_roofline_terms_values():
    r = build_report("t", _stats(flops=197e12, bytes_=819e9, coll=200e9),
                     TPU_V5E, chips=1)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(1.0)


def test_mfu_bounded_when_flops_counted_right():
    """useful flops <= compiled flops => mfu <= roofline fraction <= 1."""
    model_fl = 6e9 * 1e6
    r = build_report("t", _stats(flops=model_fl / 256 * 1.2,
                                 bytes_=1e9, coll=1e8),
                     TPU_V5E, chips=256, model_flops=model_fl)
    assert 0 < r.mfu <= 1.0
    assert 0 < r.useful_ratio <= 1.0


def test_device_registry():
    assert get_device_model("tpu-v5e").peak_flops["bfloat16"] == 197e12
    assert GB203.peak_flops["float4_e2m1fn"] > GB203.peak_flops["float8_e4m3fn"]
    with pytest.raises(KeyError):
        get_device_model("nope")


def test_fp8_fallback_on_tpu():
    """v5e has no fp8 pipeline: peak falls back to bf16 (the paper's QMMA
    fallback story)."""
    assert TPU_V5E.peak_flops_for("float8_e4m3fn") == \
        TPU_V5E.peak_flops_for("bfloat16")


def test_energy_precision_staircase():
    """Paper Tab VI ordering: FP4 < FP6 < FP8 < BF16 energy at iso-work."""
    joules = {}
    for fmt in ("float4_e2m1fn", "float6_e2m3fn", "float8_e4m3fn",
                "bfloat16"):
        joules[fmt] = estimate(GB203, flops=1e12, dtype=fmt,
                               seconds=1.0).joules
    assert joules["float4_e2m1fn"] < joules["float6_e2m3fn"] \
        < joules["float8_e4m3fn"] < joules["bfloat16"]


def test_energy_tdp_clamp():
    e = estimate(GB203, flops=1e18, dtype="bfloat16", seconds=1e-3)
    assert e.total_watts <= GB203.peak_watts


def test_matmul_energy_grows_with_size():
    e1 = matmul_energy(TPU_V5E, 1024, 1024, 1024, "bfloat16")
    e2 = matmul_energy(TPU_V5E, 8192, 8192, 8192, "bfloat16")
    assert e2.joules > e1.joules * 100


def test_time_fn_measures():
    r = time_fn(lambda: jnp.sum(jnp.ones((256, 256))), iters=5, warmup=2)
    assert r.median_s > 0
    assert r.iters == 5


def test_autotune_block_pick():
    from repro.core.autotune import pick_matmul_block
    c = pick_matmul_block(TPU_V5E, 4096, 4096, 4096)
    assert c.bm % 128 == 0 and c.bn % 128 == 0 and c.bk % 128 == 0
    vmem = TPU_V5E.level("vmem").capacity_bytes
    assert c.vmem_bytes <= vmem


def test_probes_smoke():
    """Probe suite runs on CPU (methodology validation, tiny sizes)."""
    from repro.core.probes import compute, memory, precision
    import math
    r = compute.measure_latency("int32", chain=256, iters=3)
    # timer-overhead subtraction can clamp tiny chains to ~0 on a fast
    # host; finiteness + non-negativity is the CPU-smoke contract
    assert math.isfinite(r.true_ns) and r.true_ns >= 0
    assert math.isfinite(r.completion_ns)
    curve = memory.chase_curve(sizes=(4096, 65536), steps=2048, iters=3)
    assert len(curve) == 2 and curve[0].ns_per_load > 0
    sup = precision.support_matrix()
    names = {s.fmt for s in sup}
    assert "e4m3" in names and "e2m1" in names
