"""Attention: chunked (flash-equivalent) vs full oracle, windows, softcaps,
GQA, decode caches (ring buffers included)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


def _qkv(key, b=2, sq=64, skv=64, hq=4, hkv=2, d=16):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, sq, hq, d)),
            jax.random.normal(ks[1], (b, skv, hkv, d)),
            jax.random.normal(ks[2], (b, skv, hkv, d)))


@pytest.mark.parametrize("chunk", [8, 16, 64, 48])
def test_chunked_equals_full(key, chunk):
    q, k, v = _qkv(key)
    want = A.full_attention(q, k, v, causal=True)
    got = A.chunked_attention(q, k, v, causal=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


@pytest.mark.parametrize("window,softcap,causal", [
    (16, None, True), (None, 20.0, True), (8, 10.0, True),
    (None, None, False)])
def test_chunked_flags(key, window, softcap, causal):
    q, k, v = _qkv(key)
    want = A.full_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap)
    got = A.chunked_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


def test_gqa_equals_repeated_kv(key):
    """GQA must equal MHA with kv heads explicitly repeated."""
    q, k, v = _qkv(key, hq=4, hkv=2)
    want = A.full_attention(q, jnp.repeat(k, 2, axis=2),
                            jnp.repeat(v, 2, axis=2))
    got = A.full_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


def test_window_masks_old_positions(key):
    """With window=1 each query sees only itself."""
    q, k, v = _qkv(key, hq=2, hkv=2)
    got = A.full_attention(q, k, v, causal=True, window=1)
    # softmax over a single visible position => output == v at that pos
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(v.astype(got.dtype)), atol=1e-5)


def test_decode_attention_matches_full(key):
    b, s, hq, hkv, d = 2, 16, 4, 2, 8
    q, k, v = _qkv(key, b=b, sq=1, skv=s, hq=hq, hkv=hkv, d=d)
    slot_pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    pos = jnp.full((b,), s - 1, jnp.int32)
    got = A.decode_attention(q, k, v, slot_pos, pos)
    want = A.full_attention(q, k, v, causal=False)   # all slots visible
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


def test_decode_attention_heterogeneous_positions(key):
    """Rows at different positions mask independently."""
    b, s, h, d = 2, 8, 2, 4
    q, k, v = _qkv(key, b=b, sq=1, skv=s, hq=h, hkv=h, d=d)
    slot_pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    pos = jnp.asarray([3, 7], jnp.int32)
    got = A.decode_attention(q, k, v, slot_pos, pos)
    # row 0 must equal attention over slots 0..3 only
    want0 = A.full_attention(q[:1], k[:1, :4], v[:1, :4], causal=False)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want0[0]),
                               atol=1e-5)


def test_ring_cache_write_and_wrap(key):
    cache = A.init_kv_cache(batch=1, capacity=4, n_kv=1, head_dim=2,
                            dtype=jnp.float32)
    for pos in range(6):
        k = jnp.full((1, 1, 1, 2), float(pos))
        cache = A.cache_write_decode(cache, k, k, jnp.asarray([pos]))
    # capacity 4, positions 2..5 retained; slot = pos % 4
    assert sorted(np.asarray(cache["slot_pos"])[0].tolist()) == [2, 3, 4, 5]
    assert float(cache["k"][0, 5 % 4, 0, 0]) == 5.0


def test_prefill_ring_cache_keeps_last_window(key):
    k = jnp.arange(10, dtype=jnp.float32).reshape(1, 10, 1, 1)
    cache = A.init_kv_cache(1, 4, 1, 1, jnp.float32)
    cache = A.cache_write_prefill(cache, k, k)
    held = sorted(np.asarray(cache["slot_pos"])[0].tolist())
    assert held == [6, 7, 8, 9]
    # slot layout consistent with pos % capacity
    for slot in range(4):
        p = int(cache["slot_pos"][0, slot])
        assert p % 4 == slot
        assert float(cache["k"][0, slot, 0, 0]) == float(p)


def test_cache_capacity():
    assert A.cache_capacity(1000, None) == 1000
    assert A.cache_capacity(1000, 64) == 64
    assert A.cache_capacity(32, 64) == 32
