"""Per-arch smoke tests: every assigned architecture (reduced config) runs
one forward and one train step on CPU with correct shapes and no NaNs —
the deliverable (f) requirement."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config, smoke_shape
from repro.models import build_model, make_batch
from repro.optim import AdamWConfig, Schedule
from repro.train import make_train_step, train_state_init

ARCH_IDS = [c.name for c in ASSIGNED]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    shape = smoke_shape("train")
    params = model.init(key)
    batch = make_batch(cfg, shape, key)
    logits, aux = jax.jit(model.forward)(params, batch)
    trunk = shape.seq_len
    assert logits.shape == (shape.global_batch, trunk, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    for k, v in aux.items():
        assert bool(jnp.isfinite(v)), f"{arch}: non-finite {k}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch, key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    shape = smoke_shape("train")
    opt = AdamWConfig(schedule=Schedule(peak_lr=1e-3, warmup_steps=2,
                                        decay_steps=10))
    state = train_state_init(model, opt, key)
    step = jax.jit(make_train_step(model, opt))
    batch = make_batch(cfg, shape, key)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: NaN loss"
    assert bool(jnp.isfinite(metrics["grad_norm"])), f"{arch}: NaN grads"
    assert float(metrics["grad_norm"]) > 0.0, f"{arch}: zero grads"
    for leaf in jax.tree.leaves(state["params"]):
        assert bool(jnp.isfinite(leaf).all()), f"{arch}: NaN params"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch, key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    shape = smoke_shape("prefill")
    params = model.init(key)
    batch = make_batch(cfg, shape, key)
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, shape.seq_len * 2))(params, batch)
    assert logits.shape == (shape.global_batch, cfg.vocab_size)
    tok = jnp.zeros((shape.global_batch,), jnp.int32)
    pos = jnp.full((shape.global_batch,), shape.seq_len, jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, tok, pos)
    assert logits2.shape == (shape.global_batch, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_param_counts_match_published():
    """Full configs must land near the published parameter counts."""
    expected = {
        "mamba2-2.7b": 2.7e9, "qwen2.5-3b": 3.1e9, "gemma2-2b": 2.6e9,
        "llama3.2-3b": 3.2e9, "gemma-2b": 2.5e9, "jamba-v0.1-52b": 52e9,
        "kimi-k2-1t-a32b": 1.04e12, "llama4-maverick-400b-a17b": 400e9,
        "internvl2-2b": 1.9e9, "seamless-m4t-medium": 0.9e9,
    }
    for cfg in ASSIGNED:
        n = cfg.param_count()
        want = expected[cfg.name]
        assert abs(n - want) / want < 0.10, (cfg.name, n, want)
