"""Checkpointing: roundtrip, atomicity, async, GC, resume, elastic specs."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import Checkpointer, load_tree, save_tree


def _tree(key):
    ks = jax.random.split(key, 3)
    return {
        "params": {"w": jax.random.normal(ks[0], (8, 8)),
                   "b": jax.random.normal(ks[1], (8,), jnp.bfloat16)},
        "opt": {"m": jax.random.normal(ks[2], (8, 8)),
                "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path, key):
    tree = _tree(key)
    path = str(tmp_path / "step_1")
    specs = jax.tree.map(lambda x: P(), tree)
    save_tree(path, tree, 1, specs)
    loaded, step, specs2 = load_tree(path, tree)
    assert step == 1
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype
    assert specs2 is not None and len(specs2) == 4


def test_checkpointer_latest_and_gc(tmp_path, key):
    tree = _tree(key)
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    for step in (10, 20, 30):
        ck.save(tree, step)
    assert ck.latest_step() == 30
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000020", "step_00000030"]


def test_async_save_and_restore(tmp_path, key):
    tree = _tree(key)
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(tree, 5, block=False)
    ck.wait()
    restored = ck.restore_latest(like=tree)
    assert restored is not None
    loaded, step = restored
    assert step == 5
    np.testing.assert_array_equal(np.asarray(loaded["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_atomicity_no_partial_dirs(tmp_path, key):
    """A completed save leaves no .tmp turds."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(_tree(key), 1)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_restore_latest_none_when_empty(tmp_path, key):
    ck = Checkpointer(str(tmp_path))
    assert ck.restore_latest(like=_tree(key)) is None


def test_train_loop_resume(tmp_path, key):
    """Kill-and-restart: the loop resumes from the latest checkpoint and
    reaches the same final state as an uninterrupted run."""
    import dataclasses
    from repro.configs import ASSIGNED, smoke_shape
    from repro.data import make_stream
    from repro.models import build_model
    from repro.optim import AdamWConfig
    from repro.train import TrainLoopConfig, make_train_step, \
        run_train_loop, train_state_init

    cfg = dataclasses.replace(ASSIGNED[1].reduced(), n_layers=1)
    model = build_model(cfg)
    opt = AdamWConfig()
    stream = make_stream(cfg, smoke_shape("train"))
    step = jax.jit(make_train_step(model, opt))

    # uninterrupted 8 steps
    s_ref = train_state_init(model, opt, key)
    s_ref, _ = run_train_loop(step, s_ref, stream,
                              TrainLoopConfig(total_steps=8, log_every=100))

    # interrupted: 4 steps + checkpoint, then "restart" resumes 4..8
    ckdir = str(tmp_path / "ck")
    s1 = train_state_init(model, opt, key)
    run_train_loop(step, s1, stream,
                   TrainLoopConfig(total_steps=4, checkpoint_every=4,
                                   checkpoint_dir=ckdir, log_every=100,
                                   async_checkpoint=False))
    s2 = train_state_init(model, opt, key)     # fresh init, must be replaced
    s2, _ = run_train_loop(step, s2, stream,
                           TrainLoopConfig(total_steps=8,
                                           checkpoint_every=100,
                                           checkpoint_dir=ckdir,
                                           log_every=100,
                                           async_checkpoint=False))
    for a, b in zip(jax.tree.leaves(s_ref["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_remesh_spec_degradation(key):
    """remesh drops spec axes that no longer divide (elastic restart)."""
    from repro.distributed import remesh
    from repro.launch.mesh import make_smoke_mesh
    mesh = make_smoke_mesh()     # 1 device: everything degrades to replicated
    tree = {"w": jax.random.normal(key, (8, 6))}
    specs = {"w": P("model", ("pod", "data"))}
    out = remesh(tree, specs, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
