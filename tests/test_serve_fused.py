"""Fused device-resident serving hot loop: decode_loop(k=N) equivalence
vs N per-step dispatches (greedy AND sampled), chunked pooled prefill vs
the width-1 prefill oracle, mid-loop slot finishes, ring-wrap
boundaries, and run() truncation flushing."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("gptneox-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _tokens(results):
    return [r.tokens for r in sorted(results, key=lambda r: r.request_id)]


@pytest.mark.parametrize("kv_format", [None, "float8_e4m3fn",
                                       "float4_e2m1fn"])
def test_fused_loop_matches_per_step(small_model, kv_format):
    """Greedy decode_loop(k=N) must be token-identical to N step() calls,
    including a slot that finishes mid-loop (shorter second request)."""
    cfg, model, params = small_model
    outs = []
    for block in (7, 1):          # fused K=7 vs per-step
        eng = ServeEngine(model, params, batch=2, max_seq=64,
                          kv_format=kv_format, decode_block=block,
                          prefill_chunk=4)
        eng.submit([1, 2, 3, 4, 5, 6, 7], max_new_tokens=12)
        eng.submit([9, 8, 7], max_new_tokens=4)   # finishes mid-K
        outs.append(_tokens(eng.run()))
    assert outs[0] == outs[1]
    assert [len(t) for t in outs[0]] == [12, 4]


def test_fused_loop_ring_wrap(small_model):
    """Decode far past a sliding window so local-layer ring buffers wrap
    inside a fused block; fused and per-step must stay identical."""
    cfg = get_config("gemma2-2b").reduced()      # window 32 local layers
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    outs = []
    for block in (8, 1):
        eng = ServeEngine(model, params, batch=1, max_seq=64,
                          decode_block=block, prefill_chunk=8)
        eng.submit(list(range(1, 11)), max_new_tokens=45)  # 10+45 > 32
        outs.append(_tokens(eng.run()))
    assert outs[0] == outs[1]
    assert len(outs[0][0]) == 45


def test_fused_loop_sampled_matches_per_step(small_model):
    """Per-slot key folding (request id, position) makes even SAMPLED
    streams identical between the fused loop and per-step dispatches —
    and independent of batch composition."""
    cfg, model, params = small_model
    a = ServeEngine(model, params, batch=2, max_seq=64, temperature=0.8,
                    top_k=8, seed=3, decode_block=5)
    b = ServeEngine(model, params, batch=1, max_seq=64, temperature=0.8,
                    top_k=8, seed=3, decode_block=1)
    a.submit([4, 5, 6], max_new_tokens=7)
    a.submit([9, 9], max_new_tokens=3)           # batch companion
    b.submit([4, 5, 6], max_new_tokens=7)
    assert _tokens(a.run())[0] == _tokens(b.run())[0]


def test_chunked_prefill_matches_manual_decode(small_model):
    """Chunked pooled prefill (prompt split over several jitted chunk
    dispatches, padded tail included) must reproduce the full-prompt
    prefill + decode oracle."""
    cfg, model, params = small_model
    prompt = list(range(2, 22))                  # 20 tokens, chunk 8 -> 3
    eng = ServeEngine(model, params, batch=2, max_seq=64,
                      decode_block=4, prefill_chunk=8)
    assert eng._chunked
    eng.submit(prompt, max_new_tokens=5)
    got = eng.run()[0].tokens

    logits, cache = model.prefill(params, {"tokens": jnp.asarray([prompt])},
                                  64)
    want = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(4):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([want[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        want.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert got == want


def test_chunked_prefill_window_wrap_matches_oracle():
    """A prompt LONGER than a sliding window (gemma2 reduced: window 32,
    ring capacity 32) must still match the full-prefill oracle: chunk
    writes wrapping the ring must not evict positions that earlier
    queries of the same chunk still see (regression — the chunk used to
    write before attending)."""
    cfg = get_config("gemma2-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    prompt = [int(1 + (i * 7) % 200) for i in range(40)]   # 40 > window
    eng = ServeEngine(model, params, batch=1, max_seq=64,
                      decode_block=4, prefill_chunk=8)
    assert eng._chunked
    eng.submit(prompt, max_new_tokens=6)
    got = eng.run()[0].tokens

    logits, cache = model.prefill(params, {"tokens": jnp.asarray([prompt])},
                                  64)
    want = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(5):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([want[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        want.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert got == want


def test_chunked_prefill_slot_reuse_isolation(small_model):
    """A slot's previous (longer) tenant must be invisible after
    readmission: clear_slot resets the ring bookkeeping, so a short
    prompt admitted into a dirty slot matches a fresh engine."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, batch=1, max_seq=64,
                      decode_block=4, prefill_chunk=8)
    eng.submit(list(range(1, 30)), max_new_tokens=6)  # long first tenant
    eng.submit([3, 1, 4, 1, 5], max_new_tokens=6)     # short, reuses slot
    got = _tokens(eng.run())[1]

    fresh = ServeEngine(model, params, batch=1, max_seq=64,
                        decode_block=4, prefill_chunk=8)
    fresh.submit([3, 1, 4, 1, 5], max_new_tokens=6)
    assert got == _tokens(fresh.run())[0]


def test_run_flushes_truncated_results(small_model):
    """Hitting the run() step budget must flush in-flight requests as
    truncated partials instead of silently dropping them."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, batch=2, max_seq=64, decode_block=4)
    done = eng.submit([1, 2, 3], max_new_tokens=4)
    cut = eng.submit([4, 5, 6], max_new_tokens=50)
    results = {r.request_id: r for r in eng.run(max_steps=8)}
    assert not results[done].truncated
    assert len(results[done].tokens) == 4
    assert results[cut].truncated
    assert 0 < len(results[cut].tokens) < 50
    # a later run() must not advance the flushed slot
    n = len(results[cut].tokens)
    eng.run(max_steps=4)
    assert len(results[cut].tokens) == n


def test_engine_reset_reuses_compilation(small_model):
    """reset() clears serving state but keeps compiled loops; results
    repeat exactly."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, batch=2, max_seq=64, decode_block=4,
                      prefill_chunk=4)
    eng.submit([5, 6, 7], max_new_tokens=6)
    first = _tokens(eng.run())
    loops_before = set(eng._loops)
    eng.reset()
    eng.submit([5, 6, 7], max_new_tokens=6)
    assert _tokens(eng.run()) == first
    assert set(eng._loops) == loops_before


def test_max_new_tokens_one(small_model):
    """max_new_tokens=1 yields exactly the admission token (the old
    per-step engine over-generated a second token)."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, batch=1, max_seq=64)
    eng.submit([1, 2, 3], max_new_tokens=1)
    (res,) = eng.run()
    assert len(res.tokens) == 1 and not res.truncated


def test_state_lives_on_device(small_model):
    """Slot state is device arrays (the tentpole's point): one dispatch
    advances K tokens with no per-token host bookkeeping."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, batch=2, max_seq=64, decode_block=8)
    for name in ("pos", "remaining", "last_token", "active", "seed"):
        assert isinstance(eng.state[name], jax.Array)
    eng.submit([1, 2, 3, 4], max_new_tokens=8)
    eng.decode_loop()                            # one fused dispatch
    assert len(eng.results) == 1                 # 1 admit + 8 fused >= 8
    assert len(eng.results[0].tokens) == 8
