"""Unit tests for the primitive layer library."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def test_rms_norm_unit_variance(key):
    x = jax.random.normal(key, (4, 64)) * 7.0 + 3.0
    w = jnp.ones((64,))
    y = L.rms_norm(w, x)
    ms = jnp.mean(jnp.square(y), axis=-1)
    np.testing.assert_allclose(np.asarray(ms), 1.0, rtol=0.05)


def test_rms_norm_gemma_style_matches_plus_one(key):
    x = jax.random.normal(key, (2, 32))
    w = jax.random.normal(key, (32,)) * 0.1
    a = L.rms_norm(w, x, gemma_style=True)
    b = L.rms_norm(1.0 + w, x, gemma_style=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_rope_preserves_norm_and_relative_phase(key):
    x = jax.random.normal(key, (1, 8, 2, 64))
    pos = jnp.arange(8)
    y = L.apply_rope(x, pos, theta=10000.0)
    # rotation preserves the per-pair norm
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # dot(q_i, k_j) depends only on i - j
    q = L.apply_rope(x, pos, 10000.0)
    k = L.apply_rope(x, pos, 10000.0)
    d01 = jnp.einsum("d,d->", q[0, 3, 0], k[0, 2, 0])
    q2 = L.apply_rope(x, pos + 11, 10000.0)
    k2 = L.apply_rope(x, pos + 11, 10000.0)
    d01_shift = jnp.einsum("d,d->", q2[0, 3, 0], k2[0, 2, 0])
    np.testing.assert_allclose(float(d01), float(d01_shift), rtol=1e-4)


def test_rope_position_zero_is_identity(key):
    x = jax.random.normal(key, (1, 1, 1, 32))
    y = L.apply_rope(x, jnp.zeros((1,), jnp.int32), 10000.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


@pytest.mark.parametrize("variant", ["swiglu", "geglu", "gelu"])
def test_mlp_variants(key, variant):
    p = L.init_mlp(key, 32, 64, variant, jnp.float32)
    x = jax.random.normal(key, (2, 5, 32))
    y = L.apply_mlp(p, x, variant)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    n_weights = 3 if variant in ("swiglu", "geglu") else 2
    assert len(p) == n_weights


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = L.softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    # near-linear for small inputs
    np.testing.assert_allclose(float(L.softcap(jnp.asarray(0.1), 30.0)),
                               0.1, rtol=1e-3)
    assert L.softcap(x, None) is x


def test_causal_conv1d_matches_numpy(key):
    x = jax.random.normal(key, (2, 10, 3))
    w = jax.random.normal(key, (3, 4))
    b = jax.random.normal(key, (3,))
    y = L.causal_conv1d(x, w, b)
    xp = np.pad(np.asarray(x), ((0, 0), (3, 0), (0, 0)))
    want = np.zeros((2, 10, 3))
    for t in range(10):
        for c in range(3):
            want[:, t, c] = (xp[:, t:t + 4, c] * np.asarray(w)[c]).sum(-1) \
                + float(b[c])
    np.testing.assert_allclose(np.asarray(y), want, atol=1e-5)


def test_embed_unembed_tied_shapes(key):
    w = jax.random.normal(key, (100, 16))
    tok = jnp.asarray([[1, 2, 3]])
    x = L.embed(w, tok)
    assert x.shape == (1, 3, 16)
    logits = L.unembed(w.T, x, softcap=30.0)
    assert logits.shape == (1, 3, 100)
    assert float(jnp.max(jnp.abs(logits))) <= 30.0
