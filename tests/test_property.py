"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev extra: pip install repro[dev]")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.attention import chunked_attention, full_attention
from repro.models.ssm import ssd_chunked, ssd_reference
from repro.data.packing import pack_documents
from repro.core import TPU_V5E, build_report
from repro.core.hlo_analysis import (CollectiveStats, CompiledStats,
                                     HloStructure)

_settings = dict(max_examples=12, deadline=None)


@given(chunk=st.integers(1, 48), seed=st.integers(0, 10))
@settings(**_settings)
def test_online_softmax_chunk_invariance(chunk, seed):
    """Chunked attention is invariant to the chunk size (online-softmax
    associativity) — the core flash-attention correctness property."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 8))
    k = jax.random.normal(ks[1], (1, 32, 2, 8))
    v = jax.random.normal(ks[2], (1, 32, 2, 8))
    got = chunked_attention(q, k, v, causal=True, chunk=chunk)
    want = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


@given(chunk=st.sampled_from([4, 8, 16, 32]), seed=st.integers(0, 10))
@settings(**_settings)
def test_ssd_chunk_invariance(chunk, seed):
    """SSD chunked form equals the sequential recurrence for any chunk."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    s = 32
    x = jax.random.normal(ks[0], (1, s, 2, 4)) * 0.5
    dt_a = -jnp.abs(jax.random.normal(ks[1], (1, s, 2))) * 0.3
    b = jax.random.normal(ks[2], (1, s, 4)) * 0.5
    c = jax.random.normal(ks[3], (1, s, 4)) * 0.5
    y1, s1 = ssd_chunked(x, dt_a, b, c, chunk)
    y2, s2 = ssd_reference(x, dt_a, b, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=3e-4)


@given(st.lists(st.integers(1, 20), min_size=1, max_size=12),
       st.integers(8, 32))
@settings(**_settings)
def test_packing_preserves_tokens(doc_lens, seq_len):
    docs = [np.arange(1, n + 1) + 100 * i for i, n in enumerate(doc_lens)]
    tokens, mask, seg = pack_documents(docs, seq_len)
    got = sorted(int(t) for t in tokens.flatten() if t != 0)
    want = sorted(int(x) for d in docs for x in d)
    assert got == want
    # masked fraction sane: first token of each doc chunk is masked out
    assert mask.sum() <= tokens.astype(bool).sum()


@given(st.floats(1e6, 1e15), st.floats(1e3, 1e12), st.floats(0, 1e12))
@settings(**_settings)
def test_roofline_bound_is_max_term(fl, by, co):
    cs = CompiledStats(flops=fl, bytes_accessed=by,
                       collectives=CollectiveStats(total_bytes=co),
                       structure=HloStructure())
    r = build_report("x", cs, TPU_V5E, chips=16)
    assert r.bound_s == pytest.approx(
        max(r.compute_s, r.memory_s, r.collective_s))
    assert r.terms()[r.dominant] == pytest.approx(r.bound_s)


@given(st.integers(0, 2**31 - 1))
@settings(**_settings)
def test_quantize_blockwise_scale_covers_range(seed):
    """No quantized value overflows its format's max after block scaling."""
    from repro.serve.quant import LOW_PRECISION_FORMATS, quantize_blockwise
    w = jax.random.normal(jax.random.PRNGKey(seed), (4, 64)) * 100
    for fmt, (dtype, fmax, _) in LOW_PRECISION_FORMATS.items():
        q, s = quantize_blockwise(w, fmt)
        assert bool(jnp.isfinite(q.astype(jnp.float32)).all()), fmt
        assert float(jnp.abs(q.astype(jnp.float32)).max()) <= fmax
