"""Quantized KV-cache decode path + the quantizer bugs that blocked it:
e8m0 1-byte scale codec, trace-safe sub-byte rounding, cache round
trips, the flash_decode dequant-in-VMEM leg, and engine plumbing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels as K
from repro import compat, lowbits
from repro.configs import get_config
from repro.models import attention as A
from repro.models import build_model
from repro.serve import ServeEngine
from repro.serve import quant as Q

KV_FORMATS = ("float8_e4m3fn", "float4_e2m1fn", "float6_e2m3fn")


# --------------------------------------------------------------------- #
# e8m0 scale codec (lowbits)
# --------------------------------------------------------------------- #

def test_e8m0_round_trip_all_codes():
    codes = np.arange(255, dtype=np.uint8)           # 255 = NaN, unused
    scales = lowbits.e8m0_decode(codes)
    assert np.array_equal(lowbits.e8m0_encode(scales), codes)


def test_e8m0_encode_clamps():
    s = np.asarray([0.0, 1e-45, 3.4e38], np.float32)
    codes = lowbits.e8m0_encode(s)
    assert codes[0] == 0 and codes[1] == 0           # floor: 2^-127
    assert codes[2] == 254                           # ceil: 2^127


def test_e8m0_scale_code_tiny_absmax_representable():
    """Satellite regression: a tiny absmax used to produce exponents no
    e8m0 byte can hold; now every emitted scale is in [2^-127, 2^127]."""
    absmax = np.asarray([0.0, 1e-38, 1e-30, 6.0, 3e38], np.float32)
    for fmt_max in (6.0, 448.0, 57344.0):
        codes = lowbits.e8m0_scale_code(absmax, fmt_max)
        scales = lowbits.e8m0_decode(codes)
        assert np.all(scales >= np.exp2(np.float32(-127)))
        assert np.all(scales <= np.exp2(np.float32(127)))
        # round trip through the byte store is lossless
        assert np.array_equal(lowbits.e8m0_encode(scales), codes)


def test_quant_scale_rule_matches_codec():
    """serve.quant._e8m0_scale must equal decode(scale_code(...)) — the
    quantizer's rule and the 1-byte store cannot drift apart."""
    absmax = jnp.asarray([1e-33, 0.3, 1.0, 6.0, 100.0], jnp.float32)
    got = Q._e8m0_scale(absmax, 6.0)
    want = lowbits.e8m0_decode(lowbits.e8m0_scale_code(absmax, 6.0))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # still the covering property: absmax/scale <= fmt_max
    assert np.all(np.asarray(absmax) / np.asarray(got) <= 6.0 * (1 + 1e-6))


def test_e8m0_trace_safe():
    f = jax.jit(lambda s: lowbits.e8m0_decode(lowbits.e8m0_encode(s)))
    s = jnp.exp2(jnp.arange(-10.0, 11.0))
    np.testing.assert_array_equal(np.asarray(f(s)), np.asarray(s))


# --------------------------------------------------------------------- #
# trace-safe rounding / encoding (lowbits arithmetic twins of ml_dtypes)
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("fmt", ["float4_e2m1fn", "float6_e2m3fn",
                                 "float6_e3m2fn"])
def test_quantize_values_matches_ml_dtypes(fmt):
    spec = lowbits.packed_spec(fmt)
    rng = np.random.default_rng(0)
    v = (rng.standard_normal(4096)
         * rng.choice([1e-3, 0.1, 1.0, 8.0], 4096)).astype(np.float32)
    edge = np.asarray([0.0, -0.0, spec.max_finite, -spec.max_finite,
                       1e30, -1e30, 2.0 ** (1 - spec.bias) / 2,
                       2.0 ** (1 - spec.bias)], np.float32)
    v = np.concatenate([v, edge])
    want = v.astype(spec.code_dtype).astype(np.float32)
    np.testing.assert_array_equal(lowbits.quantize_values(v, fmt), want)
    got_jit = jax.jit(lambda x: lowbits.quantize_values(x, fmt))(
        jnp.asarray(v))
    np.testing.assert_array_equal(np.asarray(got_jit), want)


@pytest.mark.parametrize("fmt", ["float4_e2m1fn", "float6_e2m3fn",
                                 "float6_e3m2fn"])
def test_encode_codes_bit_exact_all_codes(fmt):
    spec = lowbits.packed_spec(fmt)
    codes = np.arange(1 << spec.bits, dtype=np.int32)
    vals = lowbits.decode(codes, fmt)
    assert np.array_equal(lowbits.encode_codes(vals, fmt), codes)
    jit_codes = jax.jit(lambda x: lowbits.encode_codes(x, fmt))(
        jnp.asarray(vals))
    assert np.array_equal(np.asarray(jit_codes), codes)


@pytest.mark.parametrize("fmt", ["float4_e2m1fn", "float6_e2m3fn"])
def test_pack_codes_matches_host_pack(fmt):
    spec = lowbits.packed_spec(fmt)
    rng = np.random.default_rng(1)
    vals = lowbits.decode(
        rng.integers(0, 1 << spec.bits, (3, 16)).astype(np.int32), fmt
    ).astype(np.float32)
    want = lowbits.pack(vals, fmt)
    codes = lowbits.encode_codes(vals, fmt)
    assert np.array_equal(lowbits.pack_codes(codes, fmt), want)
    got_jit = jax.jit(lambda x: lowbits.pack_codes(
        lowbits.encode_codes(x, fmt), fmt))(jnp.asarray(vals))
    assert np.array_equal(np.asarray(got_jit), want)


def test_pack_codes_rejects_odd_tail():
    with pytest.raises(ValueError):
        lowbits.pack_codes(np.zeros((3,), np.int32), "float4_e2m1fn")


# --------------------------------------------------------------------- #
# quantize_blockwise trace-safety (satellite regression: the fp6 host
# rounding path crashed under jit/vmap via np.asarray on tracers)
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("fmt", ["float6_e2m3fn", "float6_e3m2fn",
                                 "float4_e2m1fn"])
def test_quantize_blockwise_jits_and_vmaps(key, fmt):
    w = jax.random.normal(key, (4, 64))
    q0, s0 = Q.quantize_blockwise(w, fmt)
    qj, sj = jax.jit(lambda x: Q.quantize_blockwise(x, fmt))(w)
    np.testing.assert_array_equal(np.asarray(q0, np.float32),
                                  np.asarray(qj, np.float32))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(sj))
    qv, sv = jax.vmap(lambda x: Q.quantize_blockwise(x, fmt))(w[:, None])
    np.testing.assert_array_equal(np.asarray(qv[:, 0], np.float32),
                                  np.asarray(q0, np.float32))


# --------------------------------------------------------------------- #
# format-table staleness (satellite regression: module-level lru_cache
# survived registry changes)
# --------------------------------------------------------------------- #

def test_format_table_tracks_registry(monkeypatch):
    full = compat.dtype_registry()
    assert "float6_e2m3fn" in Q.LOW_PRECISION_FORMATS
    shrunk = {k: v for k, v in full.items() if k != "float6_e2m3fn"}
    monkeypatch.setattr(compat, "dtype_registry", lambda: shrunk)
    assert "float6_e2m3fn" not in Q.LOW_PRECISION_FORMATS
    assert "float8_e4m3fn" in Q.LOW_PRECISION_FORMATS
    monkeypatch.undo()
    assert "float6_e2m3fn" in Q.LOW_PRECISION_FORMATS
    Q.invalidate_format_table()                      # explicit hook works
    assert "float6_e2m3fn" in Q.LOW_PRECISION_FORMATS


# --------------------------------------------------------------------- #
# packed e8m0 scale store in the weight quantizer
# --------------------------------------------------------------------- #

def test_quantize_tree_stores_byte_scales(key):
    params = {"w1": jax.random.normal(key, (64, 64))}
    store, stats = Q.quantize_tree(params, "float4_e2m1fn", packed=True)
    leaf = store["w1"]
    assert leaf["scales"].dtype == jnp.uint8
    assert leaf["scale_fmt"] == "e8m0"
    # 0.5 B/elem codes + 1 B per 32-block scale
    assert leaf["q"].nbytes == 64 * 64 // 2
    assert leaf["scales"].nbytes == 64 * (64 // Q.BLOCK)
    # dequant matches the fp32-scale reference exactly (scales are
    # powers of two, losslessly byte-coded)
    q, s = Q.quantize_blockwise(params["w1"], "float4_e2m1fn")
    want = Q.dequantize_blockwise(q, s, jnp.float32)
    got = Q.dequantize_tree(store, jnp.float32)["w1"]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_params_counts_byte_scales(key):
    params = {"w1": jax.random.normal(key, (64, 64))}
    _, stats = Q.quantize_params(params, "float4_e2m1fn")
    want = int(64 * 64 * 0.5) + 64 * (64 // Q.BLOCK)
    assert stats["quantized_bytes"] == want


# --------------------------------------------------------------------- #
# quantized KV cache (models.attention)
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("fmt", KV_FORMATS)
def test_kv_quantize_round_trip_error(key, fmt):
    x = jax.random.normal(key, (2, 5, 3, 32))
    stored, scales = A.quantize_kv(x, fmt)
    back = A.dequantize_kv(stored, scales, fmt, 32)
    err = float(jnp.max(jnp.abs(back - x)))
    spec = compat.dtype_spec(fmt)
    # blockwise e8m0 scaling bounds the relative step size
    tol = {"float8_e4m3fn": 0.07, "float6_e2m3fn": 0.07,
           "float4_e2m1fn": 0.3}[fmt]
    assert err <= tol * float(jnp.max(jnp.abs(x)))
    if spec.packed is not None:
        assert stored.dtype == jnp.uint8
        assert stored.shape[-1] == 32 * spec.packed.bits // 8
    assert scales.dtype == jnp.uint8


@pytest.mark.parametrize("fmt", ["float8_e4m3fn", "float4_e2m1fn"])
def test_cache_write_decode_quantized_matches_bulk(key, fmt):
    """Per-token decode writes land the same stored bytes as one
    prefill bulk write of the same values."""
    b, cap, h, d = 2, 8, 2, 16
    ks = jax.random.split(key, 2)
    k = jax.random.normal(ks[0], (b, cap, h, d))
    v = jax.random.normal(ks[1], (b, cap, h, d))
    bulk = A.cache_write_prefill(
        A.init_kv_cache(b, cap, h, d, jnp.float32, kv_format=fmt),
        k, v, kv_format=fmt)
    step = A.init_kv_cache(b, cap, h, d, jnp.float32, kv_format=fmt)
    write = jax.jit(lambda c, kk, vv, p: A.cache_write_decode(
        c, kk, vv, p, kv_format=fmt))
    for p in range(cap):
        pos = jnp.full((b,), p, jnp.int32)
        step = write(step, k[:, p:p + 1], v[:, p:p + 1], pos)
    for name in ("k_q", "k_s", "v_q", "v_s", "slot_pos"):
        np.testing.assert_array_equal(np.asarray(step[name]),
                                      np.asarray(bulk[name]), err_msg=name)


@pytest.mark.parametrize("fmt", ["float8_e4m3fn", "float4_e2m1fn"])
def test_quantized_decode_matches_quantize_then_dense(key, fmt):
    """decode_attention over the quantized cache == decode_attention
    over the explicitly dequantized K/V (the quantize-then-dense
    reference), and tracks the unquantized oracle within tolerance."""
    b, S, hq, hkv, d = 2, 64, 4, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d))
    kd = jax.random.normal(ks[1], (b, S, hkv, d))
    vd = jax.random.normal(ks[2], (b, S, hkv, d))
    cache = A.cache_write_prefill(
        A.init_kv_cache(b, S, hkv, d, jnp.float32, kv_format=fmt),
        kd, vd, kv_format=fmt)
    pos = jnp.full((b,), S - 1, jnp.int32)
    kc, vc = A.cache_kv(cache, fmt, d)
    got = A.decode_attention(q, kc, vc, cache["slot_pos"], pos)
    # reference: quantize-then-dense by hand
    k_ref = A.dequantize_kv(*A.quantize_kv(kd, fmt), fmt, d)
    v_ref = A.dequantize_kv(*A.quantize_kv(vd, fmt), fmt, d)
    want = A.decode_attention(q, k_ref, v_ref, cache["slot_pos"], pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6)
    dense = A.decode_attention(q, kd, vd, cache["slot_pos"], pos)
    tol = 0.1 if fmt == "float8_e4m3fn" else 0.6
    assert float(jnp.max(jnp.abs(got - dense))) < tol


# --------------------------------------------------------------------- #
# flash_decode quantized leg (dequant-in-VMEM) vs the oracle
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("fmt", ["float8_e4m3fn", "float4_e2m1fn"])
@pytest.mark.parametrize("S,bk,window,softcap", [
    (128, 64, None, None),
    (200, 128, 40, None),         # padded tail + window
    (96, 64, None, 15.0),         # padded tail + softcap
])
def test_flash_decode_quant_matches_oracle(key, fmt, S, bk, window,
                                           softcap):
    b, hq, hkv, d = 2, 4, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d))
    kd = jax.random.normal(ks[1], (b, S, hkv, d))
    vd = jax.random.normal(ks[2], (b, S, hkv, d))
    cache = A.cache_write_prefill(
        A.init_kv_cache(b, S, hkv, d, jnp.float32, kv_format=fmt),
        kd, vd, kv_format=fmt)
    pos = jnp.asarray([S - 1, S // 2], jnp.int32)
    got = K.flash_decode_quant(q, cache, pos, fmt=fmt, window=window,
                               softcap=softcap, bk=bk)
    kc, vc = A.cache_kv(cache, fmt, d)
    want = A.decode_attention(q, kc, vc, cache["slot_pos"], pos,
                              window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


def test_flash_decode_quant_ring_wrap(key):
    """Quantized leg over a wrapped ring cache (decode writes past the
    capacity), vs the dequantized oracle."""
    fmt = "float4_e2m1fn"
    b, cap, h, d = 1, 32, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    cache = A.init_kv_cache(b, cap, h, d, jnp.float32, kv_format=fmt)
    for p in range(40):                               # wraps past 32
        kv = jax.random.normal(jax.random.fold_in(ks[1], p), (b, 1, h, d))
        vv = jax.random.normal(jax.random.fold_in(ks[2], p), (b, 1, h, d))
        cache = A.cache_write_decode(cache, kv, vv,
                                     jnp.full((b,), p, jnp.int32),
                                     kv_format=fmt)
    pos = jnp.full((b,), 39, jnp.int32)
    got = K.flash_decode_quant(q, cache, pos, fmt=fmt, window=20, bk=16)
    kc, vc = A.cache_kv(cache, fmt, d)
    want = A.decode_attention(q, kc, vc, cache["slot_pos"], pos, window=20)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


# --------------------------------------------------------------------- #
# model + engine plumbing
# --------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("gptneox-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("fmt", ["float8_e4m3fn", "float4_e2m1fn"])
def test_model_decode_quantized_kv_tracks_dense(small_model, fmt):
    """Full decode steps with kv_format match the dense-cache model to
    quantization tolerance (greedy path stays usable)."""
    cfg, model, params = small_model
    qmodel = build_model(dataclasses.replace(cfg, kv_format=fmt))
    batch = {"tokens": jnp.asarray([[5, 7, 9, 11]], jnp.int32)}
    lg_d, cache_d = model.prefill(params, batch, 32)
    lg_q, cache_q = qmodel.prefill(params, batch, 32)
    # prefill attention runs on pre-quantization K/V: identical logits
    np.testing.assert_array_equal(np.asarray(lg_d), np.asarray(lg_q))
    tok = jnp.asarray([3], jnp.int32)
    step_d = jax.jit(model.decode_step)
    step_q = jax.jit(qmodel.decode_step)
    for p in range(4, 8):
        pos = jnp.asarray([p], jnp.int32)
        lg_d, cache_d = step_d(params, cache_d, tok, pos)
        lg_q, cache_q = step_q(params, cache_q, tok, pos)
    denom = float(jnp.max(jnp.abs(lg_d))) + 1e-9
    rel = float(jnp.max(jnp.abs(lg_d - lg_q))) / denom
    assert rel < (0.05 if fmt == "float8_e4m3fn" else 0.25)


def test_engine_kv_format_stats_and_completion(small_model):
    cfg, model, params = small_model
    stats = {}
    for fmt in (None, "float8_e4m3fn", "float4_e2m1fn"):
        eng = ServeEngine(model, params, batch=2, max_seq=32,
                          kv_format=fmt)
        for i in range(3):
            eng.submit([1 + i, 2, 3], max_new_tokens=4)
        results = eng.run()
        assert all(len(r.tokens) == 4 for r in results)
        stats[fmt] = eng.kv_stats
    # measured bytes shrink monotonically; fp4 + byte scales <= 0.6 B/elem
    assert (stats[None]["kv_bytes"] > stats["float8_e4m3fn"]["kv_bytes"]
            > stats["float4_e2m1fn"]["kv_bytes"])
    assert stats["float4_e2m1fn"]["bytes_per_elem"] <= 0.6
    assert stats["float8_e4m3fn"]["bytes_per_elem"] <= 1.25


def test_engine_rejects_overlong_prompt(small_model):
    """Satellite regression: a prompt with len >= max_seq used to be
    admitted with pos past the cache (silently clipped prefill)."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, batch=1, max_seq=16)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(list(range(16)), max_new_tokens=2)
    eng.submit(list(range(15)), max_new_tokens=4)      # 15 < 16: admitted
    results = eng.run()
    assert len(results) == 1 and len(results[0].tokens) >= 1
