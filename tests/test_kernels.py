"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(ref.py), all in interpret mode — deliverable (c)'s kernel requirement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels as K
from repro.kernels import ref
from repro.kernels.ops import quantize_for_qmatmul
from repro.kernels.probe_chase import chase_reference
from repro.kernels.probe_dep_chain import dep_chain_closed_form


# ------------------------------------------------------------------ #
# flash attention
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,hq,hkv,d", [
    (1, 128, 128, 4, 4, 64),
    (2, 256, 256, 8, 2, 64),     # GQA 4:1
    (1, 128, 384, 4, 1, 128),    # MQA, rectangular, skv % bk != 0 pad
    (1, 96, 128, 2, 2, 64),      # sq padding path
])
def test_flash_attention_sweep(key, dtype, b, sq, skv, hq, hkv, d):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), dtype)
    got = K.flash_attention(q, k, v, causal=True)
    want = ref.attention_ref(q, k, v, causal=True)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=atol)


@pytest.mark.parametrize("window,softcap,causal", [
    (64, None, True), (None, 30.0, True), (32, 20.0, True),
    (None, None, False)])
def test_flash_attention_flags(key, window, softcap, causal):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    got = K.flash_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, bq=64, bk=64)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ------------------------------------------------------------------ #
# ssd scan
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("chunk", [32, 64])
@pytest.mark.parametrize("s,h,p,n", [(128, 2, 32, 16), (192, 4, 64, 32)])
def test_ssd_scan_sweep(key, chunk, s, h, p, n):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (2, s, h, p)) * 0.5
    dt_a = -jnp.abs(jax.random.normal(ks[1], (2, s, h))) * 0.2
    b = jax.random.normal(ks[2], (2, s, n)) * 0.5
    c = jax.random.normal(ks[3], (2, s, n)) * 0.5
    y, st = K.ssd_scan(x, dt_a, b, c, chunk=chunk)
    y_ref, st_ref = ref.ssd_ref(x, dt_a, b, c, sequential=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=2e-4)


def test_ssd_scan_initial_state(key):
    """Kernel carry-in: scanning [s0 | s1] in one call == scanning s0,
    then s1 seeded with s0's final state (the chunked-prefill contract)."""
    s0, s1, h, p, n = 64, 64, 2, 32, 16
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (2, s0 + s1, h, p)) * 0.5
    dt_a = -jnp.abs(jax.random.normal(ks[1], (2, s0 + s1, h))) * 0.2
    b = jax.random.normal(ks[2], (2, s0 + s1, n)) * 0.5
    c = jax.random.normal(ks[3], (2, s0 + s1, n)) * 0.5
    y_all, st_all = K.ssd_scan(x, dt_a, b, c, chunk=32)
    _, st0 = K.ssd_scan(x[:, :s0], dt_a[:, :s0], b[:, :s0], c[:, :s0],
                        chunk=32)
    y1, st1 = K.ssd_scan(x[:, s0:], dt_a[:, s0:], b[:, s0:], c[:, s0:],
                         chunk=32, initial_state=st0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_all[:, s0:]),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st_all),
                               atol=2e-4)
    # and against the jnp oracle with the same carry
    y1_ref, st1_ref = ref.ssd_ref(x[:, s0:], dt_a[:, s0:], b[:, s0:],
                                  c[:, s0:], sequential=True,
                                  initial_state=st0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y1_ref),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st1_ref),
                               atol=2e-4)


def test_ssd_scan_padding(key):
    """s=100 not a chunk multiple -> ops pads with an identity tail."""
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (1, 100, 2, 16)) * 0.5
    dt_a = -jnp.abs(jax.random.normal(ks[1], (1, 100, 2))) * 0.2
    b = jax.random.normal(ks[2], (1, 100, 8)) * 0.5
    c = jax.random.normal(ks[3], (1, 100, 8)) * 0.5
    y, st = K.ssd_scan(x, dt_a, b, c, chunk=32)
    y_ref, st_ref = ref.ssd_ref(x, dt_a, b, c, sequential=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=2e-4)


# ------------------------------------------------------------------ #
# qmatmul
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("fmt", ["float8_e4m3fn", "float8_e5m2",
                                 "float6_e2m3fn", "float6_e3m2fn",
                                 "float4_e2m1fn"])
def test_qmatmul_formats(key, fmt):
    w = jax.random.normal(key, (256, 128), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (64, 256),
                          jnp.bfloat16)
    qw, sc = quantize_for_qmatmul(w, fmt)
    got = K.qmatmul(x, qw, sc)
    want = ref.qmatmul_ref(x, qw, sc)
    scale = float(jnp.abs(want.astype(jnp.float32)).max())
    err = float(jnp.abs(got.astype(jnp.float32)
                        - want.astype(jnp.float32)).max())
    assert err / scale < 2e-2, (fmt, err / scale)


def test_qmatmul_block_shapes(key):
    w = jax.random.normal(key, (512, 256), jnp.float32)
    x = jax.random.normal(key, (100, 512), jnp.bfloat16)   # m padding
    qw, sc = quantize_for_qmatmul(w, "float8_e4m3fn")
    for bm, bn, bk in [(128, 128, 128), (64, 256, 256), (128, 64, 512)]:
        got = K.qmatmul(x, qw, sc, bm=bm, bn=bn, bk=bk)
        want = ref.qmatmul_ref(x, qw, sc)
        err = float(jnp.abs(got.astype(jnp.float32)
                            - want.astype(jnp.float32)).max())
        assert err / float(jnp.abs(want.astype(jnp.float32)).max()) < 1e-3


def test_qmatmul_precision_staircase(key):
    """Quantization error must grow as bits shrink (paper §V.C ordering)."""
    w = jax.random.normal(key, (256, 128), jnp.float32)
    x = jax.random.normal(key, (32, 256), jnp.bfloat16)
    true = jnp.dot(x.astype(jnp.float32), w)
    errs = {}
    for fmt in ["float8_e4m3fn", "float6_e2m3fn", "float4_e2m1fn"]:
        qw, sc = quantize_for_qmatmul(w, fmt)
        got = ref.qmatmul_ref(x, qw, sc).astype(jnp.float32)
        errs[fmt] = float(jnp.abs(got - true).mean())
    assert errs["float8_e4m3fn"] < errs["float6_e2m3fn"] \
        < errs["float4_e2m1fn"]


# ------------------------------------------------------------------ #
# probe kernels
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("chain_len,ilp", [(10, 1), (100, 2), (57, 4)])
def test_dep_chain(key, chain_len, ilp):
    x = jax.random.normal(key, (ilp, 8, 128))
    got = K.dep_chain(x, chain_len, ilp=ilp, interpret=True)
    want = dep_chain_closed_form(x, chain_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


@pytest.mark.parametrize("rows,steps", [(16, 50), (64, 200)])
def test_chase(rows, steps):
    buf = K.make_chase_buffer(rows)
    got = int(K.chase(buf, steps, interpret=True))
    want = chase_reference(np.asarray(buf), steps)
    assert got == want


@pytest.mark.parametrize("ilp,bm", [(1, 128), (2, 64), (4, 128)])
def test_mma_probe(key, ilp, bm):
    x = jax.random.normal(key, (ilp, 256, 256), jnp.float32)
    y = jax.random.normal(jax.random.fold_in(key, 1), (256, 128),
                          jnp.float32)
    got = K.mma_probe(x, y, bm=bm, bn=128, bk=128, ilp=ilp, interpret=True)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-4)
