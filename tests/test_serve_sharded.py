"""Tier-1 mesh-native serving suite.

The multi-device halves run ``tests/sharded_cases.py`` in a subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` pinned in
the child's environment — the flag must precede the first jax backend
init, and this process (via conftest) has already initialized a
single-device backend.  The in-process half covers the sharding *rules*
(no devices needed): the packed sub-byte local-bytes accounting that
``launch/memdiag.py`` and the serving memory plans consume.
"""

import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CASES = os.path.join(REPO, "tests", "sharded_cases.py")


def _run_case(*names):
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, CASES, *names],
                          capture_output=True, text=True, env=env,
                          cwd=REPO, timeout=1800)
    assert proc.returncode == 0, (
        f"sharded case(s) {names} failed:\n--- stdout ---\n"
        f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    for name in names:
        assert f"CASE_OK {name}" in proc.stdout, proc.stdout


@pytest.mark.slow
def test_greedy_identity_attn_kv_formats():
    """gptneox greedy streams bit-identical for mesh None/(2,)/(2,2) and
    fused-vs-per-step on 2x2, across KV formats none/fp8/fp4 plus the
    bit-packed fp4 weight store."""
    _run_case("greedy_attn")


@pytest.mark.slow
def test_greedy_identity_ssm_hybrid():
    _run_case("greedy_ssm_hybrid")


@pytest.mark.slow
def test_greedy_identity_encdec_vlm():
    _run_case("greedy_encdec_vlm")


@pytest.mark.slow
def test_sharded_logits_and_chunked_prefill():
    _run_case("logits_and_prefill")


@pytest.mark.slow
def test_sanitize_and_contracts_sharded():
    _run_case("sanitize_sharded", "contracts_sharded")


# ---------------------------------------------------------------------------
# in-process: packed-leaf local-bytes accounting (rule arithmetic only)


class FakeMesh:
    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


MESH = FakeMesh({"data": 2, "model": 2})


def test_spec_local_bytes_packed_leaves():
    """A bit-packed fp4 leaf stores 0.5 B/elem — ``spec_local_bytes``
    must charge the registry's storage width, not ``uint8.itemsize``
    on the packed container (which would double-count fp4, the old
    memdiag bug) and not the logical dtype width."""
    shapes = {"q": jax.ShapeDtypeStruct((64, 32), jnp.uint8)}
    specs = {"q": P("data", "model")}
    dense = shd.spec_local_bytes(shapes, specs, MESH)
    assert dense == (64 // 2) * (32 // 2) * 1
    # same leaf declared as packed fp4 payload: half a byte per LOGICAL
    # element; the uint8 container already holds 2 values/byte, so the
    # formats tree is keyed by what the bytes MEAN, not what they claim
    fp4 = shd.spec_local_bytes(shapes, specs, MESH,
                               formats={"q": "float4_e2m1fn"})
    assert fp4 == math.ceil((64 // 2) * (32 // 2) * 0.5)
    fp6 = shd.spec_local_bytes(shapes, specs, MESH,
                               formats={"q": "float6_e2m3fn"})
    assert fp6 == math.ceil((64 // 2) * (32 // 2) * 0.75)


def test_spec_local_bytes_uniform_format_and_mixed_tree():
    shapes = {"w": jax.ShapeDtypeStruct((16, 16), jnp.uint8),
              "s": jax.ShapeDtypeStruct((16, 1), jnp.float32)}
    specs = {"w": P("model", None), "s": P("model", None)}
    # uniform string applies to every leaf
    n = shd.spec_local_bytes(shapes, specs, MESH,
                             formats="float4_e2m1fn")
    assert n == math.ceil(8 * 16 * 0.5) + math.ceil(8 * 1 * 0.5)
    # per-leaf tree: packed codes next to dense float scales (the real
    # quantized-KV layout)
    n = shd.spec_local_bytes(shapes, specs, MESH,
                             formats={"w": "float4_e2m1fn", "s": None})
    assert n == math.ceil(8 * 16 * 0.5) + 8 * 1 * 4


def test_serving_state_and_logits_rules():
    """Slot state and sample-point logits are replicated by rule — the
    host-side scheduler reads them with one addressable shard."""
    from repro.models.slotstate import SLOT_STATE_FIELDS

    for name in SLOT_STATE_FIELDS:
        assert tuple(shd.state_rule(name, MESH)) == ()
    assert tuple(shd.logits_spec(MESH)) == ()
