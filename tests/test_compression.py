"""Gradient compression: unbiasedness (hypothesis property test) and the
compressed mean-psum under shard_map."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import has_hypothesis
from repro.distributed.compression import (
    compressed_psum_tree, quantize, stochastic_round)
from repro.serve.quant import dequantize_blockwise, quantize_blockwise

# only the property test needs hypothesis (optional dev extra:
# pip install repro[dev]) — the rest of this module must still run
if has_hypothesis():
    from hypothesis import given, settings, strategies as st

    @given(st.floats(-100.0, 100.0), st.integers(0, 1000))
    @settings(max_examples=30, deadline=None)
    def test_stochastic_round_unbiased(value, seed):
        keys = jax.random.split(jax.random.PRNGKey(seed), 256)
        x = jnp.full((8,), value)
        samples = jnp.stack([stochastic_round(x, k) for k in keys])
        est = float(jnp.mean(samples))
        assert abs(est - value) < 0.15, (value, est)
else:
    @pytest.mark.skip(reason="optional dev extra: pip install repro[dev]")
    def test_stochastic_round_unbiased():
        pass


def test_quantize_dequantize_error_bound(key):
    g = jax.random.normal(key, (64, 64)) * 3.0
    q, scale = quantize(g, key, qmax=127)
    deq = q.astype(jnp.float32) * scale
    assert float(jnp.abs(deq - g).max()) <= float(scale) + 1e-6


def test_compressed_psum_mean(key):
    """shard_map over the single CPU device (world=1): the compressed mean
    must equal the plain mean to quantization error."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map
    mesh = Mesh(np.array(jax.devices()).reshape(1), ("data",))
    g = jax.random.normal(key, (4, 8))

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P(), P()), out_specs=P())
    def run(g, k):
        return compressed_psum_tree({"g": g}, k, "data", world=1)["g"]

    out = run(g, key)
    scale = float(jnp.abs(g).max()) / 127
    assert float(jnp.abs(out - g).max()) <= scale + 1e-6


@pytest.mark.parametrize("fmt,tol", [
    ("float8_e4m3fn", 0.07), ("float8_e5m2", 0.14),
    ("float6_e2m3fn", 0.13), ("float6_e3m2fn", 0.26),
    ("float4_e2m1fn", 0.5)])
def test_block_quant_roundtrip_bound(key, fmt, tol):
    """Blockwise e8m0 quantization: relative error bounded by the format's
    relative resolution (paper §V.C precision/expressiveness trade-off)."""
    w = jax.random.normal(key, (32, 256))
    q, s = quantize_blockwise(w, fmt)
    deq = dequantize_blockwise(q, s, jnp.float32)
    rel = float(jnp.abs(deq - w).max() / jnp.abs(w).max())
    assert rel < tol, (fmt, rel)


def test_e8m0_scales_are_powers_of_two(key):
    w = jax.random.normal(key, (8, 64))
    _, s = quantize_blockwise(w, "float8_e4m3fn")
    log2 = np.log2(np.asarray(s))
    np.testing.assert_allclose(log2, np.round(log2), atol=1e-6)
