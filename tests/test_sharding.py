"""Sharding rules: coverage, divisibility fallbacks, cache spill rules,
local-bytes accounting.  Uses fake meshes built from abstract devices via
mesh shape arithmetic only (no XLA device requirement beyond CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ASSIGNED, get_config, get_shape
from repro.distributed import sharding as shd
from repro.models import build_model
from repro.models.model import decode_inputs_spec


class FakeMesh:
    """Duck-typed mesh: only .shape and .axis_names are consulted by the
    rule functions."""

    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["1pod", "2pod"])
@pytest.mark.parametrize("arch", [c.name for c in ASSIGNED])
def test_param_specs_cover_and_divide(arch, mesh):
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_specs(cfg, mesh, shapes)
    n_checked = 0
    for leaf, spec in zip(jax.tree.leaves(shapes),
                          jax.tree.leaves(
                              specs, is_leaf=lambda x: isinstance(x, P))):
        assert isinstance(spec, P)
        assert len(tuple(spec)) == len(leaf.shape), (leaf.shape, spec)
        for dim, axes in zip(leaf.shape, tuple(spec)):
            if axes is None:
                continue
            size = shd.axis_size(mesh, axes)
            assert dim % size == 0, (arch, leaf.shape, spec)
            n_checked += 1
    assert n_checked > 0


def test_fsdp_shards_big_archs():
    """>=52B archs must come out with per-device param bytes < HBM."""
    for arch in ("jamba-v0.1-52b", "kimi-k2-1t-a32b",
                 "llama4-maverick-400b-a17b"):
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = shd.param_specs(cfg, MESH2, shapes)
        local = shd.spec_local_bytes(shapes, specs, MESH2)
        assert local < 8 * 2**30, f"{arch}: {local/2**30:.1f} GiB/device"


def test_head_fallback_to_data_axis():
    """llama3.2 (24 q-heads, 16-way model axis): attention weights must
    shard d_model on data instead of replicating."""
    cfg = get_config("llama3.2-3b")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_specs(cfg, MESH1, shapes)
    wq_spec = specs["layers"]["pos0"]["attn"]["wq"]
    assert tuple(wq_spec)[1] == "data"     # (period, D, H, hd): D on data
    assert tuple(wq_spec)[2] is None       # heads replicated


def test_kv_cache_seq_spill():
    """kv_heads=8 on model=16 -> cache seq dim takes the model axis."""
    cfg = get_config("llama3.2-3b")
    shape = get_shape("decode_32k")
    cache_shapes, _, _ = decode_inputs_spec(cfg, shape)
    specs = shd.cache_specs(cfg, MESH1, cache_shapes)
    k_spec = specs["pos0"]["kv"]["k"]
    assert tuple(k_spec)[2] in ("model", ("model",))   # seq -> model
    assert tuple(k_spec)[3] is None            # heads replicated
    # batch 128 shardable on data
    assert tuple(k_spec)[1] in ("data", ("data",))


def test_kv_cache_long_context_spill():
    """batch=1 long_500k -> seq takes data (+model when heads can't)."""
    cfg = get_config("gemma2-2b")              # kv=4 not divisible by 16
    shape = get_shape("long_500k")
    cache_shapes, _, _ = decode_inputs_spec(cfg, shape)
    specs = shd.cache_specs(cfg, MESH1, cache_shapes)
    # find a global-attention kv leaf
    k_spec = specs["pos1"]["kv"]["k"]
    assert tuple(k_spec)[1] is None            # batch 1
    assert set(tuple(k_spec)[2]) == {"data", "model"}


def test_spec_local_bytes():
    shapes = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
    specs = {"w": P("data", "model")}
    n = shd.spec_local_bytes(shapes, specs, MESH1)
    assert n == (64 // 16) * (32 // 16) * 4


def test_batch_specs():
    from repro.models.model import batch_fields
    cfg = get_config("qwen2.5-3b")
    shape = get_shape("train_4k")
    specs = shd.batch_specs(cfg, shape, MESH2, batch_fields(cfg, shape))
    assert tuple(specs["tokens"])[0] == ("pod", "data")
