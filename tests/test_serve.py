"""Serving engine: batched continuous batching, greedy determinism,
quantized-weights serving."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine, quantize_params, sample_token


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("gptneox-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_completes_requests(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, batch=2, max_seq=64)
    ids = [eng.submit([1, 2, 3, 4], max_new_tokens=5) for _ in range(5)]
    results = eng.run()
    assert sorted(r.request_id for r in results) == ids
    for r in results:
        assert len(r.tokens) == 5


def test_greedy_engine_matches_manual_decode(small_model):
    """Engine output == hand-rolled prefill + decode loop (greedy)."""
    cfg, model, params = small_model
    prompt = [5, 7, 9, 11, 13, 2, 4, 6]
    eng = ServeEngine(model, params, batch=1, max_seq=64)
    eng.submit(prompt, max_new_tokens=4)
    got = eng.run()[0].tokens

    logits, cache = model.prefill(params, {"tokens": jnp.asarray([prompt])},
                                  64)
    want = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(3):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([want[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        want.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert got == want


def test_continuous_batching_isolation(small_model):
    """A request's output must not depend on its batch companions."""
    cfg, model, params = small_model
    solo = ServeEngine(model, params, batch=1, max_seq=64)
    solo.submit([1, 2, 3, 4], max_new_tokens=4)
    want = solo.run()[0].tokens

    crowded = ServeEngine(model, params, batch=3, max_seq=64)
    rid = crowded.submit([1, 2, 3, 4], max_new_tokens=4)
    crowded.submit([9, 9, 9, 9, 9, 9], max_new_tokens=6)
    crowded.submit([4, 4], max_new_tokens=3)
    got = [r for r in crowded.run() if r.request_id == rid][0].tokens
    assert got == want


def test_sampler_modes(key):
    logits = jnp.asarray([[0.0, 5.0, 1.0]])
    assert int(sample_token(logits)[0]) == 1           # greedy
    t = sample_token(logits, key, temperature=1.0, top_k=2)
    assert int(t[0]) in (1, 2)                         # top-2 excludes 0


@pytest.mark.parametrize("fmt", ["bfloat16", "float8_e4m3fn",
                                 "float4_e2m1fn"])
def test_quantized_serving_runs(small_model, fmt):
    cfg, model, params = small_model
    qparams, stats = quantize_params(params, fmt)
    if fmt != "bfloat16":
        assert stats["n_quantized"] > 0
        assert stats["mse"] < 0.05
    eng = ServeEngine(model, qparams, batch=1, max_seq=32)
    eng.submit([1, 2, 3], max_new_tokens=3)
    results = eng.run()
    assert len(results[0].tokens) == 3


def test_quantized_bytes_shrink(small_model):
    cfg, model, params = small_model
    _, s8 = quantize_params(params, "float8_e4m3fn")
    _, s4 = quantize_params(params, "float4_e2m1fn")
    _, s16 = quantize_params(params, "bfloat16")
    assert s8["quantized_bytes"] < s16["quantized_bytes"]
    assert s4["quantized_bytes"] < s8["quantized_bytes"]
