"""Bit-packed sub-byte storage (repro.lowbits + the packed qmatmul path).

The e4m3-container emulation is the numerical oracle: every packed
format must round-trip to exactly the values the container path stores,
and qmatmul_packed must be bit-exact with qmatmul in interpret mode.
"""

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

import repro.kernels as K
from repro import compat, lowbits
from repro.serve.quant import (BLOCK, dequantize_tree, quantize_blockwise,
                               quantize_params, quantize_tree)

PACKED = sorted(lowbits.PACKED_FORMATS)


# ------------------------------------------------------------------ #
# codes <-> values
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("fmt", PACKED)
def test_decode_matches_ml_dtypes_all_codes(fmt):
    """The arithmetic decoder reproduces ml_dtypes bit-for-bit over the
    format's entire code space (so in-kernel decode == host decode)."""
    spec = lowbits.packed_spec(fmt)
    codes = np.arange(1 << spec.bits, dtype=np.uint8)
    want = codes.view(spec.code_dtype).astype(np.float32)
    np.testing.assert_array_equal(lowbits.decode(codes, fmt), want)
    # and on the jnp side (the path Pallas kernels trace)
    got_jnp = np.asarray(lowbits.decode(jnp.asarray(codes), fmt))
    np.testing.assert_array_equal(got_jnp, want)


@pytest.mark.parametrize("fmt", PACKED)
def test_encode_decode_roundtrip(fmt):
    spec = lowbits.packed_spec(fmt)
    x = np.random.RandomState(0).randn(256).astype(np.float32)
    rounded = x.astype(spec.code_dtype).astype(np.float32)
    np.testing.assert_array_equal(
        lowbits.decode(lowbits.encode(x, fmt), fmt), rounded)


# ------------------------------------------------------------------ #
# pack / unpack
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("fmt", PACKED)
@pytest.mark.parametrize("n", [1, 2, 3, 5, 7, 32, 64, 65, 127])
def test_pack_unpack_odd_tails(fmt, n):
    """Round trip at every tail length, packed size from the spec."""
    spec = lowbits.packed_spec(fmt)
    x = np.random.RandomState(n).randn(3, n).astype(np.float32)
    rounded = x.astype(spec.code_dtype).astype(np.float32)
    p = lowbits.pack(x, fmt)
    assert p.dtype == np.uint8
    assert p.shape == (3, spec.packed_len(n))
    assert p.shape[-1] == lowbits.packed_nbytes(n, fmt)
    np.testing.assert_array_equal(lowbits.unpack(p, fmt, n), rounded)


@pytest.mark.parametrize("fmt", PACKED)
def test_pack_matches_container_path(fmt):
    """Packed storage holds exactly the values the e4m3 container path
    (quantize_blockwise) stores — the emulation oracle."""
    w = np.random.RandomState(1).randn(16, 2 * BLOCK).astype(np.float32)
    q, scales = quantize_blockwise(jnp.asarray(w), fmt)
    container_vals = np.asarray(q.astype(jnp.float32))
    p = lowbits.pack(container_vals, fmt)
    np.testing.assert_array_equal(
        lowbits.unpack(p, fmt, container_vals.shape[-1]), container_vals)


def test_storage_accounting():
    assert lowbits.packed_nbytes(128, "float4_e2m1fn") == 64      # 0.5 B
    assert lowbits.packed_nbytes(128, "float6_e2m3fn") == 96      # 0.75 B
    assert lowbits.packed_nbytes(7, "float4_e2m1fn") == 4         # tail
    assert lowbits.packed_nbytes(5, "float6_e3m2fn") == 6         # tail
    assert compat.storage_bytes_per_element("float4_e2m1fn") == 0.5
    assert compat.storage_bytes_per_element("float6_e3m2fn") == 0.75
    assert compat.storage_bytes_per_element("float8_e4m3fn") == 1.0
    assert compat.storage_bytes_per_element(
        "float4_e2m1fn", packed=False) == 1.0


def test_registry_carries_packed_specs():
    for name, spec in compat.dtype_registry().items():
        if spec.bits < 8:
            assert spec.packed is not None and spec.packable
            assert spec.packed.packed_len(64) == 64 * spec.bits // 8
            assert "packed" in spec.describe()
        else:
            assert spec.packed is None and not spec.packable


# ------------------------------------------------------------------ #
# qmatmul_packed vs qmatmul (interpret mode)
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("fmt", PACKED)
def test_qmatmul_packed_bit_exact(key, fmt):
    w = jax.random.normal(key, (256, 128), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (64, 256),
                          jnp.bfloat16)
    qw, sc = K.quantize_for_qmatmul(w, fmt)
    pw, sc2 = K.pack_for_qmatmul(w, fmt)
    np.testing.assert_array_equal(np.asarray(sc), np.asarray(sc2))
    spec = lowbits.packed_spec(fmt)
    assert pw.shape == (128, spec.packed_len(256))
    got = K.qmatmul_packed(x, pw, sc2, fmt)
    want = K.qmatmul(x, qw, sc)
    np.testing.assert_array_equal(
        np.asarray(got).view(np.uint16), np.asarray(want).view(np.uint16))


def test_qmatmul_packed_block_shapes_and_padding(key):
    """m-padding path + non-default blocks, fp4."""
    fmt = "float4_e2m1fn"
    w = jax.random.normal(key, (512, 256), jnp.float32)
    x = jax.random.normal(key, (100, 512), jnp.bfloat16)
    qw, sc = K.quantize_for_qmatmul(w, fmt)
    pw, _ = K.pack_for_qmatmul(w, fmt)
    for bm, bn, bk in [(128, 128, 128), (64, 256, 256), (128, 64, 512)]:
        got = K.qmatmul_packed(x, pw, sc, fmt, bm=bm, bn=bn, bk=bk)
        want = K.qmatmul(x, qw, sc, bm=bm, bn=bn, bk=bk)
        np.testing.assert_array_equal(
            np.asarray(got).view(np.uint16),
            np.asarray(want).view(np.uint16))


# ------------------------------------------------------------------ #
# quantize_tree / engine storage
# ------------------------------------------------------------------ #

def _toy_params(key):
    ks = jax.random.split(key, 3)
    return {"blk": {"w1": jax.random.normal(ks[0], (64, 2 * BLOCK)),
                    "ln_1": jax.random.normal(ks[1], (64,))},
            "embed": jax.random.normal(ks[2], (32, 2 * BLOCK))}


@pytest.mark.parametrize("fmt,bpe", [("float4_e2m1fn", 0.5),
                                     ("float6_e2m3fn", 0.75),
                                     ("float6_e3m2fn", 0.75),
                                     ("float8_e4m3fn", 1.0)])
def test_quantize_tree_storage_and_roundtrip(key, fmt, bpe):
    params = _toy_params(key)
    store, stats = quantize_tree(params, fmt, packed=True)
    assert stats["n_quantized"] == 2
    assert stats["bytes_per_element"] == bpe
    n_elems = params["blk"]["w1"].size + params["embed"].size
    assert stats["weight_bytes"] == int(n_elems * bpe)
    # dequantized store == the fake-quant oracle, exactly
    deq = dequantize_tree(store)
    fake, _ = quantize_params(params, fmt)
    np.testing.assert_array_equal(
        np.asarray(deq["blk"]["w1"], np.float32),
        np.asarray(fake["blk"]["w1"], np.float32))
    np.testing.assert_array_equal(
        np.asarray(deq["embed"], np.float32),
        np.asarray(fake["embed"], np.float32))
    # non-quantizable leaves pass through untouched
    np.testing.assert_array_equal(np.asarray(deq["blk"]["ln_1"]),
                                  np.asarray(params["blk"]["ln_1"]))


def test_quantize_tree_unpacked_container(key):
    params = _toy_params(key)
    store, stats = quantize_tree(params, "float4_e2m1fn", packed=False)
    assert not stats["packed"]
    assert stats["bytes_per_element"] == 1.0        # container width
    np.testing.assert_array_equal(
        np.asarray(dequantize_tree(store)["embed"], np.float32),
        np.asarray(dequantize_tree(
            quantize_tree(params, "float4_e2m1fn", packed=True)[0]
        )["embed"], np.float32))


def test_engine_packed_weight_store(key):
    """Engine with weight_format holds a 0.5 B/elem fp4 store and decodes
    identically to pre-dequantized params (greedy sampling)."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import ServeEngine

    cfg = get_config("gptneox-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch=2, max_seq=32,
                      weight_format="float4_e2m1fn", packed=True)
    assert eng.weight_stats["packed"]
    assert eng.weight_stats["bytes_per_element"] == 0.5
    # oracle: fake-quant params through quantize_params
    fake, _ = quantize_params(params, "float4_e2m1fn")
    ref = ServeEngine(model, fake, batch=2, max_seq=32)
    for e in (eng, ref):
        e.submit([1, 2, 3, 4], max_new_tokens=4)
    got = eng.run()
    want = ref.run()
    assert [r.tokens for r in got] == [r.tokens for r in want]
