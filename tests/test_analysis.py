"""Tier-1 tests for the static-analysis subsystem (repro.analysis).

Three layers, each with positive (bug detected) and negative (idiom not
flagged) fixtures:

* lint (JL1xx)     — AST rules keyed to bug classes this repo has
                     actually shipped: PR-4's jit-captured attr
                     mutation, PR-3's stale memo cache, plus the
                     host-op / control-flow / wall-clock tracer rules.
* contracts (CT3xx)— jaxpr checks: packed-payload upcasts, host
                     callbacks, cache storage width.
* pallas (PC2xx)   — write-race / alias / VMEM checks over recorded
                     ``pallas_call`` sites, plus coverage of the repo's
                     real kernels.

Plus the runtime sanitizer: the fused serving loop must compile exactly
once and perform zero implicit host transfers, and ``quantize_tree``
must sync O(1) per tree, not O(leaves).
"""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.analysis import lint as L
from repro.analysis import pallas_check as PC
from repro.analysis import sanitize as SAN
from repro.analysis import contracts as CT


def run_lint(src, roots=("f",), path="fixture.py", select=None):
    cfg = L.LintConfig(traced_roots={path: set(roots)},
                       select=set(select) if select else None)
    return L.lint_source(textwrap.dedent(src), path, cfg)


def rules(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# layer 1: lint rules


class TestJL101HostOps:
    def test_float_on_traced_value(self):
        out = run_lint("""
            def f(x):
                y = x * 2
                return float(y)
        """)
        assert rules(out) == ["JL101"]

    def test_np_asarray_on_traced_value(self):
        out = run_lint("""
            import numpy as np
            def f(x):
                return np.asarray(x).sum()
        """)
        assert "JL101" in rules(out)

    def test_item_tolist(self):
        out = run_lint("""
            def f(x):
                a = x.item()
                b = x.tolist()
                return a, b
        """)
        assert rules(out) == ["JL101", "JL101"]

    def test_metadata_only_np_is_clean(self):
        out = run_lint("""
            import numpy as np
            def f(x):
                if np.issubdtype(x.dtype, np.floating):
                    return x
                return x * np.float32(2.0)
        """)
        assert out == []

    def test_untraced_function_is_clean(self):
        out = run_lint("""
            def g(x):
                return float(x)
        """)
        assert out == []

    def test_pragma_suppresses_with_reason(self):
        out = run_lint("""
            def f(x):
                return float(x)  # jaxlint: disable=JL101(eager-only path)
        """)
        assert out == []

    def test_transitive_callee_inherits_traced(self):
        # f is the configured root; helper is only reached from f, so a
        # host op inside helper is still a finding
        out = run_lint("""
            def helper(x):
                return float(x)
            def f(x):
                return helper(x)
        """)
        assert "JL101" in rules(out)


class TestJL102ControlFlow:
    def test_if_on_traced_value(self):
        out = run_lint("""
            def f(x):
                if x > 0:
                    return x
                return -x
        """)
        assert rules(out) == ["JL102"]

    def test_while_on_traced_value(self):
        out = run_lint("""
            def f(x):
                while x < 10:
                    x = x + 1
                return x
        """)
        assert "JL102" in rules(out)

    def test_shape_branch_is_static(self):
        out = run_lint("""
            def f(x):
                if x.ndim == 2:
                    return x
                return x[None]
        """)
        assert out == []

    def test_isinstance_and_config_are_static(self):
        out = run_lint("""
            def f(x, cfg):
                if isinstance(x, dict):
                    return x["a"]
                if cfg.heads > 1:
                    return x * cfg.heads
                return x
        """)
        assert out == []

    def test_membership_test_is_static(self):
        out = run_lint("""
            def f(x, batch):
                if "patches" in batch:
                    return x
                return -x
        """)
        assert out == []


class TestJL103CapturedMutation:
    # PR-4 regression: ServeEngine captured self.temperature in its
    # jitted sampler; a later `eng.temperature = 0.5` was silently
    # ignored by the stale executable.
    PR4_PATTERN = """
        import jax

        class Engine:
            def __init__(self, temperature):
                self.temperature = temperature
                temp = self.temperature
                self._step = jax.jit(lambda x: x / temp)

            def set_temperature(self, t):
                self.temperature = t
    """

    def test_pr4_pattern_detected(self):
        out = run_lint(self.PR4_PATTERN, roots=())
        assert rules(out) == ["JL103"]
        assert "temperature" in out[0].message

    def test_direct_self_read_in_local_def(self):
        out = run_lint("""
            import jax

            class Engine:
                def build(self):
                    def step(x):
                        return x * self.scale
                    self._step = jax.jit(step)

                def rescale(self, s):
                    self.scale = s
        """, roots=())
        assert rules(out) == ["JL103"]

    def test_uncaptured_attr_mutation_is_clean(self):
        out = run_lint("""
            import jax

            class Engine:
                def __init__(self, temperature):
                    temp = temperature
                    self._step = jax.jit(lambda x: x / temp)

                def retarget(self, t):
                    self.queue = t
        """, roots=())
        assert out == []

    def test_readonly_property_backing_field_is_sanctioned(self):
        # the fix the rule message recommends must itself lint clean
        out = run_lint("""
            import jax

            class Engine:
                def __init__(self, temperature):
                    self._temperature = temperature
                    temp = self._temperature
                    self._step = jax.jit(lambda x: x / temp)

                @property
                def temperature(self):
                    return self._temperature
        """, roots=())
        assert out == []


class TestJL104WallClock:
    def test_time_in_traced_scope(self):
        out = run_lint("""
            import time
            def f(x):
                t0 = time.perf_counter()
                return x + t0
        """)
        assert "JL104" in rules(out)

    def test_np_random_in_traced_scope(self):
        out = run_lint("""
            import numpy as np
            def f(x):
                return x + np.random.rand()
        """)
        assert "JL104" in rules(out)

    def test_jax_prng_is_clean(self):
        out = run_lint("""
            import jax
            def f(x, key):
                return x + jax.random.normal(key, x.shape)
        """)
        assert out == []


class TestJL105StaleMemo:
    # PR-3 regression: `_format_table` was lru_cached over the mutable
    # format registry, so formats registered later never appeared.
    def test_pr3_pattern_detected(self):
        out = run_lint("""
            import functools

            @functools.lru_cache()
            def format_table():
                rows = [fmt.name for fmt in get_registry()]
                return "\\n".join(rows)
        """, roots=())
        assert rules(out) == ["JL105"]

    def test_pure_memo_is_clean(self):
        out = run_lint("""
            import functools

            @functools.lru_cache(maxsize=None)
            def fib(n):
                return n if n < 2 else fib(n - 1) + fib(n - 2)
        """, roots=())
        assert out == []


class TestBaselineAndPaths:
    def test_baseline_waives_exact_finding_once(self, tmp_path):
        fix = tmp_path / "fixture.py"
        fix.write_text(textwrap.dedent("""
            def f(x):
                return float(x)
        """))
        cfg = L.LintConfig(traced_roots={"fixture.py": {"f"}})
        first = L.lint_paths([str(fix)], config=cfg, root=str(tmp_path))
        assert rules(first) == ["JL101"]
        base = [{"path": f.path, "rule": f.rule, "context": f.context,
                 "text": f.text} for f in first]
        again = L.lint_paths([str(fix)], config=cfg, baseline=base,
                             root=str(tmp_path))
        assert again == []
        # baseline entries age out when the waived line changes
        fix.write_text(textwrap.dedent("""
            def f(x):
                return float(x + 1)
        """))
        changed = L.lint_paths([str(fix)], config=cfg, baseline=base,
                               root=str(tmp_path))
        assert rules(changed) == ["JL101"]

    def test_repo_gate_is_clean(self):
        """The shipped gate: src + benchmarks lint clean (pragmas only,
        empty baseline)."""
        import os
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        out = L.lint_paths([os.path.join(root, "src"),
                            os.path.join(root, "benchmarks")],
                           root=root)
        assert out == [], "\n".join(f.render() for f in out)


# ---------------------------------------------------------------------------
# layer 2: jaxpr contracts


class TestContracts:
    def test_ct301_upcast_detected(self):
        def bad(codes):
            # "forgot the unpack": treat packed bytes as dense values
            return codes.astype(jnp.float32) * 2.0

        jx = jax.make_jaxpr(bad)(jnp.zeros((4, 8), jnp.uint8))
        out = CT.upcast_findings(jx, [0], "bad")
        assert rules(out) == ["CT301"]

    def test_ct301_bitwise_unpack_is_sanctioned(self):
        def good(codes):
            lo = (codes & 0x0F).astype(jnp.float32)
            hi = (codes >> 4).astype(jnp.float32)
            return lo + hi

        jx = jax.make_jaxpr(good)(jnp.zeros((4, 8), jnp.uint8))
        assert CT.upcast_findings(jx, [0], "good") == []

    def test_ct301_taint_flows_through_layout_and_scan(self):
        def bad(codes):
            def body(carry, row):
                return carry + row.astype(jnp.float32).sum(), None

            r = codes.reshape(8, 4).T    # layout ops keep the taint
            return jax.lax.scan(body, 0.0, r)[0]

        jx = jax.make_jaxpr(bad)(jnp.zeros((4, 8), jnp.uint8))
        assert rules(CT.upcast_findings(jx, [0], "bad")) == ["CT301"]

    def test_ct302_debug_print_detected(self):
        def noisy(x):
            jax.debug.print("x={x}", x=x.sum())
            return x * 2

        jx = jax.make_jaxpr(noisy)(jnp.zeros((4,), jnp.float32))
        out = CT.callback_findings(jx, "noisy")
        assert out and all(f.rule == "CT302" for f in out)

    def test_ct302_clean_fn(self):
        jx = jax.make_jaxpr(lambda x: x * 2)(jnp.zeros((4,)))
        assert CT.callback_findings(jx, "clean") == []

    def test_repo_entry_points_hold_their_contracts(self):
        out = CT.check_entry_points()
        assert out == [], "\n".join(f.render() for f in out)


# ---------------------------------------------------------------------------
# layer 3: Pallas checker


def _record_site(*, grid, in_spec, out_spec, out_shape, semantics,
                 args, aliases=None):
    from jax.experimental import pallas as pl  # noqa: F401

    with PC.capture() as sites:
        fn = compat.pallas_call(
            lambda *refs: None,
            grid=grid, in_specs=[in_spec], out_specs=out_spec,
            out_shape=out_shape, dimension_semantics=semantics,
            input_output_aliases=aliases or {})
        fn(*args)
    assert len(sites) == 1
    return sites[0]


class TestPallasChecker:
    def test_seeded_write_race_detected(self):
        from jax.experimental import pallas as pl

        site = _record_site(
            grid=(4,),
            in_spec=pl.BlockSpec((8,), lambda i: (0,)),
            out_spec=pl.BlockSpec((8,), lambda i: (0,)),  # all i -> block 0
            out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
            semantics=("parallel",),
            args=(jnp.zeros((8,), jnp.float32),))
        out = PC.check_sites([site])
        assert "PC201" in rules(out)

    def test_sequential_accumulator_is_legal(self):
        # the qmatmul k-loop / ssd_scan pattern: same output block
        # revisited across an "arbitrary" dimension is NOT a race
        from jax.experimental import pallas as pl

        site = _record_site(
            grid=(4,),
            in_spec=pl.BlockSpec((8,), lambda i: (i,)),
            out_spec=pl.BlockSpec((8,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
            semantics=("arbitrary",),
            args=(jnp.zeros((32,), jnp.float32),))
        assert PC.check_sites([site]) == []

    def test_undeclared_semantics_assumed_parallel(self):
        from jax.experimental import pallas as pl

        site = _record_site(
            grid=(2,),
            in_spec=pl.BlockSpec((8,), lambda i: (i,)),
            out_spec=pl.BlockSpec((8,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
            semantics=None,
            args=(jnp.zeros((16,), jnp.float32),))
        assert "PC201" in rules(PC.check_sites([site]))

    def test_disjoint_writes_are_clean(self):
        from jax.experimental import pallas as pl

        site = _record_site(
            grid=(4,),
            in_spec=pl.BlockSpec((8,), lambda i: (i,)),
            out_spec=pl.BlockSpec((8,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
            semantics=("parallel",),
            args=(jnp.zeros((32,), jnp.float32),))
        assert PC.check_sites([site]) == []

    def test_vmem_overflow_detected(self):
        from jax.experimental import pallas as pl

        site = _record_site(
            grid=(1,),
            in_spec=pl.BlockSpec((1024,), lambda i: (0,)),
            out_spec=pl.BlockSpec((1024,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((1024,), jnp.float32),
            semantics=("arbitrary",),
            args=(jnp.zeros((1024,), jnp.float32),))
        out = PC.check_sites([site], vmem_budget=4096)  # 8 KiB needed
        assert rules(out) == ["PC203"]

    def test_unsound_alias_detected(self):
        from jax.experimental import pallas as pl

        site = _record_site(
            grid=(1,),
            in_spec=pl.BlockSpec((8,), lambda i: (0,)),
            out_spec=pl.BlockSpec((8,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((8,), jnp.int32),
            semantics=("arbitrary",),
            args=(jnp.zeros((8,), jnp.float32),),   # f32 aliased to i32
            aliases={0: 0})
        assert "PC202" in rules(PC.check_sites([site]))

    def test_ast_pass_sees_every_kernel_file(self):
        import os
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        kdir = os.path.join(root, "src", "repro", "kernels")
        sites = PC.pallas_call_sites([kdir])
        files = {os.path.basename(p) for p, _, _ in sites}
        assert files == {"flash_attention.py", "flash_decode.py",
                         "probe_chase.py", "probe_dep_chain.py",
                         "probe_mma.py", "qmatmul.py", "ssd_scan.py"}
        assert len(sites) == 9

    def test_repo_kernels_pass_and_are_fully_covered(self):
        out = PC.check_kernels()
        assert out == [], "\n".join(f.render() for f in out)


# ---------------------------------------------------------------------------
# runtime sanitizers


class TestSanitizers:
    def test_sync_counter_counts_host_reads(self):
        x = jnp.arange(8.0)
        with SAN.SyncCounter() as sc:
            float(jnp.sum(x))
            int(jnp.argmax(x))
        assert sc.count >= 2

    def test_compile_counter_sees_fresh_jit(self):
        @jax.jit
        def g(x):
            return x * 3 + 1

        x = jnp.arange(7.0)
        jax.block_until_ready(x)            # arange has its own compile
        with SAN.CompileCounter() as cc:
            g(x).block_until_ready()
        assert cc.count == 1
        with SAN.CompileCounter() as cc2:
            g(x).block_until_ready()        # cache hit
        assert cc2.count == 0

    def test_serving_hot_loop_is_sanitized(self):
        """The ISSUE's acceptance check: the fused decode loop compiles
        exactly once and performs zero implicit host transfers."""
        rep = SAN.sanitize_serving(kv_format="float4_e2m1fn")
        assert rep["compiled_exactly_once"], rep
        assert rep["measured_compiles"] == 0, rep
        assert rep["zero_implicit_loop_transfers"], rep
        assert rep["measured_loop_syncs"] == 0, rep
        assert rep["tokens_match_warmup"], rep
        # the quant.py fix: one batched sync per tree, not 2 per leaf
        assert rep["quantize_tree_leaves"] >= 4
        assert rep["quantize_tree_syncs"] <= 2, (
            "quantize_tree regressed to per-leaf host syncs: "
            f"{rep['quantize_tree_syncs']} syncs for "
            f"{rep['quantize_tree_leaves']} leaves")

    @pytest.mark.parametrize("arch", ["mamba2-2.7b",
                                      "seamless-m4t-medium"])
    def test_serving_sanitized_per_family(self, arch):
        """Same compile-once / zero-sync discipline on the SSM and
        enc-dec scenarios (the slot-state protocol makes their hot
        loops structurally identical to the attention arch's — the
        enc-dec engine adds the encode_slot admission executable, which
        must also compile exactly once)."""
        rep = SAN.sanitize_serving(arch=arch)
        assert rep["compiled_exactly_once"], rep
        assert rep["zero_implicit_loop_transfers"], rep
        assert rep["tokens_match_warmup"], rep
        if arch == "seamless-m4t-medium":
            assert rep["compile_cache_sizes"]["encode_slot"] == 1, rep


# ---------------------------------------------------------------------------
# CLI


class TestCli:
    def test_exit_codes(self, tmp_path, monkeypatch):
        from tools import jaxlint as cli

        clean = tmp_path / "clean.py"
        clean.write_text("def helper(x):\n    return x\n")
        assert cli.main([str(clean), "--no-baseline"]) == 0

        dirty = tmp_path / "models" / "transformer.py"
        dirty.parent.mkdir()
        dirty.write_text(textwrap.dedent("""
            def lm_decode_step(params, cache, tok):
                if tok > 0:
                    return cache
                return None
        """))
        assert cli.main([str(dirty), "--no-baseline"]) == 1
