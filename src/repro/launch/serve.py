"""Serving launcher: batched engine with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch gptneox-1b --reduced \
        --requests 8 --batch 4 --max-new 16 --precision float8_e4m3fn

Mesh-native serving: ``--mesh 2x2`` shards the engine over a
('data', 'model') device mesh (``--mesh 4`` = pure TP on ('model',)).
On a CPU host, pair it with ``--fake-devices N`` (must come before jax
touches a backend, which is why this launcher parses args before
importing anything that initializes jax).

Traffic mode: ``--scenario poisson|bursty|ramp`` replays a seeded
arrival trace (``repro.serve.traffic``) instead of pre-enqueueing
``--requests`` prompts, reporting TTFT/per-token tails, goodput, and
exact status accounting.  ``--queue-limit``/``--policy``/
``--deadline-ms`` bound the admission queue in either mode:

    PYTHONPATH=src python -m repro.launch.serve --arch gptneox-1b \
        --reduced --scenario ramp --queue-limit 4 --policy shed_oldest \
        --deadline-ms 500
"""

from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gptneox-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--decode-block", type=int, default=16,
                    help="decode steps fused per dispatch (1 = per-token)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens per pooled-prefill dispatch")
    ap.add_argument("--precision", default="bfloat16",
                    help="float32|bfloat16|float8_e4m3fn|float8_e5m2|"
                         "float6_e2m3fn|float6_e3m2fn|float4_e2m1fn")
    ap.add_argument("--mesh", default=None,
                    help="serving mesh shape, e.g. 2x2 (data x model) "
                         "or 4 (pure TP); omit for single-device")
    ap.add_argument("--fake-devices", type=int, default=0,
                    help="XLA host-platform fake device count (CPU mesh "
                         "smoke runs); set before jax backend init")
    ap.add_argument("--scenario", default=None,
                    choices=["poisson", "bursty", "ramp"],
                    help="replay a seeded arrival trace instead of "
                         "pre-enqueueing --requests prompts")
    ap.add_argument("--scenario-seed", type=int, default=0)
    ap.add_argument("--queue-limit", type=int, default=None,
                    help="bound the admission queue (queued requests; "
                         "in-flight slots are bounded by --batch)")
    ap.add_argument("--policy", default="reject",
                    choices=["reject", "shed_oldest", "block"],
                    help="what a full queue does to the next submit")
    ap.add_argument("--scheduler", default="fifo",
                    choices=["fifo", "spf"])
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline from submit; expired "
                         "requests finish as deadline_exceeded")
    args = ap.parse_args()

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models import build_model
    from repro.serve import (AdmissionConfig, ServeEngine,
                             quantize_params, replay)
    from repro.serve.traffic import TRACES

    mesh = make_serving_mesh(args.mesh)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params, qstats = quantize_params(params, args.precision)
    print(f"[serve] {cfg.name} precision={args.precision} "
          f"quantized_bytes={qstats['quantized_bytes']/2**20:.1f} MiB "
          f"rel-mse={qstats['mse']:.2e}"
          + (f" mesh={dict(mesh.shape)}" if mesh is not None else ""))

    admission = None
    if (args.queue_limit is not None or args.deadline_ms is not None
            or args.policy != "reject" or args.scheduler != "fifo"):
        admission = AdmissionConfig(
            queue_limit=args.queue_limit, policy=args.policy,
            scheduler=args.scheduler, deadline_ms=args.deadline_ms)
    engine = ServeEngine(model, params, batch=args.batch,
                         max_seq=args.max_seq,
                         temperature=args.temperature,
                         decode_block=args.decode_block,
                         prefill_chunk=args.prefill_chunk,
                         mesh=mesh, admission=admission)

    if args.scenario:
        trace_args = {
            "poisson": dict(n=args.requests, rate=200.0),
            "bursty": dict(n_bursts=max(args.requests // 8, 1),
                           burst_size=8, gap_s=0.25),
            "ramp": dict(n=args.requests, rate0=5.0, rate1=400.0),
        }[args.scenario]
        sc = TRACES[args.scenario](
            vocab_size=cfg.vocab_size, seed=args.scenario_seed,
            deadline_ms=args.deadline_ms, **trace_args)
        rep = replay(engine, sc, k=args.decode_block)
        print(f"[serve] scenario={rep.scenario} policy={rep.policy}/"
              f"{rep.scheduler} K={rep.k} submitted={rep.submitted} "
              f"by_status={rep.by_status}")

        def _ms(x):
            return "-" if x is None else f"{1e3 * x:.1f}ms"
        print(f"[serve] goodput={rep.goodput_tok_s:.1f} tok/s "
              f"ttft p50/p99={_ms(rep.ttft_p50)}/{_ms(rep.ttft_p99)} "
              f"tpt p50/p99={_ms(rep.tpt_p50)}/{_ms(rep.tpt_p99)} "
              f"accounting_ok={rep.accounting_ok}")
        if not rep.accounting_ok:
            raise SystemExit("[serve] accounting identity violated")
        return

    key = jax.random.PRNGKey(1)
    for i in range(args.requests):
        key, sub = jax.random.split(key)
        prompt = jax.random.randint(
            sub, (args.prompt_len,), 0, cfg.vocab_size).tolist()
        engine.submit(prompt, max_new_tokens=args.max_new)

    t0 = time.perf_counter()
    results = engine.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in results)
    print(f"[serve] {len(results)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s)")
    for r in results[:3]:
        print(f"  req {r.request_id}: {r.tokens[:12]}...")


if __name__ == "__main__":
    main()
