"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt /tmp/ckpt

On a real TPU pod this runs under the production mesh with the same
sharding specs the dry-run validated; on CPU (``--reduced``) it runs the
same code path end-to-end with the smoke mesh — checkpoint/restart,
watchdog and heartbeat included.  Multi-host init (``jax.distributed``)
is activated by the standard TPU env vars when present.
"""

from __future__ import annotations

import argparse
import dataclasses
import os

import jax
from jax.sharding import PartitionSpec as P


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced (smoke) config for CPU runs")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--data", default="affine",
                    choices=["affine", "uniform", "zipf"])
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if "TPU_PROCESS_BOUNDS" in os.environ:      # multi-host pod
        jax.distributed.initialize()

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.data import SyntheticConfig, SyntheticStream
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_production_mesh, make_smoke_mesh
    from repro.models import build_model
    from repro.optim import AdamWConfig, Schedule, adamw_init, opt_state_specs
    from repro.train import (TrainLoopConfig, make_train_step,
                             run_train_loop, train_state_init)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_smoke_mesh())
    if args.production_mesh:
        cfg = dataclasses.replace(cfg, batch_axes=shd.dp_axes(mesh))
    model = build_model(cfg)
    opt_cfg = AdamWConfig(
        schedule=Schedule(peak_lr=args.lr, warmup_steps=20,
                          decay_steps=args.steps),
        m_dtype="bfloat16" if cfg.fsdp else "float32",
        factored_v=cfg.fsdp)

    with mesh:
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_specs = shd.param_specs(cfg, mesh, params_shapes)
        o_specs = opt_state_specs(opt_cfg, params_shapes, p_specs)
        state_sh = {
            "params": jax.tree.map(lambda s: shd.named(mesh, s), p_specs,
                                   is_leaf=lambda x: isinstance(x, P)),
            "opt": jax.tree.map(lambda s: shd.named(mesh, s), o_specs,
                                is_leaf=lambda x: isinstance(x, P)),
        }
        state = jax.jit(
            lambda k: train_state_init(model, opt_cfg, k),
            out_shardings=state_sh)(jax.random.PRNGKey(0))
        step_fn = jax.jit(
            make_train_step(model, opt_cfg, accum_steps=args.accum,
                            dp_axes=shd.dp_axes(mesh)),
            donate_argnums=(0,))
        stream = SyntheticStream(cfg, shape, SyntheticConfig(kind=args.data))
        loop_cfg = TrainLoopConfig(
            total_steps=args.steps,
            checkpoint_dir=args.ckpt,
            checkpoint_every=max(args.steps // 4, 10))
        state, history = run_train_loop(step_fn, state, stream, loop_cfg)
    print(f"[train] done: final loss {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
