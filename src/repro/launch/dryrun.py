import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax-importing import — jax locks
the device count at first init.  (They are intentionally before the
module docstring's imports, per the deployment spec.)

For each cell this:
  1. builds parameter / optimizer / batch / cache ShapeDtypeStructs
     (``jax.eval_shape`` — no allocation),
  2. lowers the step function under the production mesh with explicit
     in/out shardings from ``repro.distributed.sharding``,
  3. compiles, and extracts cost_analysis / memory_analysis / collective
     bytes (``repro.core.hlo_analysis``),
  4. computes the three roofline terms vs TPU v5e constants
     (``repro.core.roofline``) and writes
     ``results/dryrun/<arch>__<shape>__<mesh>.json``.

CLI:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all          # every runnable cell, both meshes
"""

import argparse
import json
import subprocess
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Per-arch gradient-accumulation for the train_4k cell: keeps the live
# microbatch activation footprint within HBM (the dry-run memory analysis
# verifies this).  global_batch 256 / accum 8 = 32 >= dp size on both meshes.
TRAIN_ACCUM_STEPS = 8


def _specs_to_shardings(mesh, tree):
    from repro.distributed.sharding import named
    return jax.tree.map(lambda s: named(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def optimized_overrides(shape_kind: str, seq_len: int,
                        n_heads: int = 0, model_axis: int = 16
                        ) -> Dict[str, Any]:
    """The §Perf-adopted beyond-baseline settings per shape kind:

    * single-chunk attention for 4k training (kills the online-softmax
      scan-carry round-trips, measured -18% memory),
    * fp8 KV storage for decode (measured -33% memory),
    * context-parallel attention when the head count cannot shard on the
      model axis (llama3.2's 24 heads / gemma's 8 on 16-way TP leave the
      whole mixer replicated: measured -83% compute / -85% memory,
      MFU 0.021 -> 0.135 on llama3.2 train).
    """
    out: Dict[str, Any] = {}
    if shape_kind == "train" and seq_len <= 4096:
        out["attn_chunk"] = seq_len
    if shape_kind == "decode":
        out["cache_dtype"] = "float8_e4m3fn"
    if (shape_kind in ("train", "prefill") and n_heads > 0
            and n_heads % model_axis != 0):
        out["attn_seq_shard"] = True
    return out


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               extra: Optional[Dict[str, Any]] = None,
               variant: str = "baseline"):
    """Returns (step_fn_jitted, example_args (SDS), meta) for one cell."""
    from repro.configs import get_config, get_shape
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model
    from repro.models.model import batch_fields, batch_spec, decode_inputs_spec
    from repro.optim import AdamWConfig, adamw_init, opt_state_specs
    from repro.train import make_train_step

    import dataclasses
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if variant == "optimized":
        cfg = dataclasses.replace(
            cfg, **optimized_overrides(shape.kind, shape.seq_len,
                                       n_heads=cfg.n_heads))
    if extra:
        cfg = dataclasses.replace(cfg, **extra)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = dataclasses.replace(cfg, batch_axes=shd.dp_axes(mesh))
    model = build_model(cfg)
    opt_cfg = AdamWConfig(
        m_dtype="bfloat16" if cfg.fsdp else "float32",
        factored_v=cfg.fsdp)

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = shd.param_specs(cfg, mesh, params_shapes)
    p_shardings = _specs_to_shardings(mesh, p_specs)

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "pod2x16x16" if multi_pod else "pod16x16",
            "chips": 512 if multi_pod else 256,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count()}

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(lambda p: adamw_init(opt_cfg, p),
                                    params_shapes)
        o_specs = opt_state_specs(opt_cfg, params_shapes, p_specs)
        state_shapes = {"params": params_shapes, "opt": opt_shapes}
        state_shardings = {"params": p_shardings,
                           "opt": _specs_to_shardings(mesh, o_specs)}
        b_specs = shd.batch_specs(cfg, shape, mesh,
                                  batch_fields(cfg, shape))
        b_shardings = _specs_to_shardings(mesh, b_specs)
        step = make_train_step(model, opt_cfg,
                               accum_steps=TRAIN_ACCUM_STEPS,
                               dp_axes=shd.dp_axes(mesh),
                               accum_dtype="bfloat16" if cfg.fsdp
                               else "float32")
        metric_keys = ("loss", "ce", "acc", "moe_lb_loss", "moe_z_loss",
                       "moe_dropped", "grad_norm")
        out_shardings = (state_shardings,
                         {k: _specs_to_shardings(mesh, P())
                          for k in metric_keys})
        jitted = jax.jit(step, in_shardings=(state_shardings, b_shardings),
                         out_shardings=out_shardings, donate_argnums=(0,))
        args = (state_shapes, batch_spec(cfg, shape))
        meta["tokens"] = shape.tokens
        meta["step_kind"] = "train_step"
        return mesh, jitted, args, meta

    if shape.kind == "prefill":
        b_specs = shd.batch_specs(cfg, shape, mesh,
                                  batch_fields(cfg, shape))
        b_shardings = _specs_to_shardings(mesh, b_specs)

        def prefill(params, batch):
            return model.prefill(params, batch, shape.seq_len)

        with mesh:   # tracing hits with_sharding_constraint
            out_shapes = jax.eval_shape(prefill, params_shapes,
                                        batch_spec(cfg, shape))
        logits_spec = P(shd.dp_axes(mesh), None)
        cache_specs_ = shd.cache_specs(cfg, mesh, out_shapes[1])
        out_shardings = (_specs_to_shardings(mesh, logits_spec),
                         _specs_to_shardings(mesh, cache_specs_))
        jitted = jax.jit(prefill, in_shardings=(p_shardings, b_shardings),
                         out_shardings=out_shardings)
        args = (params_shapes, batch_spec(cfg, shape))
        meta["tokens"] = shape.tokens
        meta["step_kind"] = "prefill_step"
        return mesh, jitted, args, meta

    # decode
    cache_shapes, token_s, pos_s = decode_inputs_spec(cfg, shape)
    c_specs = shd.cache_specs(cfg, mesh, cache_shapes)
    c_shardings = _specs_to_shardings(mesh, c_specs)
    tok_sharding = _specs_to_shardings(
        mesh, P(shd._maybe(mesh, shape.global_batch, shd.dp_axes(mesh))))
    logits_spec = P(shd.dp_axes(mesh) if shape.global_batch > 1 else None,
                    None)
    out_shardings = (_specs_to_shardings(mesh, logits_spec), c_shardings)
    jitted = jax.jit(
        model.decode_step,
        in_shardings=(p_shardings, c_shardings, tok_sharding, tok_sharding),
        out_shardings=out_shardings, donate_argnums=(1,))
    args = (params_shapes, cache_shapes, token_s, pos_s)
    meta["tokens"] = shape.global_batch       # one new token per row
    meta["step_kind"] = "serve_step"
    return mesh, jitted, args, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "results/dryrun", verbose: bool = True,
             variant: str = "baseline") -> Dict[str, Any]:
    from repro.core import (TPU_V5E, analyze_compiled, build_report)

    t0 = time.time()
    mesh, jitted, args, meta = build_cell(arch, shape_name, multi_pod,
                                          variant=variant)
    meta["variant"] = variant
    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        stats = analyze_compiled(compiled)

    chips = meta["chips"]
    n_active = meta["active_params"]
    if meta["step_kind"] == "train_step":
        model_flops = 6.0 * n_active * meta["tokens"]
    else:
        model_flops = 2.0 * n_active * meta["tokens"]
    report = build_report(
        cell=f"{arch}/{shape_name}/{meta['mesh']}",
        stats=stats, device=TPU_V5E, chips=chips,
        dtype="bfloat16", model_flops=model_flops)

    result = {
        **meta,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": stats.flops,
        "bytes_per_device": stats.bytes_accessed,
        "collective_bytes": stats.collectives.total_bytes,
        "collective_by_kind": dict(stats.collectives.bytes_by_kind),
        "collective_counts": dict(stats.collectives.count_by_kind),
        "memory": {
            "argument_bytes": stats.argument_bytes,
            "output_bytes": stats.output_bytes,
            "temp_bytes": stats.temp_bytes,
            "peak_bytes": stats.peak_bytes,
        },
        "structure": vars(stats.structure),
        "roofline": {
            "compute_s": report.compute_s,
            "memory_s": report.memory_s,
            "collective_s": report.collective_s,
            "dominant": report.dominant,
            "bound_s": report.bound_s,
            "model_flops": report.model_flops,
            "useful_ratio": report.useful_ratio,
            "mfu": report.mfu,
        },
    }
    if verbose:
        mm = result["memory"]
        print(f"[dryrun] {result['arch']:26s} {result['shape']:12s} "
              f"{result['mesh']:10s} compile {t_compile:6.1f}s  "
              f"args {mm['argument_bytes']/2**30:7.2f} GiB  "
              f"temp {mm['temp_bytes']/2**30:7.2f} GiB  "
              f"dominant={report.dominant:10s} mfu@bound={report.mfu:.3f}")
        print(f"         memory_analysis: {mem}")

    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{result['mesh']}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "optimized"])
    args = ap.parse_args()

    if args.all:
        # one subprocess per cell: fresh XLA state, bounded memory
        from repro.configs import all_cells
        failures = []
        for cfg, shape, ok, why in all_cells():
            for mp in (False, True):
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                if not ok:
                    print(f"[dryrun] SKIP {cfg.name}/{shape.name}/"
                          f"{mesh_name}: {why}")
                    continue
                fname = os.path.join(
                    args.out, f"{cfg.name}__{shape.name}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(fname):
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", cfg.name, "--shape", shape.name,
                       "--out", args.out, "--variant", args.variant]
                if mp:
                    cmd.append("--multi-pod")
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    failures.append((cfg.name, shape.name, mesh_name))
        if failures:
            print("FAILED cells:", failures)
            sys.exit(1)
        print("[dryrun] all cells passed")
        return

    assert args.arch and args.shape, "--arch/--shape or --all"
    run_cell(args.arch, args.shape, args.multi_pod, args.out,
             variant=args.variant)


if __name__ == "__main__":
    main()
