"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init.

Axes:
  single-pod : (16, 16)      -> ('data', 'model')        = 256 chips
  multi-pod  : (2, 16, 16)   -> ('pod', 'data', 'model') = 512 chips

'pod' composes with 'data' for the batch dimension (DP across pods — the
gradient all-reduce crossing 'pod' is the DCN-equivalent hop in a real
deployment) and with FSDP parameter sharding for the >=52B archs.
"""

from __future__ import annotations

import math
from typing import Tuple

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape: Tuple[int, ...] = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under launch/dryrun.py (sets "
            "--xla_force_host_platform_device_count=512)")
    # more devices than the mesh needs (e.g. 512 forced, single-pod 256)
    return Mesh(np.array(devices[:n]).reshape(shape), axes)


def make_smoke_mesh(model_axis: int = 1) -> Mesh:
    """Tiny mesh over whatever devices exist (tests / examples)."""
    devices = jax.devices()
    n = len(devices)
    assert n % model_axis == 0
    return Mesh(np.array(devices).reshape(n // model_axis, model_axis),
                ("data", "model"))


def make_serving_mesh(shape) -> "Mesh | None":
    """Mesh for ``ServeEngine(mesh=...)`` from a shape spec.

    ``shape``: None (single-device engine, returns None), an int or
    1-tuple (pure tensor parallel: axis ('model',)), or a 2-tuple
    (('data', 'model') — slots over 'data', heads/vocab over 'model').
    Also accepts a "2x2"-style string (the CLI/benchmark ``--mesh``
    flag).  Uses the first prod(shape) devices, so it composes with
    ``--xla_force_host_platform_device_count``."""
    if shape is None:
        return None
    if isinstance(shape, str):
        shape = tuple(int(p) for p in shape.lower().split("x"))
    if isinstance(shape, int):
        shape = (shape,)
    shape = tuple(int(s) for s in shape)
    if len(shape) not in (1, 2):
        raise ValueError(f"serving mesh shape must be 1-D or 2-D, "
                         f"got {shape}")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for serving mesh {shape}, have "
            f"{len(devices)} — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} (before jax "
            f"initializes) or shrink the mesh")
    axes = ("model",) if len(shape) == 1 else ("data", "model")
    return Mesh(np.array(devices[:n]).reshape(shape), axes)
