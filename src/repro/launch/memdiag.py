import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Memory diagnosis for one dry-run cell: histogram of the largest tensor
shapes in the optimized (partitioned) HLO — the 'profile' used by the
§Perf hillclimb loop to localize per-device memory blowups.

    PYTHONPATH=src python -m repro.launch.memdiag --arch llama3.2-3b \
        --shape train_4k [--multi-pod] [--top 20]
"""

import argparse
import collections
import re


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--min-mib", type=float, default=64.0)
    args = ap.parse_args()

    from repro.launch.dryrun import build_cell

    mesh, jitted, cell_args, meta = build_cell(
        args.arch, args.shape, args.multi_pod)
    with mesh:
        compiled = jitted.lower(*cell_args).compile()
        txt = compiled.as_text()
        mem = compiled.memory_analysis()

    from repro import compat

    pat = re.compile(r"\b(f32|bf16|f16|f8e4m3fn|f8e5m2|f6e2m3fn|f6e3m2fn"
                     r"|f4e2m1fn|s32|u32|s16|s8|u8|pred)"
                     r"\[([0-9,]+)\]")
    # sub-byte HBM stores are accounted at the compat registry's *packed*
    # bytes/element (fp4 0.5, fp6 0.75) — the previous table charged
    # f4e2m1fn a full byte, double-counting every fp4 weight/KV tensor
    # in the per-device profile this tool exists to localize
    bytes_per = {"f32": 4.0, "s32": 4.0, "u32": 4.0, "bf16": 2.0,
                 "f16": 2.0, "s16": 2.0, "f8e4m3fn": 1.0, "f8e5m2": 1.0,
                 "s8": 1.0, "u8": 1.0, "pred": 1.0}
    for hlo_name, reg_name in (("f4e2m1fn", "float4_e2m1fn"),
                               ("f6e2m3fn", "float6_e2m3fn"),
                               ("f6e3m2fn", "float6_e3m2fn")):
        bytes_per[hlo_name] = compat.storage_bytes_per_element(
            reg_name, packed=True)

    counts = collections.Counter()
    for m in pat.finditer(txt):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * bytes_per[dt]
        if b >= args.min_mib * 2**20:
            counts[f"{dt}[{dims}]"] += 1

    print(f"cell {meta['arch']}/{meta['shape']}/{meta['mesh']}  "
          f"args={mem.argument_size_in_bytes/2**30:.2f} GiB  "
          f"temp={mem.temp_size_in_bytes/2**30:.2f} GiB")
    print(f"{'size':>10s} {'refs':>5s}  shape")
    for k, c in counts.most_common(args.top):
        dt, dims = k.split("[")
        n = 1
        for d in dims[:-1].split(","):
            n *= int(d)
        print(f"{n*bytes_per[dt]/2**30:8.2f}G {c:5d}  {k}")


if __name__ == "__main__":
    main()
