"""Serving stack: samplers, quantization, batched engine, admission
control, fault injection, speculative decoding, and the traffic
scenario harness."""

from repro.serve.sampler import (  # noqa: F401
    fold_slot_keys,
    sample_token,
    sample_tokens,
    sample_tokens_chunk,
)
from repro.serve.spec import SpecConfig  # noqa: F401
from repro.serve.quant import (  # noqa: F401
    LOW_PRECISION_FORMATS,
    dequantize_blockwise,
    dequantize_tree,
    invalidate_format_table,
    quantize_blockwise,
    quantize_params,
    quantize_tree,
)
from repro.serve.admission import (  # noqa: F401
    AdmissionConfig,
    AdmissionQueue,
    POLICIES,
    QueueFull,
    SCHEDULERS,
)
from repro.serve.faults import FAULT_KINDS  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    GenerationResult,
    STATUSES,
    ServeEngine,
)
from repro.serve.traffic import (  # noqa: F401
    Arrival,
    Scenario,
    ScenarioReport,
    bursty_trace,
    overload_ramp_trace,
    poisson_trace,
    replay,
)
