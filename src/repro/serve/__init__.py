"""Serving stack: samplers, quantization, batched engine."""

from repro.serve.sampler import (  # noqa: F401
    fold_slot_keys,
    sample_token,
    sample_tokens,
)
from repro.serve.quant import (  # noqa: F401
    LOW_PRECISION_FORMATS,
    dequantize_blockwise,
    dequantize_tree,
    invalidate_format_table,
    quantize_blockwise,
    quantize_params,
    quantize_tree,
)
from repro.serve.engine import ServeEngine, GenerationResult  # noqa: F401
