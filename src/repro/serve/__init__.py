"""Serving stack: samplers, quantization, batched engine."""

from repro.serve.sampler import sample_token  # noqa: F401
from repro.serve.quant import (  # noqa: F401
    LOW_PRECISION_FORMATS,
    dequantize_blockwise,
    quantize_blockwise,
    quantize_params,
)
from repro.serve.engine import ServeEngine, GenerationResult  # noqa: F401
