"""Admission control for the serving engine: bounded queue, overload
policies, deadlines, and pluggable scheduling.

The engine's queue used to be an unbounded FIFO deque — fine for
pre-enqueued benchmark request sets, wrong under real traffic: overload
grows the queue without bound, every queued request eventually runs (long
after its answer stopped mattering), and "measured p99" silently becomes
"p99 of an infinite backlog".  This module makes the overload behaviour
an explicit, *accounted* policy choice:

* **Bounded queue** — ``queue_limit`` caps queued (not in-flight)
  requests.  What happens at the cap is the ``policy``:

  - ``"reject"``      the NEW request is shed (finishes immediately with
                      ``status="shed"``, zero tokens) — classic
                      admission control; protects queued work.
  - ``"shed_oldest"`` the oldest queued request is shed and the new one
                      admitted — freshest-work-wins; bounds queueing
                      delay at the cost of wasted earlier arrivals.
  - ``"block"``       ``submit()`` raises :class:`QueueFull` — explicit
                      backpressure to the caller, who owns the retry
                      (the traffic harness re-offers on the next tick).

* **Deadlines** — a request can carry an absolute deadline (engine
  ``submit(deadline_ms=...)``, measured on the engine's clock).  Expired
  *queued* requests are dropped at admission time (no prefill is ever
  spent on them); expired *in-flight* requests are cancelled through the
  engine's one jitted cancel state-write and finish as
  ``status="deadline_exceeded"`` with their partial tokens.

* **Scheduling** — ``scheduler`` picks which queued request a freed slot
  takes: ``"fifo"`` (arrival order) or ``"spf"`` (shortest-prompt-first:
  smallest decoder trunk wins; ties resolve FIFO).  SPF minimizes mean
  TTFT under mixed prompt lengths at the cost of long-prompt starvation
  — which the deadline mechanism then surfaces as explicit
  ``deadline_exceeded`` results instead of silent unbounded waiting.

Everything here is host-side bookkeeping: no policy decision touches a
traced value, so one engine serves every (policy, scheduler, deadline)
combination with the exact same compiled executables (the scenario
sanitizer asserts this).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional, Tuple

POLICIES = ("reject", "shed_oldest", "block")
SCHEDULERS = ("fifo", "spf")


class QueueFull(RuntimeError):
    """Raised by ``submit()`` under ``policy="block"`` when the queue is
    at ``queue_limit`` — backpressure is the caller's to handle."""


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission/overload policy for a :class:`~repro.serve.ServeEngine`.

    ``queue_limit=None`` with FIFO scheduling and no default deadline is
    exactly the pre-admission-control engine behaviour."""

    queue_limit: Optional[int] = None      # None = unbounded
    policy: str = "reject"                 # at the limit: see POLICIES
    scheduler: str = "fifo"                # freed-slot pick: fifo | spf
    deadline_ms: Optional[float] = None    # default per-request deadline

    def __post_init__(self) -> None:
        if self.policy not in POLICIES:
            raise ValueError(f"policy {self.policy!r} not in {POLICIES}")
        if self.scheduler not in SCHEDULERS:
            raise ValueError(
                f"scheduler {self.scheduler!r} not in {SCHEDULERS}")
        if self.queue_limit is not None and self.queue_limit < 1:
            raise ValueError("queue_limit must be >= 1 (or None)")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError("deadline_ms must be positive (or None)")


class AdmissionQueue:
    """Bounded request queue enforcing one :class:`AdmissionConfig`.

    Items are engine ``_Request`` objects (anything exposing
    ``request_id``, ``trunk_len`` and ``deadline_s``); the queue never
    touches device state, so swapping configs between scenario runs
    costs zero recompiles."""

    def __init__(self, cfg: Optional[AdmissionConfig] = None):
        self.cfg = cfg or AdmissionConfig()
        self._q: Deque = collections.deque()

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __iter__(self):
        return iter(self._q)

    # -- enqueue -------------------------------------------------------- #
    def offer(self, req) -> Tuple[bool, List]:
        """Try to enqueue ``req``; returns ``(accepted, shed)``.

        ``shed`` lists requests the overload policy dropped — the new
        one under ``"reject"`` (then ``accepted`` is False), the oldest
        queued one under ``"shed_oldest"``.  ``"block"`` raises
        :class:`QueueFull` instead of shedding."""
        lim = self.cfg.queue_limit
        if lim is None or len(self._q) < lim:
            self._q.append(req)
            return True, []
        if self.cfg.policy == "reject":
            return False, [req]
        if self.cfg.policy == "shed_oldest":
            oldest = self._q.popleft()
            self._q.append(req)
            return True, [oldest]
        raise QueueFull(
            f"queue at limit {lim} (policy=block): retry after the "
            f"engine drains")

    # -- dequeue -------------------------------------------------------- #
    def take(self, now: float) -> Tuple[Optional[object], List]:
        """Pop the next admittable request per the scheduler; returns
        ``(request_or_None, expired)`` where ``expired`` are queued
        requests whose deadline passed before a slot freed up — they
        must be finished as ``deadline_exceeded`` without prefill."""
        expired: List = []
        while True:
            live = [r for r in self._q
                    if r.deadline_s is not None and now >= r.deadline_s]
            for r in live:
                self._q.remove(r)
                expired.append(r)
            if not self._q:
                return None, expired
            if self.cfg.scheduler == "spf":
                req = min(self._q, key=lambda r: r.trunk_len)
                self._q.remove(req)
            else:
                req = self._q.popleft()
            return req, expired

    def remove(self, request_id: int):
        """Pull a specific queued request (``cancel`` path); None if the
        id is not queued."""
        for r in self._q:
            if r.request_id == request_id:
                self._q.remove(r)
                return r
        return None

    def drain(self) -> List:
        """Empty the queue, returning the stranded requests (engine
        flush path: they finish as shed)."""
        out = list(self._q)
        self._q.clear()
        return out
