"""Token samplers (fp32 logits in, int32 token out)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample_token(logits: jax.Array, key: Optional[jax.Array] = None,
                 temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits (b, v) -> tokens (b,).  temperature 0 = greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert key is not None, "sampling needs a PRNG key"
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)
