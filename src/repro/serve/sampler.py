"""Token samplers (fp32 logits in, int32 token out).

Two entry points:

* :func:`sample_token` — host-driven sampling with one key per call (the
  original per-step engine path, kept for API stability and tests).
* :func:`sample_tokens` — trace-safe batched sampling for the fused
  decode loop.  Instead of splitting a host-held key per step (a device
  round trip per token), each row's key is **folded** from a base key
  plus per-slot data (``slot_seed``, ``pos``).  The fold makes sampling
  deterministic per (engine seed, request, position) — independent of
  batch composition, of which pool slot the request landed in, and of
  whether tokens were produced by the fused K-token loop or K single
  steps.  That last property is what lets the equivalence tests cover
  the sampled path, not just greedy.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _top_k_filter(logits: jax.Array, top_k: int) -> jax.Array:
    vals, _ = jax.lax.top_k(logits, top_k)
    cutoff = vals[..., -1:]
    return jnp.where(logits < cutoff, -jnp.inf, logits)


def sample_token(logits: jax.Array, key: Optional[jax.Array] = None,
                 temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits (b, v) -> tokens (b,).  temperature 0 = greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert key is not None, "sampling needs a PRNG key"
    logits = logits / temperature
    if top_k > 0:
        logits = _top_k_filter(logits, top_k)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def fold_slot_keys(key: jax.Array, slot_seed: jax.Array,
                   pos: jax.Array) -> jax.Array:
    """Per-row keys: ``fold_in(fold_in(key, slot_seed[i]), pos[i])``.

    slot_seed: (b,) int32 per-request seed (the engine uses the request
    id); pos: (b,) int32 position the sampled token will occupy.  Both
    folds are trace-safe, so this runs inside the jitted decode loop.
    """
    def fold(seed, p):
        return jax.random.fold_in(jax.random.fold_in(key, seed), p)
    return jax.vmap(fold)(slot_seed.astype(jnp.int32),
                          pos.astype(jnp.int32))


def sample_tokens(logits: jax.Array, key: Optional[jax.Array] = None,
                  temperature: float = 0.0, top_k: int = 0,
                  slot_seed: Optional[jax.Array] = None,
                  pos: Optional[jax.Array] = None,
                  logits_sharding=None) -> jax.Array:
    """Batched in-loop sampling: logits (b, v) -> tokens (b,).

    Greedy (temperature 0) needs no key.  Otherwise each row samples
    under its own folded key (see :func:`fold_slot_keys`); when
    ``slot_seed``/``pos`` are omitted it falls back to one shared key
    (rows still sample independently via ``jax.random.categorical``).

    ``logits_sharding``: optional NamedSharding (normally the fully
    replicated ``distributed.sharding.logits_spec``) constrained onto
    the logits before sampling — THE sample-point gather of a
    mesh-sharded engine.  Decode leaves logits vocab-sharded over
    'model' (the unembed placement); argmax and the per-row folded
    categorical must each see every vocab column and produce one
    mesh-independent token stream, so the all-gather happens here,
    exactly once, and the token/bookkeeping arithmetic downstream of it
    is replicated — which is what keeps ``fold_slot_keys`` sampling
    batch- and mesh-independent.
    """
    if logits_sharding is not None:
        logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert key is not None, "sampling needs a PRNG key"
    logits = logits / temperature
    if top_k > 0:
        logits = _top_k_filter(logits, top_k)
    if slot_seed is None or pos is None:
        return jax.random.categorical(key, logits).astype(jnp.int32)
    keys = fold_slot_keys(key, slot_seed, pos)
    return jax.vmap(
        lambda k, l: jax.random.categorical(k, l))(keys, logits
                                                   ).astype(jnp.int32)


def sample_tokens_chunk(logits: jax.Array, key: Optional[jax.Array] = None,
                        temperature: float = 0.0, top_k: int = 0,
                        slot_seed: Optional[jax.Array] = None,
                        pos: Optional[jax.Array] = None,
                        logits_sharding=None) -> jax.Array:
    """Verify-time sampling: logits (b, s, v) -> tokens (b, s).

    The speculative verify pass produces one logits row per drafted
    position; every row samples under the SAME per-(request, position)
    folded key :func:`sample_tokens` would have used for that position
    (``slot_seed`` (b,), ``pos`` (b, s) — the position each sampled
    token will occupy).  That identity is the whole correctness story:
    the token at position p is a pure function of (engine seed, request,
    p, logits), so a speculative engine emits the same stream as the
    non-speculative loop whenever the verify logits match the per-step
    logits — drafts only decide how many of these tokens are valid.
    """
    if logits_sharding is not None:
        logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert key is not None, "sampling needs a PRNG key"
    logits = logits / temperature
    if top_k > 0:
        logits = _top_k_filter(logits, top_k)
    if slot_seed is None or pos is None:
        return jax.random.categorical(key, logits).astype(jnp.int32)

    def row(seed, row_pos, row_logits):                # (s,), (s, v)
        keys = jax.vmap(lambda p: jax.random.fold_in(
            jax.random.fold_in(key, seed), p))(row_pos.astype(jnp.int32))
        return jax.vmap(jax.random.categorical)(keys, row_logits)

    return jax.vmap(row)(slot_seed.astype(jnp.int32), pos,
                         logits).astype(jnp.int32)
