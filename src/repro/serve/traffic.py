"""Traffic scenario harness: deterministic arrival traces replayed
through :class:`~repro.serve.ServeEngine`.

The ROADMAP's "continuous batching under real traffic" item needs the
engine measured under Poisson/bursty arrivals and overload, not on
pre-enqueued request sets.  This module generates seeded arrival traces
(mixed prompt/output lengths from ``repro.data.synthetic.host_prompt``)
and replays them against an engine, producing a :class:`ScenarioReport`
with TTFT and per-token p50/p99, goodput, and exact status accounting.

Determinism discipline (lint rule JL104): every random choice here is
seeded **host** NumPy (``np.random.default_rng``) — wall-clock and RNG
never appear in traced scope, so the same (scenario, seed) replays the
identical trace on every machine.  The replay clock is injectable:

* ``step_cost_s=None`` (default) — **wall mode**: arrivals are released
  against measured elapsed time; latencies are real.  This is what the
  benchmark uses.
* ``step_cost_s=x`` — **virtual mode**: the clock advances ``x`` per
  fused decode step (plus ``prefill_cost_s`` per admission).  Fully
  deterministic — tests assert exact shed/deadline accounting with it.

The replay drives the SAME fused executables as steady-state serving:
one engine instance sweeps every (policy, K) cell with zero recompiles
(``benchmarks/serve_scenarios.py`` asserts this with CompileCounter).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.data.synthetic import host_prompt
from repro.serve.admission import AdmissionConfig, QueueFull


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request in a trace: arrival time (seconds from scenario
    start) plus the request shape."""
    t: float
    prompt: List[int]
    max_new_tokens: int
    deadline_ms: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, fully-determined arrival trace."""
    name: str
    seed: int
    arrivals: Sequence[Arrival]

    @property
    def duration(self) -> float:
        return self.arrivals[-1].t if self.arrivals else 0.0


def _mk_arrivals(name: str, seed: int, times: np.ndarray,
                 vocab_size: int, prompt_lens: Sequence[int],
                 output_lens: Sequence[int],
                 deadline_ms: Optional[float]) -> Scenario:
    rng = np.random.default_rng(seed ^ 0x5EED)
    arrivals = []
    for i, t in enumerate(times):
        plen = int(rng.choice(prompt_lens))
        olen = int(rng.choice(output_lens))
        arrivals.append(Arrival(
            t=float(t),
            prompt=host_prompt(plen, seed=seed * 100003 + i,
                               vocab_size=vocab_size),
            max_new_tokens=olen, deadline_ms=deadline_ms))
    return Scenario(name=name, seed=seed, arrivals=tuple(arrivals))


def poisson_trace(n: int, rate: float, vocab_size: int, seed: int = 0,
                  prompt_lens: Sequence[int] = (4, 8, 16, 24),
                  output_lens: Sequence[int] = (4, 8, 16),
                  deadline_ms: Optional[float] = None) -> Scenario:
    """``n`` arrivals with exponential inter-arrival gaps at ``rate``
    requests/second — the memoryless baseline every queueing result is
    stated against."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return _mk_arrivals(f"poisson_r{rate:g}", seed, np.cumsum(gaps),
                        vocab_size, prompt_lens, output_lens, deadline_ms)


def bursty_trace(n_bursts: int, burst_size: int, gap_s: float,
                 vocab_size: int, seed: int = 0,
                 prompt_lens: Sequence[int] = (4, 8, 16, 24),
                 output_lens: Sequence[int] = (4, 8, 16),
                 deadline_ms: Optional[float] = None) -> Scenario:
    """``n_bursts`` bursts of ``burst_size`` simultaneous arrivals,
    ``gap_s`` apart — the pattern that exposes queue-depth spikes and
    head-of-line blocking that a smooth Poisson average hides."""
    times = np.repeat(np.arange(n_bursts) * gap_s, burst_size)
    return _mk_arrivals(f"bursty_{n_bursts}x{burst_size}", seed, times,
                        vocab_size, prompt_lens, output_lens, deadline_ms)


def overload_ramp_trace(n: int, rate0: float, rate1: float,
                        vocab_size: int, seed: int = 0,
                        prompt_lens: Sequence[int] = (4, 8, 16, 24),
                        output_lens: Sequence[int] = (4, 8, 16),
                        deadline_ms: Optional[float] = None) -> Scenario:
    """Arrival rate ramping linearly from ``rate0`` to ``rate1``
    requests/second across ``n`` arrivals — crosses the capacity knee
    mid-trace, so one run measures underload, saturation, and overload
    (where the admission policy, not throughput, decides behaviour)."""
    rng = np.random.default_rng(seed)
    rates = np.linspace(rate0, rate1, n)
    gaps = rng.exponential(1.0, size=n) / rates
    return _mk_arrivals(f"ramp_r{rate0:g}-{rate1:g}", seed,
                        np.cumsum(gaps), vocab_size, prompt_lens,
                        output_lens, deadline_ms)


TRACES = {"poisson": poisson_trace, "bursty": bursty_trace,
          "ramp": overload_ramp_trace}


def _pct(xs: List[float], q: float) -> Optional[float]:
    return float(np.percentile(xs, q)) if xs else None


@dataclasses.dataclass
class ScenarioReport:
    """Replay outcome: tails, goodput, exact accounting."""
    scenario: str
    k: int
    policy: str
    scheduler: str
    submitted: int
    by_status: Dict[str, int]
    elapsed_s: float
    tokens_ok: int               # tokens of status="ok" results only
    tokens_total: int            # all delivered tokens incl. partials
    goodput_tok_s: float         # tokens_ok / elapsed
    ttft_p50: Optional[float]    # seconds, over results with a first
    ttft_p99: Optional[float]    # token (admitted at all)
    tpt_p50: Optional[float]     # per-token decode seconds, over "ok"
    tpt_p99: Optional[float]     # results with >= 2 tokens
    accounting_ok: bool          # submitted == sum(by_status)

    def row(self) -> Dict:
        """Flat dict — one BENCH_serve scenario row."""
        return dataclasses.asdict(self)


def replay(engine, scenario: Scenario, k: Optional[int] = None,
           admission: Optional[AdmissionConfig] = None,
           step_cost_s: Optional[float] = None,
           max_wall_s: float = 120.0,
           max_ticks: int = 100_000) -> ScenarioReport:
    """Replay ``scenario`` through ``engine`` and measure it.

    The engine is reset first; ``admission`` (if given) replaces its
    policy — host-side only, so sweeping (policy, scheduler, deadline)
    combinations costs zero recompiles.  ``step_cost_s=None`` uses real
    wall time; a float switches to the deterministic virtual clock
    (every decode tick charges ``step_cost_s * k``, or one
    ``step_cost_s`` when the tick could not dispatch — the clock always
    advances, so deadlines expire and the replay terminates).

    ``block``-policy arrivals that hit :class:`QueueFull` are re-offered
    on the next tick — the backpressure contract: the caller owns the
    retry.  If the wall/tick guard trips first, still-queued requests
    are drained as ``shed`` and in-flight ones flushed as ``truncated``
    so accounting stays exact; never-submitted arrivals (still pending
    or blocked) are simply not counted as submitted."""
    engine.reset()
    if admission is not None:
        engine.set_admission(admission)
    k = k or engine.decode_block
    virtual = step_cost_s is not None
    clock = _VirtualClock() if virtual else _WallClock()
    engine.set_clock(clock.now)

    pending = list(scenario.arrivals)       # trace order = time order
    blocked: List[Arrival] = []
    ticks = 0
    while pending or blocked or engine.queue or engine._any_active():
        ticks += 1
        if ticks > max_ticks or (not virtual
                                 and clock.now() > max_wall_s):
            break
        t = clock.now()
        due = [a for a in pending if a.t <= t]
        pending = [a for a in pending if a.t > t]
        retry, blocked = blocked, []
        for a in retry + due:
            try:
                engine.submit(a.prompt, a.max_new_tokens,
                              deadline_ms=a.deadline_ms)
            except QueueFull:
                blocked.append(a)
        if engine.queue or engine._any_active():
            d0 = engine._dispatches
            engine.decode_loop(k)
            if virtual:
                dispatched = engine._dispatches > d0
                clock.advance(step_cost_s * (k if dispatched else 1))
        elif pending:
            # idle gap: fast-forward (virtual) / nap (wall) to the
            # next arrival instead of busy-spinning submit checks
            nxt = min(a.t for a in pending)
            if virtual:
                clock.advance(max(nxt - clock.now(), step_cost_s))
            else:
                time.sleep(min(max(nxt - clock.now(), 0.0), 0.01))

    # guard tripped: drain to a fully-accounted terminal state
    for req in engine.queue.drain():
        engine._finish_unadmitted(req, "shed")
    if engine._any_active():
        engine.run(max_steps=0)             # flush partials: truncated

    elapsed = max(clock.now(), 1e-9)
    res = engine.results
    by_status: Dict[str, int] = {}
    for r in res:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    ttfts = [r.ttft for r in res if r.ttft is not None]
    tpts = [(r.finish_t - r.first_token_t) / (len(r.tokens) - 1)
            for r in res
            if r.status == "ok" and r.first_token_t is not None
            and r.finish_t is not None and len(r.tokens) >= 2]
    tokens_ok = sum(len(r.tokens) for r in res if r.status == "ok")
    tokens_total = sum(len(r.tokens) for r in res)
    acc = engine.accounting()
    cfg = engine.queue.cfg
    return ScenarioReport(
        scenario=scenario.name, k=k, policy=cfg.policy,
        scheduler=cfg.scheduler, submitted=acc["submitted"],
        by_status=by_status, elapsed_s=elapsed, tokens_ok=tokens_ok,
        tokens_total=tokens_total, goodput_tok_s=tokens_ok / elapsed,
        ttft_p50=_pct(ttfts, 50), ttft_p99=_pct(ttfts, 99),
        tpt_p50=_pct(tpts, 50), tpt_p99=_pct(tpts, 99),
        accounting_ok=(acc["balanced"] and acc["in_flight"] == 0
                       and acc["queued"] == 0))


class _VirtualClock:
    """Deterministic replay clock: advances only when charged."""

    def __init__(self) -> None:
        self._t = 0.0

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        self._t += dt


class _WallClock:
    """Measured clock, zeroed at replay start."""

    def __init__(self) -> None:
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def advance(self, dt: float) -> None:  # pragma: no cover
        raise RuntimeError("wall clock cannot be advanced")
