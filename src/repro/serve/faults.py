"""Fault injection for the serving stack, paired with the in-loop
sentinel that detects the detectable class.

The source paper's first discipline is to characterize failure modes
before trusting any number (§IV.A/§IV.B); our failure modes are numeric:
a NaN/Inf escaping a matmul, an e8m0 scale byte overflowing to the inf
code, a flipped bit in a packed KV byte.  This module injects each class
on demand so the recovery path is *testable*, and names exactly which
classes the engine's device-side sentinel can and cannot see:

=================  ==============================  =====================
fault kind         mechanism                       detected by
=================  ==============================  =====================
``logits_nan``     NaN written over one slot's     sentinel (non-finite
                   logits row at an armed           reduce in the scan
                   position (data-driven, in the    body)
                   compiled scan body — no
                   recompile)
``logits_inf``     same, with +inf                 sentinel
``e8m0_overflow``  every e8m0 scale byte of the    sentinel — code 0xFF
                   slot's ring KV set to the        decodes to 2^128 =
                   overflow code 0xFF (what an      inf in fp32, so the
                   inf/overflowed quantizer         next attention read
                   input would store)               goes non-finite
``kv_bitflip``     XOR over the slot's packed KV   usually NOT — an
                   bytes: scale bytes (``k_s``,     XOR'd e8m0 code is a
                   default) or code bytes           wrong-but-FINITE
                   (``k_q``)                        scale (100^0xFF=155
                                                    → 2^28), and code
                                                    flips decode finite:
                                                    SILENT corruption
                                                    unless a downstream
                                                    op happens to
                                                    overflow
``state_inf``      the slot's recurrent state      sentinel — inf state
                   row (SSM conv/ssd) set to inf    propagates to the
                                                    logits within a step
=================  ==============================  =====================

The sentinel is a per-slot non-finite reduce over the logits *inside*
the fused scan body, carried out through the emitted-token mask — no
extra host sync, no recompile (see ``ServeEngine._make_decode_loop``).
A detected slot stops advancing within the same block, finishes as
``status="faulted"`` at the block boundary, and is re-initialized
through the existing ``clear_slot`` eviction path; every other in-flight
slot's stream is bit-identical to an uninjected run (row-independent
numerics — the isolation tests pin this per arch family).

The same taxonomy arms the SPECULATIVE loop (``ServeEngine(spec=...)``)
at token granularity: a logits fault poisons the verify-logits row
whose sampling position equals the armed ``fault_pos``, and the
sentinel reduce runs per verify row.  A poisoned row inside the
accepted prefix truncates acceptance there — tokens before it commit,
EMIT_FAULT follows them, and the slot recovers through the same
block-boundary ``clear_slot`` path.  A poisoned row in the REJECTED
tail (drafted-but-not-accepted positions) is discarded with the tail:
the fault stays armed and fires when decoding actually reaches that
position, exactly as the non-speculative loop would.  Cache poisons in
a drafted-but-rejected ring region are likewise harmless by
construction — rejected rows are never written to the target cache, so
there is nothing poisoned to read back (the speculative bitflip test
pins survivor isolation).

The honest gap: a ``kv_bitflip`` that decodes to a finite wrong value —
which is the COMMON case for both scale and code bytes — passes the
sentinel: silent data corruption, visible only as a diverged token
stream.  That is a property of non-finite sentinels everywhere, not of
this one; the test suite pins the miss (status stays ``ok`` while the
tokens differ from the uninjected oracle) so the gap stays documented
instead of assumed away.  The guaranteed-detectable cache faults are
``e8m0_overflow`` and ``state_inf``, whose poison decodes to inf by
construction.

Cache poisoners here are pure jnp functions over the slot-state cache
tree (slot traced), so the engine jits each exactly once.
"""

from __future__ import annotations

from typing import Iterator, Tuple

import jax
import jax.numpy as jnp

# in-body fault codes carried in the engine's device slot state
# (state["fault_kind"]); 0 = disarmed
FAULT_NONE = 0
FAULT_NAN = 1
FAULT_INF = 2
LOGITS_FAULTS = {"logits_nan": FAULT_NAN, "logits_inf": FAULT_INF}

# e8m0 code 0xFF decodes to 2^(255-127) = 2^128 -> inf in fp32: the
# stored image of an overflowed quantizer input (repro.lowbits clamps
# encodes to 254, so 255 can only appear through corruption)
E8M0_OVERFLOW_CODE = 255

CACHE_FAULTS = ("e8m0_overflow", "kv_bitflip", "state_inf")
FAULT_KINDS = tuple(LOGITS_FAULTS) + CACHE_FAULTS


def _ring_parts(cache: dict) -> Iterator[Tuple[str, str, dict]]:
    """Yield ``(entry, part, tree)`` for every ring part (has a
    ``slot_pos`` leaf) of a slot-state cache, self-attn KV first."""
    for pref in (lambda p: p == "kv", lambda p: p != "kv"):
        for name, entry in cache.items():
            if not isinstance(entry, dict):
                continue
            for part, tree in entry.items():
                if (isinstance(tree, dict) and "slot_pos" in tree
                        and pref(part)):
                    yield name, part, tree


def _recurrent_parts(cache: dict) -> Iterator[Tuple[str, str, dict]]:
    for name, entry in cache.items():
        if not isinstance(entry, dict):
            continue
        for part, tree in entry.items():
            if isinstance(tree, dict) and "slot_pos" not in tree:
                yield name, part, tree


def _with_leaf(cache: dict, entry: str, part: str, leaf: str,
               new_leaf: jax.Array) -> dict:
    out = dict(cache)
    out[entry] = dict(cache[entry])
    out[entry][part] = dict(cache[entry][part], **{leaf: new_leaf})
    return out


def overflow_e8m0_scales(cache: dict, slot: jax.Array) -> dict:
    """Overflow the slot's e8m0 K-scale bytes in the first quantized
    ring part: every ``k_s`` byte becomes 0xFF (scale 2^128 = inf), the
    exact storage an overflowed quantizer input would leave behind.
    Runs jitted with ``slot`` traced."""
    for name, part, tree in _ring_parts(cache):
        if "k_s" in tree:
            ks = tree["k_s"]
            return _with_leaf(
                cache, name, part, "k_s",
                ks.at[:, slot].set(jnp.uint8(E8M0_OVERFLOW_CODE)))
    raise ValueError(
        "e8m0_overflow needs a quantized KV cache (no ring part with "
        "k_s scale bytes found) — use kv_format=... or a logits fault")


def flip_kv_bytes(cache: dict, slot: jax.Array, leaf: str = "k_s",
                  xor: int = 0xFF) -> dict:
    """XOR the slot's packed KV bytes in the first quantized ring part.

    ``leaf="k_s"`` flips e8m0 scale bytes (complementing a code gives a
    wrong-but-finite scale, e.g. 100^0xFF=155 → 2^28); ``leaf="k_q"``
    flips packed value codes.  Both are typically SILENT corruption —
    the sentinel only fires if the damage overflows downstream (see
    module docstring).  Runs jitted with ``slot`` traced."""
    for name, part, tree in _ring_parts(cache):
        if leaf in tree:
            buf = tree[leaf]
            as_u8 = buf.dtype == jnp.uint8
            bits = buf if as_u8 else jax.lax.bitcast_convert_type(
                buf, jnp.uint8)
            row = bits[:, slot] ^ jnp.uint8(xor)
            bits = bits.at[:, slot].set(row)
            new = bits if as_u8 else jax.lax.bitcast_convert_type(
                bits, buf.dtype)
            return _with_leaf(cache, name, part, leaf, new)
    raise ValueError(
        f"kv_bitflip needs a quantized ring KV part with a {leaf!r} "
        f"leaf — use kv_format=... or a logits fault")


def poison_recurrent_state(cache: dict, slot: jax.Array) -> dict:
    """Set the slot's row of the first recurrent part (SSM conv/ssd
    state) to +inf — the storage image of an overflowed state update.
    Runs jitted with ``slot`` traced."""
    for name, part, tree in _recurrent_parts(cache):
        out = dict(cache)
        out[name] = dict(cache[name])
        out[name][part] = jax.tree.map(
            lambda a: a.at[:, slot].set(
                jnp.full_like(a[:, 0], jnp.inf)), tree)
        return out
    raise ValueError(
        "state_inf needs a recurrent cache part (SSM/hybrid arch) — "
        "use a KV or logits fault")


CACHE_POISONERS = {
    "e8m0_overflow": overflow_e8m0_scales,
    "kv_bitflip": flip_kv_bytes,
    "state_inf": poison_recurrent_state,
}
