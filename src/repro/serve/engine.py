"""Batched serving engine: device-resident hot loop with continuous
batching.

A fixed pool of ``batch`` slots shares one cache pytree.  The paper's
first discipline is to characterize measurement and dispatch overhead
before trusting any number (§IV.A/§IV.B), and its §VI.D story is that
decode is memory-bound — batching exists to amortize reads.  A serving
loop that pays a host↔device round trip per generated token therefore
measures *dispatch latency*, not the HBM roofline this repo models.  So
the hot path is device-resident:

* **On-device slot state** — ``pos`` / ``remaining`` / ``last_token`` /
  ``active`` / per-request RNG ``seed`` live in device arrays (the
  ``state`` pytree), not host-side Python bookkeeping.
* **Fused multi-token decode** — :meth:`decode_loop` runs K decode
  steps in ONE dispatch: a jitted ``lax.scan`` whose body fuses
  decode → sample → (quantized) cache-write → slot bookkeeping.
  Inactive slots are masked end to end: they neither sample nor write
  (KV ring, slot_pos, and SSM state all hold), so a slot finishing
  mid-loop rides along at zero state cost.  Host code touches tokens
  once per K steps instead of once per token.
* **Chunked pooled prefill for every arch** — admission writes prompt
  chunks directly into the slot's pool region inside a jitted step
  (quantize-on-write for ``kv_format`` caches): ceil(prompt/chunk)
  dispatches of one compiled executable, with no host-side
  rematerialization of the whole cache pytree.  The per-slot
  slot-state protocol (``repro.models.slotstate``) extends this to
  every mixer: SSM/hybrid archs carry conv/ssm state across chunk
  boundaries, enc-dec archs encode once into slot-resident
  enc_out/cross-KV (one ``encode_slot`` dispatch, then the decoder
  prompt chunks), and VLM patch prefixes stream through the same
  chunk executable as precomputed embeddings.  There is no width-1
  prefill or host-side slot scatter anywhere — the fused-loop speedup
  applies to every config in ``repro.configs``.

Sampling inside the loop folds per-slot keys from (request id,
position) — see ``serve.sampler.sample_tokens`` — so token streams are
deterministic per request regardless of batch composition, pool slot,
or whether they came from the fused loop or per-step dispatches.  That
is what makes the fused-vs-per-step equivalence testable for sampled
decoding, not just greedy.

Weight storage: with ``weight_format`` set, the engine keeps its weights
in true quantized storage (``serve.quant.quantize_tree`` — bit-packed
0.5 B/elem fp4 / 0.75 B/elem fp6 via ``repro.lowbits`` when
``packed=True``) as the HBM-resident source of truth, and materializes
the dense compute copy the XLA path consumes.  ``weight_stats`` carries
the *measured* stored-byte counts the Tab VIII benchmark reports.

KV storage: with ``kv_format`` set, the pooled decode cache itself is
blockwise-quantized (``repro.models.attention``: packed fp8/fp4 codes +
1-byte e8m0 scales, quantize-on-write inside the jitted step) — at long
context the KV read, not the weights, dominates decode HBM traffic
(§VI.D).  ``kv_stats`` carries the measured stored KV bytes.  The XLA
decode step materializes a dense dequantized view per layer, so off-TPU
the win is *footprint*; the streaming read win belongs to the Pallas
leg (``repro.kernels.flash_decode_quant``).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.distributed import sharding as shard_rules
from repro.models.model import Model, build_model
from repro.serve.quant import dequantize_tree, quantize_tree
from repro.serve.sampler import sample_tokens


@dataclasses.dataclass
class GenerationResult:
    request_id: int
    prompt: List[int]
    tokens: List[int]
    truncated: bool = False       # run() step budget hit mid-generation


@dataclasses.dataclass
class _Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int
    frames: Optional[np.ndarray] = None    # enc-dec source embeddings
    patches: Optional[np.ndarray] = None   # VLM patch-prefix embeddings

    @property
    def trunk_len(self) -> int:
        """Decoder-trunk length: VLM patch prefix + text tokens."""
        n_pat = 0 if self.patches is None else self.patches.shape[0]
        return n_pat + len(self.prompt)


class ServeEngine:
    """See module docstring.  ``decode_block`` is K, the number of decode
    steps fused into one dispatch by :meth:`run` (1 = the per-token
    dispatch pattern, kept as the measurable baseline — that leg is what
    ``benchmarks/serve_throughput.py`` compares against)."""

    def __init__(self, model: Model, params, batch: int, max_seq: int,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 weight_format: Optional[str] = None, packed: bool = True,
                 kv_format=None, compute_dtype=jnp.bfloat16,
                 decode_block: int = 16, prefill_chunk: int = 32,
                 enc_len: Optional[int] = None,
                 mesh: Optional[Mesh] = None):
        if kv_format:
            # rebind the model onto a config whose cache layer quantizes:
            # every prefill/decode below then writes packed codes +
            # 1-byte e8m0 scales instead of full-width K/V.  A
            # tuple/list sets PER-POSITION-IN-PERIOD formats
            # (cfg.kv_formats — e.g. fp8 global / fp4 local layers).
            if isinstance(kv_format, (tuple, list)):
                model = build_model(dataclasses.replace(
                    model.cfg, kv_formats=tuple(kv_format)))
            else:
                model = build_model(
                    dataclasses.replace(model.cfg, kv_format=kv_format))
        self.model = model
        self.kv_format = kv_format
        self.weight_store = None
        self.weight_stats: Optional[Dict] = None
        if weight_format is not None:
            self.weight_store, self.weight_stats = quantize_tree(
                params, weight_format, packed=packed)
            params = dequantize_tree(self.weight_store, compute_dtype)
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self._temperature = temperature
        self._top_k = top_k
        self.decode_block = max(int(decode_block), 1)
        self._chunked = model.supports_chunked_prefill   # always True now
        self.prefill_chunk = max(
            1, min(int(prefill_chunk), model.min_cache_capacity(max_seq)))
        # enc-dec pools pad every request's source frames to one fixed
        # enc_len so the encode/decode executables compile exactly once
        self.enc_len = ((enc_len or max_seq)
                        if model.cfg.is_encoder_decoder else 0)
        # base sampling key; per-token keys are FOLDED from (request id,
        # position) inside the jitted loop — never split on the host
        self._sample_key = jax.random.PRNGKey(seed)

        self.cache = model.init_cache(batch, max_seq, enc_len=self.enc_len)
        # measured KV storage accounting (codes + scales, what a decode
        # step actually reads) — reported by Tab VIII next to weights
        self.kv_stats: Dict = model.kv_cache_stats(self.cache)

        # host-side request bookkeeping (no per-token state here)
        self.slot_req: List[Optional[_Request]] = [None] * batch
        self.out_tokens: List[List[int]] = [[] for _ in range(batch)]
        self.queue: Deque[_Request] = collections.deque()
        self.results: List[GenerationResult] = []
        self._next_id = 0

        # device-resident slot state
        self.state = self._init_state()

        # mesh-native placement: with a mesh, EVERY array the engine owns
        # gets an explicit NamedSharding from the distributed/sharding
        # rules (params per _param_rule, KV/cross-KV/SSM pools per
        # cache_rule, packed weight store re-fitted onto stored layouts,
        # slot state replicated) before the first executable is built —
        # the jits below then pin their outputs to the same placements,
        # so steady-state serving never triggers a resharding transfer.
        # mesh=None is the exact single-device engine (no placement, no
        # out_shardings, byte-identical dispatch path).
        self.mesh = mesh
        self._sh: Optional[Dict] = None
        if mesh is not None:
            self._sh = shard_rules.serving_shardings(
                model.cfg, mesh, self.params, self.cache, self.state,
                self.weight_store)
            self.params = jax.device_put(self.params, self._sh["params"])
            if self.weight_store is not None:
                self.weight_store = shard_rules.device_put_store(
                    self.weight_store, self._sh["weights"])
            self.cache = jax.device_put(self.cache, self._sh["cache"])
            self.state = jax.device_put(self.state, self._sh["state"])
            self._sample_key = jax.device_put(self._sample_key,
                                              self._sh["replicated"])

        # jitted executables (shared across reset(); decode loops are
        # cached per fused length K).  One executable per admission step
        # kind — token chunks, embed chunks (VLM), encode (enc-dec) —
        # each compiled exactly once (the sanitizer asserts this).
        repl = self._sh["replicated"] if mesh is not None else None
        cache_sh = self._sh["cache"] if mesh is not None else None
        state_sh = self._sh["state"] if mesh is not None else None
        self._loops: Dict[int, jax.stages.Wrapped] = {}
        self._prefill_chunk_fn = self._jit(model.prefill_chunk,
                                           (repl, cache_sh))
        if model.cfg.frontend == "vision":
            self._prefill_embeds_fn = self._jit(
                lambda p, c, emb, slot, off, vl: model.prefill_chunk(
                    p, c, jnp.zeros((emb.shape[1],), jnp.int32), slot,
                    off, vl, embeds=emb),
                (repl, cache_sh))
        if model.cfg.is_encoder_decoder:
            self._encode_slot_fn = self._jit(model.encode_slot, cache_sh)
        self._clear_slot_fn = self._jit(model.clear_slot, cache_sh)
        self._admit_fn = self._jit(self._admit_update, (repl, state_sh))

    def _jit(self, fn, out_shardings=None):
        """jax.jit, pinning outputs to their serving shardings when the
        engine is mesh-native (mesh=None compiles exactly as before)."""
        if self.mesh is None:
            return jax.jit(fn)
        return jax.jit(fn, out_shardings=out_shardings)

    def _host_read(self, x) -> np.ndarray:
        """The engine's ONE designed device→host sync point per dispatch.

        Mesh-native outputs are replicated (their jits pin P() output
        shardings), so shard 0 already holds the full array — read it
        through the single-device buffer path instead of np.asarray on
        the multi-device Array (which routes through ``._value``, i.e.
        an implicit cross-device fetch the sanitizer rightly counts)."""
        if self.mesh is not None:
            return np.asarray(x.addressable_data(0))
        return np.asarray(x)

    # sampling params are traced INTO the compiled loop/admit
    # executables — mutating them after construction would be silently
    # ignored by the cached jits, so they are read-only (build a new
    # engine to change them)
    @property
    def temperature(self) -> float:
        return self._temperature

    @property
    def top_k(self) -> int:
        return self._top_k

    # -- device state --------------------------------------------------- #
    def _init_state(self) -> Dict[str, jax.Array]:
        b = self.batch
        return {"pos": jnp.zeros((b,), jnp.int32),
                "remaining": jnp.zeros((b,), jnp.int32),
                "last_token": jnp.zeros((b,), jnp.int32),
                "active": jnp.zeros((b,), bool),
                "seed": jnp.zeros((b,), jnp.int32)}

    def reset(self) -> None:
        """Clear all serving state (cache, slots, queue, results) while
        keeping compiled executables — benchmark legs reuse one engine so
        recompilation never pollutes a timed region."""
        self.cache = self.model.init_cache(self.batch, self.max_seq,
                                           enc_len=self.enc_len)
        self.state = self._init_state()
        if self.mesh is not None:
            self.cache = jax.device_put(self.cache, self._sh["cache"])
            self.state = jax.device_put(self.state, self._sh["state"])
        self.slot_req = [None] * self.batch
        self.out_tokens = [[] for _ in range(self.batch)]
        self.queue = collections.deque()
        self.results = []
        self._next_id = 0

    # -- request management -------------------------------------------- #
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               frames=None, patches=None) -> int:
        """Enqueue a request.

        ``frames`` ((s_src, d_model) float) — REQUIRED for enc-dec archs:
        the source-side frontend embeddings, padded on-device to the
        pool's fixed ``enc_len``.  ``patches`` ((n_patches, d_model)
        float) — optional VLM patch-prefix embeddings, prepended to the
        decoder trunk (early fusion) and streamed through the chunked
        prefill as precomputed embeddings.

        Prompts must leave room for at least one generated token: a
        trunk of ``max_seq`` or longer used to be admitted anyway,
        setting ``pos`` past the cache so the first decode step attended
        over a silently clipped prefill."""
        cfg = self.model.cfg
        if cfg.is_encoder_decoder:
            if frames is None:
                raise ValueError(
                    f"{cfg.name} is encoder-decoder: submit() needs "
                    f"frames=(s_src, d_model) source embeddings")
            frames = np.asarray(frames)
            if frames.ndim != 2 or frames.shape[0] < 1:
                raise ValueError(f"frames must be (s_src, d_model); got "
                                 f"{frames.shape}")
            if frames.shape[0] > self.enc_len:
                raise ValueError(
                    f"source length {frames.shape[0]} > pool enc_len "
                    f"{self.enc_len}: raise ServeEngine(enc_len=...)")
        elif frames is not None:
            raise ValueError(f"{cfg.name} is not encoder-decoder: "
                             f"frames= is not accepted")
        if patches is not None:
            if cfg.frontend != "vision":
                raise ValueError(f"{cfg.name} has no vision frontend: "
                                 f"patches= is not accepted")
            patches = np.asarray(patches)
        req = _Request(self._next_id, list(prompt), max_new_tokens,
                       frames=frames, patches=patches)
        if req.trunk_len >= self.max_seq:
            raise ValueError(
                f"trunk length {req.trunk_len} (prompt + patch prefix) "
                f">= max_seq {self.max_seq}: the cache holds max_seq-1 "
                f"prompt tokens plus the decode stream; truncate the "
                f"prompt or raise max_seq")
        self._next_id += 1
        self.queue.append(req)
        return req.request_id

    def _admit_update(self, state, logits, slot, plen, max_new, rid, key):
        """Jitted per-admission state write: sample the first token from
        the prefill logits (same (rid, pos) key fold as the loop) and set
        the slot's device state.  One dispatch per admission."""
        tok = sample_tokens(logits, key, self.temperature, self.top_k,
                            slot_seed=rid[None], pos=plen[None])[0]
        active = max_new > 1
        return tok, {
            "pos": state["pos"].at[slot].set(plen),
            "remaining": state["remaining"].at[slot].set(max_new - 1),
            "last_token": state["last_token"].at[slot].set(tok),
            "active": state["active"].at[slot].set(active),
            "seed": state["seed"].at[slot].set(rid),
        }

    def _prefill_into_slot(self, slot: int, req: _Request) -> jax.Array:
        """Build the slot's cache region through the slot-state protocol;
        returns last-prompt-position logits (1, vocab).

        Every arch admits the same way: evict the previous tenant's ring
        bookkeeping (``clear_slot``), run the per-request one-shot legs
        (enc-dec: one ``encode_slot`` dispatch writing slot-resident
        enc_out + quantized cross-KV), then stream the decoder trunk —
        VLM patch-embedding chunks first, token chunks after — straight
        into the pool region (jitted; quantize-on-write for kv_format
        caches; SSM conv/state carried across chunk boundaries)."""
        self.cache = self._clear_slot_fn(self.cache, jnp.int32(slot))
        cdtype = jnp.dtype(self.model.cfg.compute_dtype)
        chunk = self.prefill_chunk
        if req.frames is not None:
            src = req.frames.shape[0]
            padded = np.zeros((1, self.enc_len, req.frames.shape[1]),
                              np.float32)
            padded[0, :src] = req.frames
            self.cache = self._encode_slot_fn(
                self.params, self.cache, jnp.asarray(padded, cdtype),
                jnp.int32(slot), jnp.int32(src))
        offset, logits = 0, None
        if req.patches is not None:
            n_pat = req.patches.shape[0]
            for off in range(0, n_pat, chunk):
                part = req.patches[off:off + chunk]
                valid = part.shape[0]
                padded = np.zeros((1, chunk, part.shape[1]), np.float32)
                padded[0, :valid] = part
                logits, self.cache = self._prefill_embeds_fn(
                    self.params, self.cache, jnp.asarray(padded, cdtype),
                    jnp.int32(slot), jnp.int32(off), jnp.int32(valid))
            offset = n_pat
        for off in range(0, len(req.prompt), chunk):
            part = req.prompt[off:off + chunk]
            valid = len(part)
            part = part + [0] * (chunk - valid)
            logits, self.cache = self._prefill_chunk_fn(
                self.params, self.cache,
                jnp.asarray(part, jnp.int32), jnp.int32(slot),
                jnp.int32(offset + off), jnp.int32(valid))
        return logits

    def _admit(self) -> None:
        for slot in range(self.batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            logits = self._prefill_into_slot(slot, req)
            tok, self.state = self._admit_fn(
                self.state, logits, jnp.int32(slot),
                jnp.int32(req.trunk_len), jnp.int32(req.max_new_tokens),
                jnp.int32(req.request_id), self._sample_key)
            self.slot_req[slot] = req
            self.out_tokens[slot] = [int(self._host_read(tok))]
            if req.max_new_tokens <= 1:
                self._finish(slot)

    # -- fused decode --------------------------------------------------- #
    def _make_decode_loop(self, k: int):
        """Jit the K-step fused loop: decode → sample → cache-write →
        bookkeeping inside one ``lax.scan``, emitting (tokens (k, b),
        emitted-mask (k, b)) plus the advanced cache/state."""
        model = self.model
        temp, top_k, max_seq = self.temperature, self.top_k, self.max_seq
        # mesh-native: decode leaves logits vocab-sharded over 'model'
        # (the unembed placement); the sample point is the loop's ONE
        # all-gather, after which tokens and bookkeeping are replicated
        logits_sh = self._sh["logits"] if self.mesh is not None else None

        def loop(params, cache, state, key):
            def body(carry, _):
                cache, st = carry
                active = st["active"]
                logits, cache = model.decode_step(
                    params, cache, st["last_token"], st["pos"],
                    active=active)
                nxt = st["pos"] + 1
                tok = sample_tokens(logits, key, temp, top_k,
                                    slot_seed=st["seed"], pos=nxt,
                                    logits_sharding=logits_sh)
                tok = jnp.where(active, tok, st["last_token"])
                new_pos = jnp.where(active, nxt, st["pos"])
                new_rem = st["remaining"] - active.astype(jnp.int32)
                finished = active & ((new_rem <= 0)
                                     | (new_pos >= max_seq - 1))
                st = {"pos": new_pos, "remaining": new_rem,
                      "last_token": tok, "active": active & ~finished,
                      "seed": st["seed"]}
                return (cache, st), (tok, active)

            (cache, state), (toks, emitted) = jax.lax.scan(
                body, (cache, state), xs=None, length=k)
            return cache, state, toks, emitted

        if self.mesh is None:
            return jax.jit(loop)
        return jax.jit(loop, out_shardings=(
            self._sh["cache"], self._sh["state"],
            self._sh["replicated"], self._sh["replicated"]))

    def _any_active(self) -> bool:
        return any(r is not None for r in self.slot_req)

    def _max_remaining(self) -> int:
        """Largest token budget left among in-flight slots (host-known:
        max_new_tokens minus tokens already emitted).  run() caps the
        fused block with this so the tail dispatch runs exactly the
        iterations it needs — without it, finishing a 23-token request
        with K=16 blocks would burn 9 fully-masked (but fully-costed)
        scan iterations."""
        rem = 0
        for slot, req in enumerate(self.slot_req):
            if req is not None:
                rem = max(rem,
                          req.max_new_tokens - len(self.out_tokens[slot]))
        return max(rem, 1)

    def _finish(self, slot: int, truncated: bool = False) -> None:
        req = self.slot_req[slot]
        self.results.append(GenerationResult(
            req.request_id, req.prompt, self.out_tokens[slot],
            truncated=truncated))
        self.slot_req[slot] = None

    def _dispatch(self, k: int) -> None:
        """One fused dispatch of K decode steps + one host sync for its
        K×batch tokens."""
        fn = self._loops.get(k)
        if fn is None:
            fn = self._loops[k] = self._make_decode_loop(k)
        self.cache, self.state, toks, emitted = fn(
            self.params, self.cache, self.state, self._sample_key)
        toks = self._host_read(toks)                  # (k, b) — ONE sync
        emitted = self._host_read(emitted)
        active_after = self._host_read(self.state["active"])
        for slot in range(self.batch):
            if self.slot_req[slot] is None:
                continue
            self.out_tokens[slot].extend(
                int(t) for t, e in zip(toks[:, slot], emitted[:, slot])
                if e)
            if not active_after[slot]:
                self._finish(slot)

    def decode_loop(self, k: Optional[int] = None) -> None:
        """Admit from the queue, then run K fused decode steps in one
        dispatch (K = ``decode_block`` by default)."""
        self._admit()
        if self._any_active():
            self._dispatch(k or self.decode_block)

    def step(self) -> None:
        """One pooled decode step — the per-token dispatch pattern (one
        launch + one host sync per generated token).  Kept as the
        measurable baseline; :meth:`run` uses the fused loop."""
        self.decode_loop(1)

    # -- driver --------------------------------------------------------- #
    def run(self, max_steps: int = 1000) -> List[GenerationResult]:
        """Serve until queue and pool drain or ``max_steps`` decode steps
        have been spent.  On budget exhaustion, in-flight requests are
        FLUSHED as partial results (``truncated=True``) instead of being
        silently dropped."""
        steps = 0
        while steps < max_steps:
            self._admit()
            if not self._any_active():
                if not self.queue:
                    break
                continue
            k = min(self.decode_block, max_steps - steps,
                    self._max_remaining())
            self._dispatch(k)
            steps += k
        if self._any_active():
            # budget hit mid-generation: flush partials and deactivate
            # their device slots so a later run() cannot advance them
            for slot in range(self.batch):
                if self.slot_req[slot] is not None:
                    self._finish(slot, truncated=True)
            self.state = dict(
                self.state,
                active=jnp.zeros_like(self.state["active"]))
        return sorted(self.results, key=lambda r: r.request_id)
