"""Batched serving engine: device-resident hot loop with continuous
batching.

A fixed pool of ``batch`` slots shares one cache pytree.  The paper's
first discipline is to characterize measurement and dispatch overhead
before trusting any number (§IV.A/§IV.B), and its §VI.D story is that
decode is memory-bound — batching exists to amortize reads.  A serving
loop that pays a host↔device round trip per generated token therefore
measures *dispatch latency*, not the HBM roofline this repo models.  So
the hot path is device-resident:

* **On-device slot state** — ``pos`` / ``remaining`` / ``last_token`` /
  ``active`` / per-request RNG ``seed`` live in device arrays (the
  ``state`` pytree), not host-side Python bookkeeping.
* **Fused multi-token decode** — :meth:`decode_loop` runs K decode
  steps in ONE dispatch: a jitted ``lax.scan`` whose body fuses
  decode → sample → (quantized) cache-write → slot bookkeeping.
  Inactive slots are masked end to end: they neither sample nor write
  (KV ring, slot_pos, and SSM state all hold), so a slot finishing
  mid-loop rides along at zero state cost.  Host code touches tokens
  once per K steps instead of once per token.
* **Chunked pooled prefill for every arch** — admission writes prompt
  chunks directly into the slot's pool region inside a jitted step
  (quantize-on-write for ``kv_format`` caches): ceil(prompt/chunk)
  dispatches of one compiled executable, with no host-side
  rematerialization of the whole cache pytree.  The per-slot
  slot-state protocol (``repro.models.slotstate``) extends this to
  every mixer: SSM/hybrid archs carry conv/ssm state across chunk
  boundaries, enc-dec archs encode once into slot-resident
  enc_out/cross-KV (one ``encode_slot`` dispatch, then the decoder
  prompt chunks), and VLM patch prefixes stream through the same
  chunk executable as precomputed embeddings.  There is no width-1
  prefill or host-side slot scatter anywhere — the fused-loop speedup
  applies to every config in ``repro.configs``.

Sampling inside the loop folds per-slot keys from (request id,
position) — see ``serve.sampler.sample_tokens`` — so token streams are
deterministic per request regardless of batch composition, pool slot,
or whether they came from the fused loop or per-step dispatches.  That
is what makes the fused-vs-per-step equivalence testable for sampled
decoding, not just greedy.

Weight storage: with ``weight_format`` set, the engine keeps its weights
in true quantized storage (``serve.quant.quantize_tree`` — bit-packed
0.5 B/elem fp4 / 0.75 B/elem fp6 via ``repro.lowbits`` when
``packed=True``) as the HBM-resident source of truth, and materializes
the dense compute copy the XLA path consumes.  ``weight_stats`` carries
the *measured* stored-byte counts the Tab VIII benchmark reports.

KV storage: with ``kv_format`` set, the pooled decode cache itself is
blockwise-quantized (``repro.models.attention``: packed fp8/fp4 codes +
1-byte e8m0 scales, quantize-on-write inside the jitted step) — at long
context the KV read, not the weights, dominates decode HBM traffic
(§VI.D).  ``kv_stats`` carries the measured stored KV bytes.  The XLA
decode step materializes a dense dequantized view per layer, so off-TPU
the win is *footprint*; the streaming read win belongs to the Pallas
leg (``repro.kernels.flash_decode_quant``).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.distributed import sharding as shard_rules
from repro.models.model import Model, build_model
from repro.serve import faults as fault_lib
from repro.serve import spec as spec_lib
from repro.serve.admission import AdmissionConfig, AdmissionQueue, QueueFull
from repro.serve.quant import dequantize_tree, quantize_tree
from repro.serve.sampler import sample_tokens, sample_tokens_chunk
from repro.serve.spec import SpecConfig

# terminal request states; every submitted request ends in exactly one
STATUSES = ("ok",                  # full generation delivered
            "truncated",           # run() step budget hit mid-generation
            "shed",                # dropped by admission policy / cancel
            "deadline_exceeded",   # deadline passed (queued or in-flight)
            "faulted")             # in-loop sentinel caught non-finite
                                   # logits; slot recovered via clear_slot

# emitted-mask codes carried out of the fused scan per (step, slot)
EMIT_NONE = 0      # slot inactive this step
EMIT_TOKEN = 1     # token sampled and appended
EMIT_FAULT = 2     # sentinel tripped: logits went non-finite


@dataclasses.dataclass
class GenerationResult:
    request_id: int
    prompt: List[int]
    tokens: List[int]
    status: str = "ok"
    submit_t: Optional[float] = None       # engine-clock timestamps
    first_token_t: Optional[float] = None  # (None when not applicable:
    finish_t: Optional[float] = None       # e.g. shed before prefill)

    @property
    def truncated(self) -> bool:
        return self.status == "truncated"

    @property
    def ttft(self) -> Optional[float]:
        if self.submit_t is None or self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t


@dataclasses.dataclass
class _Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int
    frames: Optional[np.ndarray] = None    # enc-dec source embeddings
    patches: Optional[np.ndarray] = None   # VLM patch-prefix embeddings
    submit_t: float = 0.0                  # engine-clock timestamps
    deadline_s: Optional[float] = None     # absolute (engine clock)
    first_token_t: Optional[float] = None

    @property
    def trunk_len(self) -> int:
        """Decoder-trunk length: VLM patch prefix + text tokens."""
        n_pat = 0 if self.patches is None else self.patches.shape[0]
        return n_pat + len(self.prompt)


class ServeEngine:
    """See module docstring.  ``decode_block`` is K, the number of decode
    steps fused into one dispatch by :meth:`run` (1 = the per-token
    dispatch pattern, kept as the measurable baseline — that leg is what
    ``benchmarks/serve_throughput.py`` compares against)."""

    def __init__(self, model: Model, params, batch: int, max_seq: int,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 weight_format: Optional[str] = None, packed: bool = True,
                 kv_format=None, compute_dtype=jnp.bfloat16,
                 decode_block: int = 16, prefill_chunk: int = 32,
                 enc_len: Optional[int] = None,
                 mesh: Optional[Mesh] = None,
                 admission: Optional[AdmissionConfig] = None,
                 clock: Optional[Callable[[], float]] = None,
                 spec: Optional[SpecConfig] = None):
        if kv_format:
            # rebind the model onto a config whose cache layer quantizes:
            # every prefill/decode below then writes packed codes +
            # 1-byte e8m0 scales instead of full-width K/V.  A
            # tuple/list sets PER-POSITION-IN-PERIOD formats
            # (cfg.kv_formats — e.g. fp8 global / fp4 local layers).
            if isinstance(kv_format, (tuple, list)):
                model = build_model(dataclasses.replace(
                    model.cfg, kv_formats=tuple(kv_format)))
            else:
                model = build_model(
                    dataclasses.replace(model.cfg, kv_format=kv_format))
        self.model = model
        self.kv_format = kv_format
        self.weight_store = None
        self.weight_stats: Optional[Dict] = None
        if weight_format is not None:
            self.weight_store, self.weight_stats = quantize_tree(
                params, weight_format, packed=packed)
            params = dequantize_tree(self.weight_store, compute_dtype)
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self._temperature = temperature
        self._top_k = top_k
        self.decode_block = max(int(decode_block), 1)
        self._chunked = model.supports_chunked_prefill   # always True now
        self.prefill_chunk = max(
            1, min(int(prefill_chunk), model.min_cache_capacity(max_seq)))
        # enc-dec pools pad every request's source frames to one fixed
        # enc_len so the encode/decode executables compile exactly once
        self.enc_len = ((enc_len or max_seq)
                        if model.cfg.is_encoder_decoder else 0)
        # base sampling key; per-token keys are FOLDED from (request id,
        # position) inside the jitted loop — never split on the host
        self._sample_key = jax.random.PRNGKey(seed)

        # speculative decoding (repro.serve.spec): the fused loop swaps
        # its 1-token decode body for a draft→verify→commit block.
        # Emitted tokens are ALWAYS the true sampled tokens from the
        # verify logits, so greedy AND sampled streams are token-
        # identical to the non-speculative loop by construction.
        self.spec = spec
        self._spec_loops: Dict[int, jax.stages.Wrapped] = {}
        self._spec_tokens = 0     # host totals for spec_report()
        self._spec_blocks = 0
        self._draft_params = None
        self._draft_cache = None
        if spec is not None and spec.draft_model is not None:
            dm: Model = spec.draft_model
            if mesh is not None:
                raise NotImplementedError(
                    "draft-model speculation is single-device; mesh "
                    "serving supports n-gram drafting")
            dcfg = dm.cfg
            if (dcfg.is_encoder_decoder or dcfg.frontend == "vision"
                    or any(blk.mixer != "attn" or blk.cross_attn
                           for blk in dcfg.block_pattern())):
                raise ValueError(
                    f"draft model {dcfg.name} must be a plain decoder-"
                    f"only attention LM (the draft leg reuses the ring "
                    f"slot_pos rollback, which only attention caches "
                    f"support)")
            if model.cfg.is_encoder_decoder or model.cfg.frontend == "vision":
                raise ValueError(
                    f"draft-model speculation needs a plain decoder-only "
                    f"target (got {model.cfg.name}); n-gram drafting "
                    f"covers the other families")
            if dcfg.vocab_size != model.cfg.vocab_size:
                raise ValueError(
                    f"draft vocab {dcfg.vocab_size} != target vocab "
                    f"{model.cfg.vocab_size}")
            self._draft_params = spec.draft_params
            self._draft_cache = dm.init_cache(batch, max_seq)
            self.prefill_chunk = max(1, min(
                self.prefill_chunk, dm.min_cache_capacity(max_seq)))

        self.cache = model.init_cache(batch, max_seq, enc_len=self.enc_len)
        # measured KV storage accounting (codes + scales, what a decode
        # step actually reads) — reported by Tab VIII next to weights
        self.kv_stats: Dict = model.kv_cache_stats(self.cache)

        # host-side request bookkeeping (no per-token state here).  The
        # queue enforces the admission policy (bounded capacity, overload
        # shedding, deadlines, scheduler) entirely on the host — every
        # (policy, scheduler, deadline) combination reuses the exact same
        # compiled executables.
        self.slot_req: List[Optional[_Request]] = [None] * batch
        self.out_tokens: List[List[int]] = [[] for _ in range(batch)]
        self.queue = AdmissionQueue(admission)
        self.results: List[GenerationResult] = []
        self._next_id = 0
        self._submitted = 0
        self._deadlines_live = False
        # injectable clock (deadlines, TTFT): tests/replays substitute a
        # virtual clock via set_clock for deterministic deadline behaviour
        self._clock: Callable[[], float] = clock or time.monotonic
        # watchdog bookkeeping: per-slot (token_count, dispatch_index)
        # snapshots to detect slots that stay active without progressing
        self._dispatches = 0
        self._slot_progress: List[Tuple[int, int]] = [(0, 0)] * batch

        # device-resident slot state
        self.state = self._init_state()

        # mesh-native placement: with a mesh, EVERY array the engine owns
        # gets an explicit NamedSharding from the distributed/sharding
        # rules (params per _param_rule, KV/cross-KV/SSM pools per
        # cache_rule, packed weight store re-fitted onto stored layouts,
        # slot state replicated) before the first executable is built —
        # the jits below then pin their outputs to the same placements,
        # so steady-state serving never triggers a resharding transfer.
        # mesh=None is the exact single-device engine (no placement, no
        # out_shardings, byte-identical dispatch path).
        self.mesh = mesh
        self._sh: Optional[Dict] = None
        if mesh is not None:
            self._sh = shard_rules.serving_shardings(
                model.cfg, mesh, self.params, self.cache, self.state,
                self.weight_store)
            self.params = jax.device_put(self.params, self._sh["params"])
            if self.weight_store is not None:
                self.weight_store = shard_rules.device_put_store(
                    self.weight_store, self._sh["weights"])
            self.cache = jax.device_put(self.cache, self._sh["cache"])
            self.state = jax.device_put(self.state, self._sh["state"])
            self._sample_key = jax.device_put(self._sample_key,
                                              self._sh["replicated"])

        # jitted executables (shared across reset(); decode loops are
        # cached per fused length K).  One executable per admission step
        # kind — token chunks, embed chunks (VLM), encode (enc-dec) —
        # each compiled exactly once (the sanitizer asserts this).
        repl = self._sh["replicated"] if mesh is not None else None
        cache_sh = self._sh["cache"] if mesh is not None else None
        state_sh = self._sh["state"] if mesh is not None else None
        self._loops: Dict[int, jax.stages.Wrapped] = {}
        self._prefill_chunk_fn = self._jit(model.prefill_chunk,
                                           (repl, cache_sh))
        if model.cfg.frontend == "vision":
            self._prefill_embeds_fn = self._jit(
                lambda p, c, emb, slot, off, vl: model.prefill_chunk(
                    p, c, jnp.zeros((emb.shape[1],), jnp.int32), slot,
                    off, vl, embeds=emb),
                (repl, cache_sh))
        if model.cfg.is_encoder_decoder:
            self._encode_slot_fn = self._jit(model.encode_slot, cache_sh)
        self._clear_slot_fn = self._jit(model.clear_slot, cache_sh)
        if self._draft_cache is not None:
            dm = self.spec.draft_model
            self._draft_prefill_fn = self._jit(dm.prefill_chunk)
            self._draft_clear_fn = self._jit(dm.clear_slot)
        self._admit_fn = self._jit(self._admit_update, (repl, state_sh))
        # cancel / fault-arm share _admit_update's shape: one jitted
        # slot-state write each, compiled at most once, dispatched only
        # when a cancel/deadline/fault actually happens
        self._cancel_fn = self._jit(self._cancel_update, state_sh)
        self._fault_arm_fn = self._jit(self._fault_arm_update, state_sh)
        self._fault_cache_fns: Dict[tuple, jax.stages.Wrapped] = {}
        self._cache_sh = cache_sh

    def _jit(self, fn, out_shardings=None):
        """jax.jit, pinning outputs to their serving shardings when the
        engine is mesh-native (mesh=None compiles exactly as before)."""
        if self.mesh is None:
            return jax.jit(fn)
        return jax.jit(fn, out_shardings=out_shardings)

    def _host_read(self, x) -> np.ndarray:
        """The engine's ONE designed device→host sync point per dispatch.

        Mesh-native outputs are replicated (their jits pin P() output
        shardings), so shard 0 already holds the full array — read it
        through the single-device buffer path instead of np.asarray on
        the multi-device Array (which routes through ``._value``, i.e.
        an implicit cross-device fetch the sanitizer rightly counts)."""
        if self.mesh is not None:
            return np.asarray(x.addressable_data(0))
        return np.asarray(x)

    # sampling params are traced INTO the compiled loop/admit
    # executables — mutating them after construction would be silently
    # ignored by the cached jits, so they are read-only (build a new
    # engine to change them)
    @property
    def temperature(self) -> float:
        return self._temperature

    @property
    def top_k(self) -> int:
        return self._top_k

    # -- device state --------------------------------------------------- #
    def _init_state(self) -> Dict[str, jax.Array]:
        b = self.batch
        # fault_pos/fault_kind arm the in-loop logits fault injector:
        # data-driven (a state write, never a recompile), disarmed at -1/0
        state = {"pos": jnp.zeros((b,), jnp.int32),
                 "remaining": jnp.zeros((b,), jnp.int32),
                 "last_token": jnp.zeros((b,), jnp.int32),
                 "active": jnp.zeros((b,), bool),
                 "seed": jnp.zeros((b,), jnp.int32),
                 "fault_pos": jnp.full((b,), -1, jnp.int32),
                 "fault_kind": jnp.zeros((b,), jnp.int32)}
        if self.spec is not None:
            # per-slot speculation state: n-gram history + table (device-
            # resident drafting, zero host traffic) and acceptance
            # accounting (tokens committed / blocks run for the CURRENT
            # tenant; engine totals live on the host).  Non-speculative
            # engines keep the exact 7-field state above.
            state["spec_hist"] = jnp.full(
                (b, self.spec.ngram_context), -1, jnp.int32)
            state["spec_ngram"] = jnp.full(
                (b, self.spec.ngram_table), -1, jnp.int32)
            state["spec_accept"] = jnp.zeros((b,), jnp.int32)
            state["spec_blocks"] = jnp.zeros((b,), jnp.int32)
        return state

    def reset(self) -> None:
        """Clear all serving state (cache, slots, queue, results) while
        keeping compiled executables — benchmark legs reuse one engine so
        recompilation never pollutes a timed region.  The admission
        config survives; use :meth:`set_admission` to swap policies."""
        self.cache = self.model.init_cache(self.batch, self.max_seq,
                                           enc_len=self.enc_len)
        self.state = self._init_state()
        self._spec_tokens = 0
        self._spec_blocks = 0
        if self._draft_cache is not None:
            self._draft_cache = self.spec.draft_model.init_cache(
                self.batch, self.max_seq)
        if self.mesh is not None:
            self.cache = jax.device_put(self.cache, self._sh["cache"])
            self.state = jax.device_put(self.state, self._sh["state"])
        self.slot_req = [None] * self.batch
        self.out_tokens = [[] for _ in range(self.batch)]
        self.queue = AdmissionQueue(self.queue.cfg)
        self.results = []
        self._next_id = 0
        self._submitted = 0
        self._deadlines_live = False
        self._dispatches = 0
        self._slot_progress = [(0, 0)] * self.batch

    # -- clock / policy injection ---------------------------------------- #
    def _now(self) -> float:
        return self._clock()

    def set_clock(self, clock: Callable[[], float]) -> None:
        """Swap the engine clock (deadlines, TTFT stamps).  Virtual
        clocks make deadline tests and trace replays deterministic."""
        self._clock = clock

    def set_admission(self, cfg: Optional[AdmissionConfig]) -> None:
        """Swap the admission policy.  Pending queued requests are
        re-offered under the new policy (overflow is shed per that
        policy) — device state and compiled executables are untouched,
        so scenario sweeps across policies cost zero recompiles."""
        pending = self.queue.drain()
        self.queue = AdmissionQueue(cfg)
        for req in pending:
            try:
                _, shed = self.queue.offer(req)
            except QueueFull:          # block policy: nobody to retry a
                shed = [req]           # config swap, so overflow sheds
            for s in shed:
                self._finish_unadmitted(s, "shed")
        if cfg is not None and cfg.deadline_ms is not None:
            self._deadlines_live = True

    # -- request management -------------------------------------------- #
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               frames=None, patches=None,
               deadline_ms: Optional[float] = None) -> int:
        """Enqueue a request through the admission policy.

        ``frames`` ((s_src, d_model) float) — REQUIRED for enc-dec archs:
        the source-side frontend embeddings, padded on-device to the
        pool's fixed ``enc_len``.  ``patches`` ((n_patches, d_model)
        float) — optional VLM patch-prefix embeddings, prepended to the
        decoder trunk (early fusion) and streamed through the chunked
        prefill as precomputed embeddings.

        ``deadline_ms`` — relative deadline on the engine clock
        (defaults to the admission config's ``deadline_ms``, if any).
        Expired queued requests finish as ``deadline_exceeded`` without
        ever spending prefill; expired in-flight requests are cancelled
        through the jitted cancel state-write with partial tokens.

        Under a bounded queue the admission policy decides overload:
        ``reject`` finishes the NEW request immediately as ``shed``,
        ``shed_oldest`` sheds the oldest queued request instead, and
        ``block`` raises :class:`QueueFull` (no id is consumed) —
        backpressure belongs to the caller.  Every submitted request is
        accounted: it ends in exactly one :data:`STATUSES` result.

        Prompts must leave room for at least one generated token: a
        trunk of ``max_seq`` or longer used to be admitted anyway,
        setting ``pos`` past the cache so the first decode step attended
        over a silently clipped prefill.  ``max_new_tokens`` must be
        >= 1: admission ALWAYS samples one token from the prefill
        logits, so 0 used to emit a token anyway and write
        ``remaining = -1`` into the slot state."""
        cfg = self.model.cfg
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1 (got {max_new_tokens}): "
                f"admission samples the first token from the prefill "
                f"logits, so a 0-token generation does not exist")
        if cfg.is_encoder_decoder:
            if frames is None:
                raise ValueError(
                    f"{cfg.name} is encoder-decoder: submit() needs "
                    f"frames=(s_src, d_model) source embeddings")
            frames = np.asarray(frames)
            if frames.ndim != 2 or frames.shape[0] < 1:
                raise ValueError(f"frames must be (s_src, d_model); got "
                                 f"{frames.shape}")
            if frames.shape[0] > self.enc_len:
                raise ValueError(
                    f"source length {frames.shape[0]} > pool enc_len "
                    f"{self.enc_len}: raise ServeEngine(enc_len=...)")
        elif frames is not None:
            raise ValueError(f"{cfg.name} is not encoder-decoder: "
                             f"frames= is not accepted")
        if patches is not None:
            if cfg.frontend != "vision":
                raise ValueError(f"{cfg.name} has no vision frontend: "
                                 f"patches= is not accepted")
            patches = np.asarray(patches)
        now = self._now()
        if deadline_ms is None:
            deadline_ms = self.queue.cfg.deadline_ms
        deadline_s = None if deadline_ms is None else now + deadline_ms / 1e3
        req = _Request(self._next_id, list(prompt), max_new_tokens,
                       frames=frames, patches=patches, submit_t=now,
                       deadline_s=deadline_s)
        if req.trunk_len >= self.max_seq:
            raise ValueError(
                f"trunk length {req.trunk_len} (prompt + patch prefix) "
                f">= max_seq {self.max_seq}: the cache holds max_seq-1 "
                f"prompt tokens plus the decode stream; truncate the "
                f"prompt or raise max_seq")
        # offer BEFORE consuming the id: block-policy QueueFull must
        # leave the engine exactly as it was
        accepted, shed = self.queue.offer(req)
        self._next_id += 1
        self._submitted += 1
        if deadline_s is not None:
            self._deadlines_live = True
        for s in shed:
            self._finish_unadmitted(s, "shed")
        return req.request_id

    def _admit_update(self, state, logits, slot, plen, max_new, rid, key,
                      tail=None):
        """Jitted per-admission state write: sample the first token from
        the prefill logits (same (rid, pos) key fold as the loop) and set
        the slot's device state.  One dispatch per admission.

        Speculative engines pass ``tail`` — the last ``prompt_tail``
        prompt tokens, left-padded with -1 — and the slot's n-gram
        history/table is reseeded from it (plus the freshly sampled
        first token) inside the same dispatch."""
        tok = sample_tokens(logits, key, self.temperature, self.top_k,
                            slot_seed=rid[None], pos=plen[None])[0]
        active = max_new > 1
        out = dict(
            state,
            pos=state["pos"].at[slot].set(plen),
            remaining=state["remaining"].at[slot].set(max_new - 1),
            last_token=state["last_token"].at[slot].set(tok),
            active=state["active"].at[slot].set(active),
            seed=state["seed"].at[slot].set(rid),
            fault_pos=state["fault_pos"].at[slot].set(-1),
            fault_kind=state["fault_kind"].at[slot].set(0),
        )
        if self.spec is not None:
            hist, table = spec_lib.seed_from_tail(
                tail, self.spec.ngram_context, self.spec.ngram_table)
            # the first token is already committed — fold it in too
            hist, table = spec_lib.ngram_update(
                hist[None], table[None], tok[None, None],
                jnp.ones((1, 1), bool))
            out["spec_hist"] = state["spec_hist"].at[slot].set(hist[0])
            out["spec_ngram"] = state["spec_ngram"].at[slot].set(table[0])
            out["spec_accept"] = state["spec_accept"].at[slot].set(0)
            out["spec_blocks"] = state["spec_blocks"].at[slot].set(0)
        return tok, out

    def _cancel_update(self, state, slot):
        """Jitted cancel state-write (same shape discipline as
        ``_admit_update``: one dispatch, compiled once): deactivate the
        slot so the next fused block neither samples nor writes for it,
        and disarm any pending fault."""
        return dict(
            state,
            remaining=state["remaining"].at[slot].set(0),
            active=state["active"].at[slot].set(False),
            fault_pos=state["fault_pos"].at[slot].set(-1),
            fault_kind=state["fault_kind"].at[slot].set(0),
        )

    def _fault_arm_update(self, state, slot, pos, kind):
        """Jitted fault-arming state-write: the fused loop corrupts the
        slot's logits when its sampling position reaches ``pos``.  Pure
        data — arming/varying the fault never recompiles the loop."""
        return dict(
            state,
            fault_pos=state["fault_pos"].at[slot].set(pos),
            fault_kind=state["fault_kind"].at[slot].set(kind),
        )

    def _prefill_into_slot(self, slot: int, req: _Request) -> jax.Array:
        """Build the slot's cache region through the slot-state protocol;
        returns last-prompt-position logits (1, vocab).

        Every arch admits the same way: evict the previous tenant's ring
        bookkeeping (``clear_slot``), run the per-request one-shot legs
        (enc-dec: one ``encode_slot`` dispatch writing slot-resident
        enc_out + quantized cross-KV), then stream the decoder trunk —
        VLM patch-embedding chunks first, token chunks after — straight
        into the pool region (jitted; quantize-on-write for kv_format
        caches; SSM conv/state carried across chunk boundaries)."""
        self.cache = self._clear_slot_fn(self.cache, jnp.int32(slot))
        if self._draft_cache is not None:
            self._draft_cache = self._draft_clear_fn(self._draft_cache,
                                                     jnp.int32(slot))
        cdtype = jnp.dtype(self.model.cfg.compute_dtype)
        chunk = self.prefill_chunk
        if req.frames is not None:
            src = req.frames.shape[0]
            padded = np.zeros((1, self.enc_len, req.frames.shape[1]),
                              np.float32)
            padded[0, :src] = req.frames
            self.cache = self._encode_slot_fn(
                self.params, self.cache, jnp.asarray(padded, cdtype),
                jnp.int32(slot), jnp.int32(src))
        offset, logits = 0, None
        if req.patches is not None:
            n_pat = req.patches.shape[0]
            for off in range(0, n_pat, chunk):
                part = req.patches[off:off + chunk]
                valid = part.shape[0]
                padded = np.zeros((1, chunk, part.shape[1]), np.float32)
                padded[0, :valid] = part
                logits, self.cache = self._prefill_embeds_fn(
                    self.params, self.cache, jnp.asarray(padded, cdtype),
                    jnp.int32(slot), jnp.int32(off), jnp.int32(valid))
            offset = n_pat
        for off in range(0, len(req.prompt), chunk):
            part = req.prompt[off:off + chunk]
            valid = len(part)
            part = part + [0] * (chunk - valid)
            logits, self.cache = self._prefill_chunk_fn(
                self.params, self.cache,
                jnp.asarray(part, jnp.int32), jnp.int32(slot),
                jnp.int32(offset + off), jnp.int32(valid))
            if self._draft_cache is not None:
                # the draft model shares the slot protocol: its cache is
                # prefilled through the same chunk stream (draft-model
                # targets are plain decoder-only, so offset == 0)
                _, self._draft_cache = self._draft_prefill_fn(
                    self._draft_params, self._draft_cache,
                    jnp.asarray(part, jnp.int32), jnp.int32(slot),
                    jnp.int32(offset + off), jnp.int32(valid))
        return logits

    def _admit(self) -> None:
        for slot in range(self.batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req, expired = self.queue.take(self._now())
            for e in expired:
                # deadline passed while queued: account it WITHOUT
                # spending a single prefill dispatch on it
                self._finish_unadmitted(e, "deadline_exceeded")
            if req is None:
                continue
            logits = self._prefill_into_slot(slot, req)
            args = [self.state, logits, jnp.int32(slot),
                    jnp.int32(req.trunk_len),
                    jnp.int32(req.max_new_tokens),
                    jnp.int32(req.request_id), self._sample_key]
            if self.spec is not None:
                ptail = self.spec.prompt_tail
                tail = np.full((ptail,), -1, np.int32)
                got = req.prompt[-ptail:]
                if got:
                    tail[-len(got):] = got
                args.append(jnp.asarray(tail))
            tok, self.state = self._admit_fn(*args)
            self.slot_req[slot] = req
            self.out_tokens[slot] = [int(self._host_read(tok))]
            req.first_token_t = self._now()
            self._slot_progress[slot] = (1, self._dispatches)
            if req.max_new_tokens <= 1:
                self._finish(slot)

    # -- fused decode --------------------------------------------------- #
    def _make_decode_loop(self, k: int):
        """Jit the K-step fused loop: decode → sample → cache-write →
        bookkeeping inside one ``lax.scan``, emitting (tokens (k, b),
        emitted-codes (k, b) int32 — EMIT_NONE/TOKEN/FAULT) plus the
        advanced cache/state.

        Two robustness legs ride inside the body at zero marginal sync:

        * **Fault injection** — if the slot's armed ``fault_pos`` equals
          this step's sampling position, its logits row is overwritten
          with NaN/Inf (``fault_kind``).  Purely data-driven: arming a
          fault is a state write, never a recompile.
        * **Sentinel** — a per-slot non-finite reduce over the logits
          (catches injected faults AND real numeric escapes, e.g. a
          poisoned quantized cache decoding to inf).  A tripped slot
          emits EMIT_FAULT, keeps its pos/remaining/last_token frozen,
          and drops out of ``active`` inside the same body — so its
          cache writes stop mid-block and every surviving slot's stream
          is bit-identical to an uninjected run (rows are independent).
          The host sees the code in the SAME emitted array it already
          syncs once per block: detection costs no extra transfer."""
        model = self.model
        temp, top_k, max_seq = self.temperature, self.top_k, self.max_seq
        # mesh-native: decode leaves logits vocab-sharded over 'model'
        # (the unembed placement); the sample point is the loop's ONE
        # all-gather, after which tokens and bookkeeping are replicated
        logits_sh = self._sh["logits"] if self.mesh is not None else None

        def loop(params, cache, state, key):
            def body(carry, _):
                cache, st = carry
                active = st["active"]
                logits, cache = model.decode_step(
                    params, cache, st["last_token"], st["pos"],
                    active=active)
                nxt = st["pos"] + 1
                hit = (active & (st["fault_kind"] > jnp.int32(0))
                       & (st["fault_pos"] == nxt))
                bad_val = jnp.where(
                    st["fault_kind"] == jnp.int32(fault_lib.FAULT_INF),
                    jnp.inf, jnp.nan).astype(logits.dtype)
                logits = jnp.where(hit[:, None], bad_val[:, None], logits)
                bad = active & jnp.any(~jnp.isfinite(logits), axis=-1)
                ok = active & ~bad
                tok = sample_tokens(logits, key, temp, top_k,
                                    slot_seed=st["seed"], pos=nxt,
                                    logits_sharding=logits_sh)
                tok = jnp.where(ok, tok, st["last_token"])
                new_pos = jnp.where(ok, nxt, st["pos"])
                new_rem = st["remaining"] - ok.astype(jnp.int32)
                finished = ok & ((new_rem <= 0)
                                 | (new_pos >= max_seq - 1))
                st = dict(st, pos=new_pos, remaining=new_rem,
                          last_token=tok, active=ok & ~finished,
                          fault_kind=jnp.where(bad, jnp.int32(0),
                                               st["fault_kind"]))
                emit = (ok.astype(jnp.int32)
                        + jnp.int32(EMIT_FAULT) * bad.astype(jnp.int32))
                return (cache, st), (tok, emit)

            (cache, state), (toks, emitted) = jax.lax.scan(
                body, (cache, state), xs=None, length=k)
            return cache, state, toks, emitted

        if self.mesh is None:
            return jax.jit(loop)
        return jax.jit(loop, out_shardings=(
            self._sh["cache"], self._sh["state"],
            self._sh["replicated"], self._sh["replicated"]))

    # -- speculative decode --------------------------------------------- #
    def _make_spec_loop(self, n_blocks: int):
        """Jit the speculative fused loop: ``n_blocks`` draft→verify→
        commit blocks in one dispatch, each covering s = draft_tokens+1
        token positions.  Emits (tokens, emitted-codes) reshaped to
        (n_blocks*s, b) so :meth:`_harvest` consumes them exactly like
        the non-speculative loop's (k, b) outputs.

        Output equivalence is by construction, not by luck: the verify
        pass re-scores every drafted position with decode-bit-identical
        logits (``lm_verify_chunk``), the TRUE tokens are sampled from
        those logits with the same per-(request, position) key folds the
        non-speculative loop uses, and drafts only decide how many of
        those true tokens are valid this block: e = min(#leading draft
        matches + 1, remaining, max_seq-1-pos).  Accepted prefixes
        commit through the quantized cache-write path; rejected verify
        rows are simply never written (the target cache needs no
        rollback — only the eagerly-written draft-model cache does).

        Fault semantics match the non-speculative loop at token
        granularity: an armed fault poisons the verify logits row whose
        sampling position equals ``fault_pos``; if that row lands inside
        the accepted prefix, acceptance truncates there, EMIT_FAULT is
        emitted after the survivors, and the slot drops out of
        ``active`` (its partially-written block is discarded with the
        slot at the block-boundary ``clear_slot``)."""
        model, spec = self.model, self.spec
        temp, top_k, max_seq = self.temperature, self.top_k, self.max_seq
        D = spec.draft_tokens
        s = D + 1
        logits_sh = self._sh["logits"] if self.mesh is not None else None
        use_draft_model = self._draft_cache is not None
        dmodel = spec.draft_model

        def block(params, cache, st, key, dparams, dcache):
            active = st["active"]
            P = st["pos"]
            # 1. propose D drafts
            if spec.draft_fn is not None:
                drafts = spec.draft_fn(st)
            elif use_draft_model:
                def dstep(carry, _):
                    dc, tok, dpos = carry
                    dlogits, dc = dmodel.decode_step(
                        dparams, dc, tok, dpos, active=active)
                    ntok = jnp.argmax(dlogits, axis=-1).astype(jnp.int32)
                    return (dc, ntok, dpos + 1), ntok
                (dcache, _, _), drafts_t = jax.lax.scan(
                    dstep, (dcache, st["last_token"], P), xs=None,
                    length=D)
                drafts = drafts_t.transpose(1, 0)
            else:
                drafts = spec_lib.ngram_draft(
                    st["spec_hist"], st["spec_ngram"], D)
            # 2. verify: decode-exact logits for all s rows at once
            tokens = jnp.concatenate(
                [st["last_token"][:, None], drafts], axis=1)
            positions = (P[:, None]
                         + jnp.arange(s, dtype=jnp.int32)[None, :])
            logits, info = model.verify_chunk(
                params, cache, tokens, positions)
            # 3. armed logits fault: poison the row whose SAMPLING
            # position matches fault_pos (same trigger rule as the
            # non-speculative body, vectorized over the block)
            q_pos = positions + 1
            hit = (active[:, None]
                   & (st["fault_kind"][:, None] > jnp.int32(0))
                   & (st["fault_pos"][:, None] == q_pos))
            bad_val = jnp.where(
                st["fault_kind"] == jnp.int32(fault_lib.FAULT_INF),
                jnp.inf, jnp.nan).astype(logits.dtype)
            logits = jnp.where(hit[:, :, None], bad_val[:, None, None],
                               logits)
            # 4. sample the TRUE tokens (drafts never enter the stream)
            toks = sample_tokens_chunk(logits, key, temp, top_k,
                                       slot_seed=st["seed"], pos=q_pos,
                                       logits_sharding=logits_sh)
            # 5. acceptance: leading drafts that matched, plus the bonus
            # token sampled past the last match
            match = (drafts == toks[:, :D]).astype(jnp.int32)
            m = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
            e0 = jnp.minimum(m + 1, st["remaining"])
            e0 = jnp.minimum(e0, jnp.maximum(max_seq - 1 - P, 0))
            e0 = jnp.where(active, e0, 0)
            # sentinel: first non-finite verify row INSIDE the accepted
            # prefix truncates acceptance there and trips the fault
            bad_rows = (active[:, None]
                        & jnp.any(~jnp.isfinite(logits), axis=-1))
            first_bad = jnp.where(
                jnp.any(bad_rows, axis=1),
                jnp.argmax(bad_rows, axis=1).astype(jnp.int32),
                jnp.int32(s))
            fault = active & (first_bad < e0)
            e = jnp.where(fault, first_bad, e0)
            # 6. commit the accepted prefix (quantized cache-write path;
            # e = 0 rows are uniform no-ops)
            cache = model.commit_chunk(cache, info, positions, e)
            if use_draft_model:
                # the draft cache wrote eagerly during drafting: roll
                # back the rejected tail by pointer invalidation
                dpos = (P[:, None]
                        + jnp.arange(D, dtype=jnp.int32)[None, :])
                reject = (jnp.arange(D, dtype=jnp.int32)[None, :]
                          >= e[:, None])
                dcache = dmodel.rollback_chunk(dcache, dpos, reject)
            # 7. slot bookkeeping (identical rules, advanced by e)
            new_pos = P + e
            new_rem = st["remaining"] - e
            last = jnp.take_along_axis(
                toks, jnp.maximum(e - 1, 0)[:, None], axis=1)[:, 0]
            last = jnp.where(e > 0, last, st["last_token"])
            finished = (active & ~fault
                        & ((new_rem <= 0) | (new_pos >= max_seq - 1)))
            cols = jnp.arange(s, dtype=jnp.int32)[None, :]
            hist, table = spec_lib.ngram_update(
                st["spec_hist"], st["spec_ngram"], toks,
                cols < e[:, None])
            st = dict(st, pos=new_pos, remaining=new_rem,
                      last_token=last,
                      active=active & ~fault & ~finished,
                      fault_kind=jnp.where(fault, jnp.int32(0),
                                           st["fault_kind"]),
                      spec_hist=hist, spec_ngram=table,
                      spec_accept=st["spec_accept"] + e,
                      spec_blocks=(st["spec_blocks"]
                                   + active.astype(jnp.int32)))
            emit = jnp.where(cols < e[:, None], jnp.int32(EMIT_TOKEN),
                             jnp.int32(EMIT_NONE))
            emit = jnp.where(fault[:, None] & (cols == e[:, None]),
                             jnp.int32(EMIT_FAULT), emit)
            return cache, dcache, st, toks, emit

        def reshape_out(ys):
            # (n_blocks, b, s) -> (n_blocks * s, b): block-major rows,
            # the exact layout _harvest's host loop already consumes
            return ys.transpose(0, 2, 1).reshape(n_blocks * s, -1)

        if use_draft_model:
            def loop(params, cache, state, key, dparams, dcache):
                def body(carry, _):
                    cache, st, dc = carry
                    cache, dc, st, toks, emit = block(
                        params, cache, st, key, dparams, dc)
                    return (cache, st, dc), (toks, emit)
                (cache, state, dcache), (toks, emitted) = jax.lax.scan(
                    body, (cache, state, dcache), xs=None,
                    length=n_blocks)
                return (cache, state, reshape_out(toks),
                        reshape_out(emitted), dcache)
            return jax.jit(loop)

        def loop(params, cache, state, key):
            def body(carry, _):
                cache, st = carry
                cache, _, st, toks, emit = block(
                    params, cache, st, key, None, None)
                return (cache, st), (toks, emit)
            (cache, state), (toks, emitted) = jax.lax.scan(
                body, (cache, state), xs=None, length=n_blocks)
            return cache, state, reshape_out(toks), reshape_out(emitted)

        if self.mesh is None:
            return jax.jit(loop)
        return jax.jit(loop, out_shardings=(
            self._sh["cache"], self._sh["state"],
            self._sh["replicated"], self._sh["replicated"]))

    def _any_active(self) -> bool:
        return any(r is not None for r in self.slot_req)

    def _max_remaining(self) -> int:
        """Largest token budget left among in-flight slots (host-known:
        max_new_tokens minus tokens already emitted).  run() caps the
        fused block with this so the tail dispatch runs exactly the
        iterations it needs — without it, finishing a 23-token request
        with K=16 blocks would burn 9 fully-masked (but fully-costed)
        scan iterations."""
        rem = 0
        for slot, req in enumerate(self.slot_req):
            if req is not None:
                rem = max(rem,
                          req.max_new_tokens - len(self.out_tokens[slot]))
        return max(rem, 1)

    def _finish(self, slot: int, status: str = "ok") -> None:
        req = self.slot_req[slot]
        self.results.append(GenerationResult(
            req.request_id, req.prompt, self.out_tokens[slot],
            status=status, submit_t=req.submit_t,
            first_token_t=req.first_token_t, finish_t=self._now()))
        self.slot_req[slot] = None

    def _finish_unadmitted(self, req: _Request, status: str) -> None:
        """Account a request that never reached a slot (shed by the
        admission policy, cancelled while queued, or deadline-expired
        before prefill): zero tokens, terminal status."""
        self.results.append(GenerationResult(
            req.request_id, req.prompt, [], status=status,
            submit_t=req.submit_t, finish_t=self._now()))

    def _dispatch(self, k: int) -> int:
        """One fused dispatch of K decode steps + one host sync for its
        K×batch tokens.  Fault recovery happens in :meth:`_harvest`, at
        the block boundary: a slot whose emitted codes contain
        EMIT_FAULT keeps the tokens it emitted before the sentinel
        tripped, finishes as ``status="faulted"``, and its pool region
        is re-initialized through the existing ``clear_slot`` eviction
        path — the next admission reuses the slot as if the fault never
        happened.  Returns the decode-step budget actually spent (k
        here; the speculative leg rounds up to whole blocks)."""
        if self.spec is not None:
            return self._dispatch_spec(k)
        fn = self._loops.get(k)
        if fn is None:
            fn = self._loops[k] = self._make_decode_loop(k)
        self.cache, self.state, toks, emitted = fn(
            self.params, self.cache, self.state, self._sample_key)
        self._harvest(toks, emitted)
        return k

    def _dispatch_spec(self, k: int) -> int:
        """Speculative dispatch covering >= k token positions:
        ceil(k / (draft_tokens+1)) fused draft→verify→commit blocks in
        one launch, then the same one-sync harvest."""
        s = self.spec.draft_tokens + 1
        n_blocks = max(1, -(-k // s))
        fn = self._spec_loops.get(n_blocks)
        if fn is None:
            fn = self._spec_loops[n_blocks] = self._make_spec_loop(
                n_blocks)
        if self._draft_cache is not None:
            (self.cache, self.state, toks, emitted,
             self._draft_cache) = fn(
                self.params, self.cache, self.state, self._sample_key,
                self._draft_params, self._draft_cache)
        else:
            self.cache, self.state, toks, emitted = fn(
                self.params, self.cache, self.state, self._sample_key)
        codes = self._harvest(toks, emitted)
        # engine-lifetime acceptance accounting, from the SAME synced
        # array: a (block, slot) cell counts as a run block iff any code
        # is non-NONE there (the slot was active entering the block)
        per_block = codes.reshape(n_blocks, s, -1)
        self._spec_tokens += int((codes == EMIT_TOKEN).sum())
        self._spec_blocks += int(
            (per_block != EMIT_NONE).any(axis=1).sum())
        return n_blocks * s

    def _harvest(self, toks, emitted) -> np.ndarray:
        """Block-boundary host pass shared by both loop flavours: ONE
        sync for the (rows, batch) token/code arrays, then per-slot
        extend/finish/fault bookkeeping.  Returns the host codes."""
        toks = self._host_read(toks)                  # (rows, b) — ONE sync
        emitted = self._host_read(emitted)
        active_after = self._host_read(self.state["active"])
        self._dispatches += 1
        for slot in range(self.batch):
            if self.slot_req[slot] is None:
                continue
            codes = emitted[:, slot]
            self.out_tokens[slot].extend(
                int(t) for t, e in zip(toks[:, slot], codes)
                if e == EMIT_TOKEN)
            if (codes == EMIT_FAULT).any():
                self._finish(slot, status="faulted")
                self.cache = self._clear_slot_fn(self.cache,
                                                 jnp.int32(slot))
                if self._draft_cache is not None:
                    self._draft_cache = self._draft_clear_fn(
                        self._draft_cache, jnp.int32(slot))
            elif not active_after[slot]:
                self._finish(slot)
            else:
                self._slot_progress[slot] = (len(self.out_tokens[slot]),
                                             self._dispatches)
        if self._deadlines_live:
            self._expire_inflight()
        return emitted

    def spec_report(self) -> Dict:
        """Engine-lifetime speculation accounting (host totals; the
        per-slot in-flight view lives in ``state['spec_accept']`` /
        ``state['spec_blocks']``).  ``mean_accepted_len`` is tokens
        committed per run block — the paper-style acceptance length
        (1.0 = no draft ever accepted, draft_tokens+1 = every block
        fully accepted)."""
        blocks = self._spec_blocks
        return {
            "enabled": self.spec is not None,
            "draft_tokens": (0 if self.spec is None
                             else self.spec.draft_tokens),
            "blocks": blocks,
            "accepted_tokens": self._spec_tokens,
            "mean_accepted_len": (self._spec_tokens / blocks
                                  if blocks else 0.0),
        }

    def _expire_inflight(self) -> None:
        """Cancel in-flight requests whose deadline passed: one jitted
        cancel state-write each, partial tokens delivered as
        ``deadline_exceeded``."""
        now = self._now()
        for slot, req in enumerate(self.slot_req):
            if (req is not None and req.deadline_s is not None
                    and now >= req.deadline_s):
                self.state = self._cancel_fn(self.state, jnp.int32(slot))
                self._finish(slot, status="deadline_exceeded")

    # -- cancellation / fault injection ---------------------------------- #
    def _slot_of(self, request_id: int) -> Tuple[int, _Request]:
        for slot, req in enumerate(self.slot_req):
            if req is not None and req.request_id == request_id:
                return slot, req
        raise KeyError(f"request {request_id} is not in flight")

    def cancel(self, request_id: int, status: str = "shed") -> bool:
        """Cancel a request wherever it lives.  Queued: removed without
        ever touching the device.  In flight: one jitted cancel
        state-write deactivates the slot (same compile-once shape as
        admission) and the partial tokens are delivered under
        ``status``.  Returns False when the id is unknown or already
        finished."""
        if status not in STATUSES:
            raise ValueError(f"status {status!r} not in {STATUSES}")
        req = self.queue.remove(request_id)
        if req is not None:
            self._finish_unadmitted(req, status)
            return True
        try:
            slot, _ = self._slot_of(request_id)
        except KeyError:
            return False
        self.state = self._cancel_fn(self.state, jnp.int32(slot))
        self._finish(slot, status=status)
        return True

    def inject_fault(self, request_id: int, kind: str = "logits_nan",
                     delay: int = 0, leaf: str = "k_s",
                     xor: int = 0xFF) -> None:
        """Arm a fault against an in-flight request (testing/chaos API;
        see ``repro.serve.faults`` for the taxonomy and which kinds the
        sentinel can detect).

        Logits kinds (``logits_nan``/``logits_inf``) arm the in-loop
        injector: the fault fires when the slot samples its
        ``delay``-th next token (0 = the first token of the next
        dispatch).  Cache kinds (``e8m0_overflow``/``kv_bitflip``/
        ``state_inf``) poison the slot's cache region immediately via
        one jitted pure cache-write; ``e8m0_overflow``/``state_inf``
        decode to inf by construction so the sentinel sees them on the
        next decode step, while ``kv_bitflip`` usually decodes to wrong
        -but-finite values the sentinel cannot see (the documented
        silent-corruption gap)."""
        slot, req = self._slot_of(request_id)
        if kind in fault_lib.LOGITS_FAULTS:
            if delay < 0:
                raise ValueError("delay must be >= 0")
            pos = req.trunk_len + len(self.out_tokens[slot]) + delay
            self.state = self._fault_arm_fn(
                self.state, jnp.int32(slot), jnp.int32(pos),
                jnp.int32(fault_lib.LOGITS_FAULTS[kind]))
            return
        if kind not in fault_lib.CACHE_POISONERS:
            raise ValueError(
                f"unknown fault kind {kind!r}; choose from "
                f"{fault_lib.FAULT_KINDS}")
        key = (kind, leaf, xor) if kind == "kv_bitflip" else (kind,)
        fn = self._fault_cache_fns.get(key)
        if fn is None:
            if kind == "kv_bitflip":
                base = functools.partial(fault_lib.flip_kv_bytes,
                                         leaf=leaf, xor=xor)
            else:
                base = fault_lib.CACHE_POISONERS[kind]
            fn = self._fault_cache_fns[key] = self._jit(
                base, self._cache_sh)
        self.cache = fn(self.cache, jnp.int32(slot))

    # -- accounting / watchdog ------------------------------------------- #
    def accounting(self) -> Dict[str, int]:
        """Exact request accounting.  ``balanced`` asserts the shed
        identity: every submitted request is either still pending
        (queued/in-flight) or in exactly one terminal status —
        submitted = ok + truncated + shed + deadline_exceeded + faulted
        + in_flight + queued."""
        by_status = {s: 0 for s in STATUSES}
        for r in self.results:
            by_status[r.status] += 1
        in_flight = sum(r is not None for r in self.slot_req)
        queued = len(self.queue)
        done = sum(by_status.values())
        return dict(by_status, submitted=self._submitted,
                    completed=by_status["ok"] + by_status["truncated"],
                    in_flight=in_flight, queued=queued,
                    balanced=(self._submitted
                              == done + in_flight + queued))

    def watchdog_report(self) -> Dict:
        """Host/device slot reconciliation (diagnostic path — a handful
        of host reads, never called inside a timed region).  Flags:
        device-active slots with no host-side tenant (orphans), host
        tenants whose device slot went inactive without being finished,
        negative ``remaining`` / out-of-range ``pos`` bookkeeping, a
        device ``remaining`` that disagrees with the host token count,
        and slots that stayed active across dispatches without emitting
        (stuck — e.g. a scheduler bug starving the slot's writes)."""
        active = self._host_read(self.state["active"])
        pos = self._host_read(self.state["pos"])
        remaining = self._host_read(self.state["remaining"])
        findings: List[str] = []
        for slot in range(self.batch):
            req = self.slot_req[slot]
            if req is None:
                if active[slot]:
                    findings.append(
                        f"slot {slot}: device-active with no host "
                        f"request (orphaned slot)")
                continue
            if not active[slot]:
                findings.append(
                    f"slot {slot}: host request {req.request_id} on an "
                    f"inactive device slot (lost finish)")
            if remaining[slot] < 0:
                findings.append(
                    f"slot {slot}: remaining={int(remaining[slot])} < 0")
            if pos[slot] >= self.max_seq:
                findings.append(
                    f"slot {slot}: pos={int(pos[slot])} >= max_seq "
                    f"{self.max_seq}")
            host_rem = req.max_new_tokens - len(self.out_tokens[slot])
            if active[slot] and int(remaining[slot]) != host_rem:
                findings.append(
                    f"slot {slot}: device remaining="
                    f"{int(remaining[slot])} != host budget {host_rem}")
            count, seen = self._slot_progress[slot]
            if (active[slot] and self._dispatches - seen >= 3
                    and len(self.out_tokens[slot]) == count):
                findings.append(
                    f"slot {slot}: stuck — no tokens emitted for "
                    f"{self._dispatches - seen} dispatches")
        return {"ok": not findings, "findings": findings,
                "dispatches": self._dispatches}

    def decode_loop(self, k: Optional[int] = None) -> None:
        """Admit from the queue, then run K fused decode steps in one
        dispatch (K = ``decode_block`` by default)."""
        self._admit()
        if self._any_active():
            self._dispatch(k or self.decode_block)

    def step(self) -> None:
        """One pooled decode step — the per-token dispatch pattern (one
        launch + one host sync per generated token).  Kept as the
        measurable baseline; :meth:`run` uses the fused loop."""
        self.decode_loop(1)

    # -- driver --------------------------------------------------------- #
    def run(self, max_steps: int = 1000) -> List[GenerationResult]:
        """Serve until queue and pool drain or ``max_steps`` decode steps
        have been spent.  On budget exhaustion, in-flight requests are
        FLUSHED as partial results (``status="truncated"``) instead of
        being silently dropped.

        A non-admittable queue state (non-empty queue, nothing active,
        and an admission pass that neither admitted, expired, nor shed
        anything) raises instead of spinning: the old bare ``continue``
        could loop forever without spending a step."""
        steps = 0
        while steps < max_steps:
            before = (len(self.queue), len(self.results))
            self._admit()
            if not self._any_active():
                if not self.queue:
                    break
                if (len(self.queue), len(self.results)) == before:
                    raise RuntimeError(
                        f"run() stalled: {len(self.queue)} queued "
                        f"request(s), no active slots, and an admission "
                        f"pass made no progress — scheduler/admission "
                        f"bug (would previously spin silently)")
                continue
            k = min(self.decode_block, max_steps - steps,
                    self._max_remaining())
            steps += self._dispatch(k)
        if self._any_active():
            # budget hit mid-generation: flush partials and deactivate
            # their device slots so a later run() cannot advance them
            for slot in range(self.batch):
                if self.slot_req[slot] is not None:
                    self._finish(slot, status="truncated")
            self.state = dict(
                self.state,
                active=jnp.zeros_like(self.state["active"]))
        return sorted(self.results, key=lambda r: r.request_id)
