"""Batched serving engine with continuous batching.

A fixed pool of ``batch`` slots shares one cache pytree; finished or empty
slots are refilled from a request queue between decode steps (prefill for
a new request writes that slot's cache region).  The decode step itself is
a single jitted call over the whole pool — the batching model TPU serving
actually uses (decode is memory-bound; batching amortizes the weight
reads, which is exactly the paper's §VI.D read-bandwidth story).

For simplicity prefill here runs per-request at pool width 1 and its cache
is scattered into the slot; a production engine would chunk prefill into
the decode schedule, which does not change the lowered decode step the
dry-run measures.

Weight storage: with ``weight_format`` set, the engine keeps its weights
in true quantized storage (``serve.quant.quantize_tree`` — bit-packed
0.5 B/elem fp4 / 0.75 B/elem fp6 via ``repro.lowbits`` when
``packed=True``) as the HBM-resident source of truth, and materializes
the dense compute copy the XLA path consumes.  ``weight_stats`` carries
the *measured* stored-byte counts the Tab VIII benchmark reports.

KV storage: with ``kv_format`` set, the pooled decode cache itself is
blockwise-quantized (``repro.models.attention``: packed fp8/fp4 codes +
1-byte e8m0 scales, quantize-on-write inside the jitted step) — at long
context the KV read, not the weights, dominates decode HBM traffic
(§VI.D), so this is the lever that actually moves the roofline.
``kv_stats`` carries the measured stored KV bytes (per token and per
element) next to the weight numbers.  Note the XLA decode step
materializes a dense dequantized view of the cache per layer (like the
weight path, XLA consumes dense arrays), so off-TPU the win is
*footprint*, not step time; the streaming read win belongs to the
Pallas leg (``repro.kernels.flash_decode_quant``, validated against
this path's oracle in interpret mode — the same kernel-vs-XLA-twin
split as flash_decode/decode_attention).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model, build_model
from repro.serve.quant import dequantize_tree, quantize_tree
from repro.serve.sampler import sample_token


@dataclasses.dataclass
class GenerationResult:
    request_id: int
    prompt: List[int]
    tokens: List[int]


@dataclasses.dataclass
class _Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int


class ServeEngine:
    def __init__(self, model: Model, params, batch: int, max_seq: int,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 weight_format: Optional[str] = None, packed: bool = True,
                 kv_format: Optional[str] = None,
                 compute_dtype=jnp.bfloat16):
        if kv_format:
            # rebind the model onto a config whose cache layer quantizes:
            # every prefill/decode below then writes packed codes +
            # 1-byte e8m0 scales instead of full-width K/V
            model = build_model(
                dataclasses.replace(model.cfg, kv_format=kv_format))
        self.model = model
        self.kv_format = kv_format
        self.weight_store = None
        self.weight_stats: Optional[Dict] = None
        if weight_format is not None:
            self.weight_store, self.weight_stats = quantize_tree(
                params, weight_format, packed=packed)
            params = dequantize_tree(self.weight_store, compute_dtype)
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.temperature = temperature
        self.top_k = top_k
        self.key = jax.random.PRNGKey(seed)

        self.cache = model.init_cache(batch, max_seq)
        # measured KV storage accounting (codes + scales, what a decode
        # step actually reads) — reported by Tab VIII next to weights
        self.kv_stats: Dict = model.kv_cache_stats(self.cache)
        self.pos = np.zeros(batch, np.int64)          # next position per slot
        self.remaining = np.zeros(batch, np.int64)
        self.active: List[Optional[_Request]] = [None] * batch
        self.out_tokens: List[List[int]] = [[] for _ in range(batch)]
        self.last_token = np.zeros(batch, np.int32)
        self.queue: List[_Request] = []
        self.results: List[GenerationResult] = []
        self._next_id = 0

        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_seq))

    # -- request management -------------------------------------------- #
    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        """Enqueue a request.  Prompts must leave room for at least one
        generated token: a prompt of ``max_seq`` or longer used to be
        admitted anyway, setting ``pos`` past the cache so the first
        decode step attended over a silently clipped prefill."""
        if len(prompt) >= self.max_seq:
            raise ValueError(
                f"prompt length {len(prompt)} >= max_seq {self.max_seq}: "
                f"the cache holds max_seq-1 prompt tokens plus the "
                f"decode stream; truncate the prompt or raise max_seq")
        rid = self._next_id
        self._next_id += 1
        self.queue.append(_Request(rid, list(prompt), max_new_tokens))
        return rid

    def _admit(self) -> None:
        for slot in range(self.batch):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            tokens = jnp.asarray([req.prompt], jnp.int32)
            logits, cache1 = self._prefill(self.params, {"tokens": tokens})
            # scatter the single-row prefill cache into this slot
            self.cache = jax.tree.map(
                lambda pool, one: self._scatter_slot(pool, one, slot),
                self.cache, cache1)
            self.key, sub = jax.random.split(self.key)
            tok = sample_token(logits, sub, self.temperature, self.top_k)
            self.active[slot] = req
            self.out_tokens[slot] = [int(tok[0])]
            self.last_token[slot] = int(tok[0])
            self.pos[slot] = len(req.prompt)
            self.remaining[slot] = req.max_new_tokens - 1

    @staticmethod
    def _scatter_slot(pool: jax.Array, one: jax.Array, slot: int):
        """Write a batch-1 cache leaf into pool slot ``slot``.

        Cache leaves carry batch on axis 0 (enc_out) or axis 1 (stacked
        period leaves); identified by matching the pool/one shapes.  A
        pool of width 1 has no differing axis — the leaf is replaced."""
        axis = next((i for i, (a, b) in enumerate(zip(pool.shape, one.shape))
                     if a != b), None)
        if axis is None:
            return one
        return jax.lax.dynamic_update_slice_in_dim(pool, one, slot, axis)

    # -- decode --------------------------------------------------------- #
    def step(self) -> None:
        """One pooled decode step (slots advance together)."""
        self._admit()
        if not any(r is not None for r in self.active):
            return
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_token),
            jnp.asarray(self.pos, jnp.int32))
        self.key, sub = jax.random.split(self.key)
        toks = np.asarray(sample_token(logits, sub, self.temperature,
                                       self.top_k))
        for slot in range(self.batch):
            req = self.active[slot]
            if req is None:
                continue
            self.out_tokens[slot].append(int(toks[slot]))
            self.last_token[slot] = int(toks[slot])
            self.pos[slot] += 1
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or self.pos[slot] >= self.max_seq - 1:
                self.results.append(GenerationResult(
                    req.request_id, req.prompt, self.out_tokens[slot]))
                self.active[slot] = None

    def run(self, max_steps: int = 1000) -> List[GenerationResult]:
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return sorted(self.results, key=lambda r: r.request_id)
