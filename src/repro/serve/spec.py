"""Speculative decoding for the fused serving loop — draft proposal.

The verify/commit half lives in the model stack
(``repro.models.transformer.lm_verify_chunk`` / ``lm_commit_chunk``);
this module owns the *drafting* side and its configuration:

* **Self-speculative n-gram drafting** (the default, no second model):
  each pool slot carries a device-resident n-gram table — a hash map
  from the last ``ngram_context`` tokens to the token that followed them
  last time — seeded from the prompt tail at admission and updated
  online as tokens commit.  Repetitive continuations (code, templated
  text, the benchmark's cyclic prompts) hit the table and verify whole
  blocks per dispatch; misses cost nothing but the wasted verify rows,
  because emitted tokens are ALWAYS the true sampled tokens from the
  verify logits — drafts only decide how many of them are valid.

* **Draft-model drafting**: a small decoder-only attention LM shares
  the slot protocol (same pool slots, same admission prefill, ring
  rollback via ``slot_pos``) and proposes greedily.  See
  ``ServeEngine(spec=SpecConfig(draft_model=..., draft_params=...))``.

* ``draft_fn`` — a test hook: the differential conformance suite
  scripts exact accept/reject patterns by supplying drafts as a pure
  function of the slot state (position-indexed match/mismatch scripts),
  driving adversarial paths (accept-all, reject-all, alternating,
  ring-wrap rollback) deterministically.

Everything here is trace-safe: the static loops are over the (small,
static) draft length / context length / prompt tail, and the tables are
ordinary int32 arrays living in the engine's slot state
(``spec_hist`` / ``spec_ngram`` — see
``repro.models.slotstate.SLOT_STATE_FIELDS``), so drafting runs inside
the jitted fused scan with zero host traffic.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

# Multiplier for the rolling polynomial context hash (int32 wraparound
# arithmetic — XLA wraps, which is exactly what a hash wants).
_HASH_MULT = 1000003


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculation knobs for ``ServeEngine(spec=...)``.

    draft_tokens:  drafts proposed per fused step (the verify width is
                   draft_tokens + 1: one row re-scores the incoming
                   committed token, the bonus row samples past the last
                   accepted draft).
    ngram_context: tokens of context hashed into the per-slot table.
    ngram_table:   per-slot hash-table entries (int32 each).
    prompt_tail:   how many prompt-tail tokens seed the table at
                   admission (static — one compiled admit executable).
    draft_model:   optional small decoder-only attention Model sharing
                   the slot protocol; ``draft_params`` its weights.
    draft_fn:      test hook — ``draft_fn(state) -> (b, draft_tokens)``
                   int32 drafts computed from the slot state; overrides
                   both n-gram and draft-model proposal.
    """
    draft_tokens: int = 4
    ngram_context: int = 3
    ngram_table: int = 512
    prompt_tail: int = 32
    draft_model: Any = None
    draft_params: Any = None
    draft_fn: Optional[Callable[[dict], jax.Array]] = None

    def __post_init__(self):
        if self.draft_tokens < 1:
            raise ValueError("draft_tokens must be >= 1")
        if self.ngram_context < 1:
            raise ValueError("ngram_context must be >= 1")
        if self.ngram_table < 1:
            raise ValueError("ngram_table must be >= 1")
        if (self.draft_model is None) != (self.draft_params is None):
            raise ValueError("draft_model and draft_params go together")


def ngram_index(ctx: jax.Array, table_size: int) -> jax.Array:
    """Hash a context window (..., C) int32 -> table index (...,) int32.

    Rolling polynomial hash in wrapping int32, folded through uint32 for
    a well-defined non-negative modulo."""
    h = jnp.zeros(ctx.shape[:-1], jnp.int32)
    for j in range(ctx.shape[-1]):
        h = h * jnp.int32(_HASH_MULT) + ctx[..., j]
    return (h.astype(jnp.uint32)
            % jnp.uint32(table_size)).astype(jnp.int32)


def ngram_draft(hist: jax.Array, table: jax.Array,
                draft_tokens: int) -> jax.Array:
    """Propose ``draft_tokens`` greedy n-gram continuations per row.

    hist: (b, C) last committed tokens (-1 where the slot has seen fewer
    than C); table: (b, T) int32 token-or-(-1) entries.  A table miss
    falls back to repeating the last context token — any deterministic
    filler is correct (a wrong draft just truncates acceptance)."""
    b = hist.shape[0]
    rows = jnp.arange(b)
    cur = hist
    drafts = []
    for _ in range(draft_tokens):
        idx = ngram_index(cur, table.shape[-1])
        tok = table[rows, idx]
        tok = jnp.where(tok >= 0, tok, jnp.maximum(cur[:, -1], 0))
        drafts.append(tok)
        cur = jnp.concatenate([cur[:, 1:], tok[:, None]], axis=1)
    return jnp.stack(drafts, axis=1)


def ngram_update(hist: jax.Array, table: jax.Array, toks: jax.Array,
                 valid: jax.Array):
    """Fold ``toks`` (b, s) with ``valid`` (b, s) into the per-slot
    history + table: each valid token is inserted at the hash of the
    history *preceding* it (only once the history is fully populated),
    then shifted into the history.  Static loop over the small block
    width — runs inside the fused scan."""
    b, s = toks.shape
    rows = jnp.arange(b)
    for j in range(s):
        tok, ok = toks[:, j], valid[:, j]
        ins = ok & jnp.all(hist >= 0, axis=1)
        idx = ngram_index(hist, table.shape[-1])
        table = table.at[rows, idx].set(
            jnp.where(ins, tok, table[rows, idx]))
        hist = jnp.where(
            ok[:, None],
            jnp.concatenate([hist[:, 1:], tok[:, None]], axis=1), hist)
    return hist, table


def seed_from_tail(tail: jax.Array, ngram_context: int,
                   table_size: int):
    """Admission-time seeding for ONE slot: fold a prompt tail
    (``prompt_tail``,) int32, left-padded with -1) into a fresh history
    + table.  Static loop over the fixed tail length — part of the one
    compiled admit executable."""
    hist = jnp.full((ngram_context,), -1, jnp.int32)
    table = jnp.full((table_size,), -1, jnp.int32)
    for j in range(tail.shape[0]):
        tok = tail[j]
        ins = (tok >= 0) & jnp.all(hist >= 0)
        idx = ngram_index(hist, table_size)
        table = table.at[idx].set(jnp.where(ins, tok, table[idx]))
        hist = jnp.where(tok >= 0,
                         jnp.concatenate([hist[1:], tok[None]]), hist)
    return hist, table
