"""Blockwise low-precision quantization — the paper's §V.B/§V.C subject.

The paper enumerates mma datatypes e2m1 (FP4), e2m3/e3m2 (FP6), e4m3/e5m2
(FP8) with e8m0 reserved for block-scale exponents (Tab V), and finds FP4
falls back to the FP8 pipeline (QMMA) in current software.  The TPU
adaptation (DESIGN.md §3): v5e's MXU has no sub-bf16 pipeline at all, so
every format here is *storage* precision — weights are kept quantized with
e8m0 (power-of-two) block scales and dequantized to bf16 on the way into
the MXU.  ``repro.kernels.qmatmul`` fuses that dequant into the matmul's
VMEM staging; this module is the numpy-level quantizer + the serving-stack
integration (weight-only PTQ for the Tab VIII inference sweep).

Storage comes in two layers:

* :func:`quantize_blockwise` — values in the registry *container* dtype
  (byte-aligned; the numerical oracle),
* :func:`quantize_tree` — true bit-packed weight storage
  (``packed=True``, via ``repro.lowbits``): fp4 at 0.5 B/elem, fp6 at
  0.75 B/elem, matching Tab V's tile packing, with measured byte counts
  in the returned stats (what the Tab VII/VIII artifacts report as HBM
  traffic).  Block scales are held as the 1-byte e8m0 store (uint8
  biased exponents, ``lowbits.e8m0_encode``) — the paper reserves e8m0
  for exactly this, and fp32-held scales were eating most of fp4's
  margin (3.2x -> ~3.8x measured traffic drop at BLOCK=32).

The KV-cache twin of this quantizer lives in
``repro.models.attention`` (``init_kv_cache(kv_format=...)``), built on
the same ``lowbits`` codec so it can run *inside* the jitted decode
step.
"""

from __future__ import annotations

from collections.abc import Mapping
from typing import Any, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat, lowbits

# (registry object, derived table) — keyed on the registry's *identity*
# rather than lru_cache'd, so a runtime whose registry changes (tests
# clearing compat's cache, a JAX gaining native fp4) never sees a stale
# table.  Holding the registry object itself (not its id()) makes the
# check immune to id reuse after GC.
_FORMAT_CACHE: Tuple[Optional[dict], dict] = (None, {})


def _format_table() -> dict:
    global _FORMAT_CACHE
    reg = compat.dtype_registry()
    if _FORMAT_CACHE[0] is not reg:
        _FORMAT_CACHE = (reg, {
            name: (spec.container, spec.max_finite, spec.round_dtype)
            for name, spec in reg.items()})
    return _FORMAT_CACHE[1]


def invalidate_format_table() -> None:
    """Drop the derived format table (next access rebuilds it).  Usually
    unnecessary — the table already tracks ``compat.dtype_registry()``
    identity — but explicit for callers that mutate a registry in
    place."""
    global _FORMAT_CACHE
    _FORMAT_CACHE = (None, {})


class _LazyFormats(Mapping):
    """name -> (container dtype, max finite magnitude, host rounding dtype).

    Built on first access from the ``repro.compat`` dtype registry so
    importing this module never dereferences a dtype the installed JAX
    lacks.  Formats without a native jnp dtype (fp6 always; fp4 on older
    JAX) round via ml_dtypes on the host and ride an e4m3 container —
    every e2m3/e3m2/e2m1 value is exactly representable in e4m3 (narrower
    mantissa AND exponent range), so the emulation is numerically exact.
    The container is the *compute-side* representation only: HBM-resident
    weight storage bit-packs sub-byte formats (``quantize_tree(packed=
    True)`` / ``repro.lowbits``) per the paper's Tab V tile packing.
    """

    def __getitem__(self, name: str) -> Tuple[Any, float, Any]:
        return _format_table()[name]

    def __iter__(self) -> Iterator[str]:
        return iter(_format_table())

    def __len__(self) -> int:
        return len(_format_table())


LOW_PRECISION_FORMATS: Mapping = _LazyFormats()

BLOCK = 32   # elements per scale block (matches mxfp4/mxfp6/mxfp8 spec)


def _e8m0_scale(absmax: jax.Array, fmt_max: float) -> jax.Array:
    """Power-of-two scale (e8m0 semantics): 2^ceil(log2(absmax/fmt_max)),
    clamped to e8m0's representable exponent range [-127, 127] so every
    scale this quantizer emits survives the 1-byte store losslessly
    (previously a tiny absmax produced exponents below -127 that no
    e8m0 byte can hold).  Routed through the ``repro.lowbits`` codec so
    scale rule and storage rule cannot drift apart."""
    return lowbits.e8m0_decode(lowbits.e8m0_scale_code(absmax, fmt_max))


def quantize_blockwise(w: jax.Array, fmt: str
                       ) -> Tuple[jax.Array, jax.Array]:
    """Quantize along the last axis in blocks of ``BLOCK``.

    Returns (q (..., n) in ``fmt``, scales (..., n/BLOCK) fp32 = powers of
    two, i.e. e8m0 content — 1-byte-storable by construction).

    Trace-safe end to end: sub-byte formats without a native jnp dtype
    round via ``lowbits.quantize_values`` (pure shift/mask/exp2 — the
    RTNE arithmetic twin of ml_dtypes), not host numpy, so the whole
    function jits/vmaps.  The KV-cache twin
    (``models.attention.quantize_kv`` — can't import this module without
    a serve<->models cycle) orchestrates the same ``lowbits`` scale and
    rounding primitives, so the two quantizers share their numerics by
    construction.
    """
    dtype, fmt_max, round_dtype = LOW_PRECISION_FORMATS[fmt]
    *lead, n = w.shape
    assert n % BLOCK == 0, f"last dim {n} % {BLOCK} != 0"
    wb = w.astype(jnp.float32).reshape(*lead, n // BLOCK, BLOCK)
    scales = _e8m0_scale(jnp.max(jnp.abs(wb), axis=-1), fmt_max)
    vals = wb / scales[..., None]
    if round_dtype is not None:                # fp6/fp4: emulated formats
        if lowbits.is_packable(fmt):           # trace-safe RTNE arithmetic
            vals = lowbits.quantize_values(vals, fmt)
        else:   # byte format emulated (ancient JAX w/o fp8): host rounding
            vals = jnp.asarray(   # jaxlint: disable=JL101(host fallback for ancient JAX without native fp8 dtypes; unreachable under jit there because the whole engine already requires eager weights at build time)
                np.asarray(vals).astype(round_dtype).astype(np.float32))
    q = vals.astype(dtype)
    return q.reshape(*lead, n), scales


def dequantize_blockwise(q: jax.Array, scales: jax.Array,
                         out_dtype=jnp.bfloat16) -> jax.Array:
    *lead, n = q.shape
    block = n // scales.shape[-1]
    qb = q.astype(jnp.float32).reshape(*lead, n // block, block)
    return (qb * scales[..., None]).reshape(*lead, n).astype(out_dtype)


# --------------------------------------------------------------------- #
# Weight-only PTQ over a parameter tree (Tab VIII serving sweep)
# --------------------------------------------------------------------- #

class _TreeStats:
    """Shared MSE/byte accounting for the tree quantizers.

    The squared-error sums accumulate as 0-d *device* scalars; nothing
    forces a host sync until :meth:`mse` reduces them in one
    ``jax.device_get`` per tree.  (The previous copy-pasted accounting
    called ``float(jnp.sum(...))`` twice per leaf — two blocking
    round trips per parameter, dominating engine build time on real
    devices; ``repro.analysis.sanitize`` counts exactly this.)
    """

    def __init__(self):
        self.n_q = 0
        self.q_bytes = 0
        self.w_bytes = 0
        self.w_elems = 0
        self._err = []       # per-leaf device scalars: sum(err^2)
        self._ref = []       # per-leaf device scalars: sum(ref^2)

    def passthrough(self, leaf) -> None:
        self.q_bytes += leaf.nbytes

    def quantized(self, deq, leaf, stored_bytes: int) -> None:
        self.n_q += 1
        self.q_bytes += stored_bytes
        self.w_elems += leaf.size
        ref = leaf.astype(jnp.float32)
        err = deq.astype(jnp.float32) - ref
        self._err.append(jnp.sum(jnp.square(err)))
        self._ref.append(jnp.sum(jnp.square(ref)))

    def mse(self) -> float:
        if not self._err:
            return 0.0
        num, den = jax.device_get((jnp.sum(jnp.stack(self._err)),
                                   jnp.sum(jnp.stack(self._ref))))
        return float(num) / max(float(den), 1e-30)


def _quantizable(path_names, leaf) -> bool:
    if leaf.ndim < 2:
        return False
    if leaf.shape[-1] % BLOCK != 0:
        return False
    name = path_names[-1]
    return name in ("w1", "w2", "w3", "wq", "wk", "wv", "wo", "embed",
                    "unembed", "wz", "wx", "out_proj")


def quantize_params(params: Any, fmt: str, compute_dtype=jnp.bfloat16
                    ) -> Tuple[Any, dict]:
    """Quantize-dequantize (weight-only, fake-quant) a parameter tree.

    Returns (params', stats).  Mirrors what a deployed engine does with
    ``repro.kernels.qmatmul`` keeping weights resident in ``fmt`` — here we
    materialize the dequantized bf16 copy because the XLA path consumes
    dense arrays; storage-byte accounting for the energy model uses
    ``stats['quantized_bytes']`` at the *true packed* width
    (``compat.storage_bytes_per_element``: fp4 0.5 B, fp6 0.75 B, fp8
    1 B — what :func:`quantize_tree` actually materializes), with scales
    counted at the 1-byte e8m0 store (one uint8 code per block, what
    :func:`quantize_tree` keeps), not fp32.
    """
    if fmt in ("float32", "bfloat16", "float16"):
        cast = jax.tree.map(lambda w: w.astype(jnp.dtype(fmt))
                            if w.ndim >= 2 else w, params)
        nbytes = sum(x.nbytes for x in jax.tree.leaves(cast))
        return cast, {"format": fmt, "quantized_bytes": nbytes,
                      "n_quantized": 0, "mse": 0.0,
                      "bytes_per_element": jnp.dtype(fmt).itemsize}

    bpe = compat.storage_bytes_per_element(fmt, packed=True)
    stats = _TreeStats()

    def visit(path, leaf):
        names = tuple(str(getattr(k, "key", k)) for k in path)
        if not _quantizable(names, leaf):
            stats.passthrough(leaf)
            return leaf
        q, s = quantize_blockwise(leaf, fmt)
        deq = dequantize_blockwise(q, s, compute_dtype)
        # scales: 1 B e8m0 each
        stats.quantized(deq, leaf, int(leaf.size * bpe) + s.size)
        return deq

    out = jax.tree_util.tree_map_with_path(visit, params)
    return out, {"format": fmt, "quantized_bytes": int(stats.q_bytes),
                 "n_quantized": stats.n_q, "bytes_per_element": bpe,
                 "mse": stats.mse()}


# --------------------------------------------------------------------- #
# True quantized weight storage (packed sub-byte via repro.lowbits)
# --------------------------------------------------------------------- #

def quantize_tree(params: Any, fmt: str, packed: bool = True
                  ) -> Tuple[Any, dict]:
    """Quantize a parameter tree into *stored* low-precision form.

    Unlike :func:`quantize_params` (fake-quant: returns dense
    ``compute_dtype`` arrays), this keeps the quantized representation:
    each quantizable leaf becomes ``{"q": codes, "scales": s, "fmt":
    fmt}`` where ``q`` is the bit-packed uint8 array (``packed=True``
    and the format is sub-byte: fp4 2 values/byte, fp6 4 values in 3
    bytes) or the registry container array (``packed=False`` — the
    byte-aligned oracle layout), and ``scales`` is the **packed e8m0
    store**: one uint8 biased-exponent code per block
    (``lowbits.e8m0_encode``, lossless for the power-of-two scales the
    quantizer emits) instead of 4-byte fp32.  Non-quantizable leaves
    pass through.

    Stats report *measured* bytes (``sum(arr.nbytes)`` over what is
    actually stored), not nominal widths — the number the Tab VII/VIII
    benchmarks quote as HBM weight traffic.  :func:`dequantize_tree`
    reverses.
    """
    do_pack = packed and lowbits.is_packable(fmt)
    stats = _TreeStats()

    def visit(path, leaf):
        names = tuple(str(getattr(k, "key", k)) for k in path)
        if not _quantizable(names, leaf):
            stats.passthrough(leaf)
            return leaf
        q, s = quantize_blockwise(leaf, fmt)
        deq = dequantize_blockwise(q, s, jnp.float32)
        if do_pack:
            q = jnp.asarray(lowbits.pack(
                np.asarray(q.astype(jnp.float32)), fmt))
        s_codes = jnp.asarray(lowbits.e8m0_encode(np.asarray(s)))
        stats.quantized(deq, leaf, q.nbytes + s_codes.nbytes)
        stats.w_bytes += q.nbytes
        return {"q": q, "scales": s_codes, "scale_fmt": "e8m0",
                "fmt": fmt, "shape": leaf.shape, "packed": do_pack}

    store = jax.tree_util.tree_map_with_path(visit, params)
    return store, {"format": fmt, "packed": do_pack,
                   "quantized_bytes": int(stats.q_bytes),
                   "n_quantized": stats.n_q,
                   "weight_bytes": int(stats.w_bytes),
                   "mse": stats.mse(),
                   "bytes_per_element": (
                       stats.w_bytes / stats.w_elems if stats.w_elems
                       else compat.storage_bytes_per_element(
                           fmt, packed=do_pack))}


def _is_qleaf(x: Any) -> bool:
    return isinstance(x, dict) and set(x) >= {"q", "scales", "fmt"}


def dequantize_tree(store: Any, compute_dtype=jnp.bfloat16) -> Any:
    """Materialize dense ``compute_dtype`` params from a quantize_tree
    store (unpacking bit-packed leaves and decoding 1-byte e8m0 scales
    through ``repro.lowbits``)."""

    def leaf(x):
        if not _is_qleaf(x):
            return x
        q = x["q"]
        if x.get("packed"):
            n = x["shape"][-1]
            vals = lowbits.unpack(np.asarray(q), x["fmt"], n)
            q = jnp.asarray(vals.reshape(x["shape"]))
        s = x["scales"]
        if x.get("scale_fmt") == "e8m0":
            s = lowbits.e8m0_decode(s)
        return dequantize_blockwise(q, s, compute_dtype)

    return jax.tree.map(leaf, store, is_leaf=_is_qleaf)
