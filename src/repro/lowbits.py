"""Bit-packed sub-byte storage for the emulated mma formats (Tab V).

The paper's sub-byte datatypes (e2m1 FP4, e2m3/e3m2 FP6) exist *for*
storage density: Tab V's packing discussion is explicit that fp4 tiles
pack 2 values/byte and fp6 tiles 4 values in 3 bytes.  The PR-1 compat
registry emulated these formats numerically (exact values in a 1-byte
e4m3 container) but stored them at container width — so the "~4x HBM
traffic drop" the qmatmul docstring promised was nominal, not measured.

This module is the packing layer behind ``repro.compat``'s dtype
registry:

* :class:`PackedSpec` — per-format bit layout (field widths, exponent
  bias, group geometry: how many values share how many bytes),
* :func:`encode` / :func:`decode` — value <-> bit-code conversion.
  Encoding rides ``ml_dtypes`` (its byte encoding IS the format's bit
  pattern, zero-extended into a uint8 — verified by the all-codes test);
  decoding is plain shift/mask/exp2 arithmetic so the *same* function
  body runs on numpy arrays on the host and on jnp tiles inside a
  Pallas kernel (``repro.kernels.qmatmul.qmatmul_packed_mkn`` expands
  nibble-packed k-blocks in VMEM with it),
* :func:`quantize_values` / :func:`encode_codes` / :func:`pack_codes` —
  the *trace-safe* twins of encode/pack: round-to-nearest-even into the
  format's value set, field assembly, and bit packing via pure
  shift/mask/exp2 arithmetic, so quantization itself can run under
  ``jit``/``vmap`` and inside Pallas kernels (the KV-cache write path
  quantizes on the fly every decode step),
* :func:`pack` / :func:`unpack` — vectorized (de)packing along the last
  axis, tail-padded with zero codes so odd lengths round-trip,
* :func:`packed_nbytes` — true storage accounting (0.5 B/elem fp4,
  0.75 B/elem fp6) used by the quantizer stats and benchmark artifacts,
* the **e8m0 scale codec** (:func:`e8m0_encode` / :func:`e8m0_decode` /
  :func:`e8m0_scale_code`) — block scales stored as 1-byte biased
  exponents (the paper's Tab V reserves e8m0 for exactly this), clamped
  to the representable range [2^-127, 2^127].  Holding power-of-two
  scales in fp32 wastes 4 bytes per block; at BLOCK=32 the 1-byte store
  takes fp4 from ~3.2x to ~3.8x measured HBM traffic drop.

Bit order is little-endian within a group: value ``i`` of an fp4 pair
occupies bits ``[4i, 4i+4)`` of the byte; an fp6 quad occupies the 24
bits of its 3 bytes in the same ascending order.

No ``repro`` imports here — this is a leaf module ``repro.compat``
builds its registry on top of.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import ml_dtypes
import numpy as np

__all__ = [
    "PackedSpec",
    "PACKED_FORMATS",
    "packed_spec",
    "is_packable",
    "encode",
    "decode",
    "quantize_values",
    "encode_codes",
    "pack",
    "pack_codes",
    "unpack",
    "unpack_codes",
    "packed_nbytes",
    "E8M0_BIAS",
    "E8M0_MIN_EXP",
    "E8M0_MAX_EXP",
    "e8m0_encode",
    "e8m0_decode",
    "e8m0_scale_code",
]


@dataclasses.dataclass(frozen=True)
class PackedSpec:
    """Bit layout + group geometry of one sub-byte format.

    ``values_per_group`` values are stored in ``bytes_per_group`` bytes:
    fp4 packs 2/1 (nibbles), fp6 packs 4/3 (24 bits) — the Tab V tile
    packing.  ``code_dtype`` is the ``ml_dtypes`` scalar whose uint8
    encoding equals the format's bit code (used for host-side encode,
    i.e. rounding float -> code).
    """

    name: str                # canonical registry name, e.g. "float4_e2m1fn"
    bits: int                # code width
    ebits: int               # exponent field width
    mbits: int               # mantissa field width
    bias: int                # exponent bias
    values_per_group: int    # values per packed group
    bytes_per_group: int     # bytes per packed group
    code_dtype: Any          # ml_dtypes dtype for host-side encoding
    max_finite: float = 0.0  # largest finite magnitude (saturation point)

    @property
    def bytes_per_element(self) -> float:
        return self.bytes_per_group / self.values_per_group

    def packed_len(self, n: int) -> int:
        """Packed byte count for ``n`` values (tail group zero-padded)."""
        g = self.values_per_group
        return (n + g - 1) // g * self.bytes_per_group


PACKED_FORMATS: Dict[str, PackedSpec] = {
    "float4_e2m1fn": PackedSpec("float4_e2m1fn", 4, ebits=2, mbits=1,
                                bias=1, values_per_group=2,
                                bytes_per_group=1,
                                code_dtype=ml_dtypes.float4_e2m1fn,
                                max_finite=6.0),
    "float6_e2m3fn": PackedSpec("float6_e2m3fn", 6, ebits=2, mbits=3,
                                bias=1, values_per_group=4,
                                bytes_per_group=3,
                                code_dtype=ml_dtypes.float6_e2m3fn,
                                max_finite=7.5),
    "float6_e3m2fn": PackedSpec("float6_e3m2fn", 6, ebits=3, mbits=2,
                                bias=3, values_per_group=4,
                                bytes_per_group=3,
                                code_dtype=ml_dtypes.float6_e3m2fn,
                                max_finite=28.0),
}


def packed_spec(name: str) -> PackedSpec:
    try:
        return PACKED_FORMATS[name]
    except KeyError:
        raise KeyError(f"format {name!r} has no packed storage layout; "
                       f"packable: {sorted(PACKED_FORMATS)}") from None


def is_packable(name: str) -> bool:
    return name in PACKED_FORMATS


def packed_nbytes(n: int, fmt: str) -> int:
    """True storage bytes for ``n`` values of ``fmt`` (no scales)."""
    return packed_spec(fmt).packed_len(n)


# --------------------------------------------------------------------- #
# value <-> code
# --------------------------------------------------------------------- #

def encode(values, fmt: str) -> np.ndarray:
    """Round float values to ``fmt`` and return uint8 bit codes (host).

    ``ml_dtypes`` encodes each sub-byte format's bit pattern in the low
    bits of one byte, so ``astype(code_dtype).view(uint8)`` is exactly
    "round, then read the code".
    """
    spec = packed_spec(fmt)
    a = np.asarray(values, dtype=np.float32)
    return a.astype(spec.code_dtype).view(np.uint8)


def decode(codes, fmt: str):
    """Bit codes -> float32 values, via shift/mask/exp2 arithmetic only.

    Works on numpy *and* jnp/traced arrays (no ml_dtypes, no table
    lookup), so Pallas kernels call this directly on VMEM tiles.
    """
    spec = packed_spec(fmt)
    c = codes.astype(np.int32) if isinstance(codes, np.ndarray) \
        else codes.astype("int32")
    m = c & ((1 << spec.mbits) - 1)
    e = (c >> spec.mbits) & ((1 << spec.ebits) - 1)
    s = c >> (spec.mbits + spec.ebits)
    frac = m.astype(np.float32) * np.float32(2.0 ** -spec.mbits)
    is_sub = (e == 0)
    # subnormal: frac * 2^(1-bias); normal: (1+frac) * 2^(e-bias)
    mag = _where(is_sub,
                 frac * np.float32(2.0 ** (1 - spec.bias)),
                 (np.float32(1.0) + frac)
                 * _exp2(e.astype(np.float32) - np.float32(spec.bias)))
    return _where(s != 0, -mag, mag)


def _xp(x):
    """numpy for numpy inputs, jax.numpy otherwise (traced arrays)."""
    if isinstance(x, np.ndarray) or np.isscalar(x):
        return np
    import jax.numpy as jnp
    return jnp


def _where(cond, a, b):
    return _xp(cond).where(cond, a, b)


def _exp2(x):
    return _xp(x).exp2(x)


def quantize_values(values, fmt: str):
    """Round values into ``fmt``'s value set: RTNE, saturating at
    ``max_finite`` — pure arithmetic, so it runs under ``jit``/``vmap``
    and inside Pallas kernels (the host-free twin of ``encode`` +
    ``decode``; bit-identical to ``ml_dtypes`` rounding, property-
    tested).  Returns float32 of the same shape.
    """
    spec = packed_spec(fmt)
    xp = _xp(values)
    x = values.astype(np.float32)
    a = xp.abs(x)
    # floor(log2(a)) via frexp (exact, unlike log2 rounding); a == 0 is
    # routed through 1.0 and comes out as 0 anyway.
    _, e2 = xp.frexp(xp.where(a > 0, a, np.float32(1.0)))
    e = xp.maximum(e2 - 1, 1 - spec.bias)        # subnormal exponent floor
    quant = xp.exp2((e - spec.mbits).astype(np.float32))
    r = xp.round(a / quant) * quant              # RTNE on the mantissa grid
    r = xp.minimum(r, np.float32(spec.max_finite))
    return xp.where(xp.signbit(x), -r, r).astype(np.float32)


def encode_codes(values, fmt: str):
    """Float values -> int32 bit codes via pure arithmetic (trace-safe).

    The jit-capable twin of :func:`encode` (which rides ml_dtypes on the
    host): rounds with :func:`quantize_values`, then assembles the
    sign/exponent/mantissa fields.  Used by the quantized KV-cache write
    path, which must encode inside a jitted decode step.
    """
    spec = packed_spec(fmt)
    xp = _xp(values)
    v = quantize_values(values, fmt)
    a = xp.abs(v)
    thr = np.float32(2.0 ** (1 - spec.bias))     # smallest normal
    _, e2 = xp.frexp(xp.where(a > 0, a, np.float32(1.0)))
    normal = a >= thr
    e = xp.where(normal, e2 - 1, 1 - spec.bias)
    # integer mantissa incl. the implicit bit: a * 2^(mbits - e)
    m = xp.round(a * xp.exp2((spec.mbits - e).astype(np.float32)))
    m = m.astype(np.int32)
    e_field = xp.where(normal, e + spec.bias, 0).astype(np.int32)
    m_field = m - xp.where(normal, 1 << spec.mbits, 0).astype(np.int32)
    sign = xp.signbit(v).astype(np.int32)   # signbit, not <0: -0.0 packs
    return ((sign << (spec.ebits + spec.mbits))
            | (e_field << spec.mbits) | m_field)


# --------------------------------------------------------------------- #
# pack / unpack along the last axis
# --------------------------------------------------------------------- #

def pack(values, fmt: str) -> np.ndarray:
    """(..., n) float values -> (..., packed_len(n)) uint8, host-side.

    Values are rounded to ``fmt`` first (exact when they already are
    ``fmt`` values, e.g. out of ``quantize_blockwise``); a tail shorter
    than the group is zero-code padded.
    """
    spec = packed_spec(fmt)
    codes = encode(values, fmt)
    *lead, n = codes.shape
    pad = (-n) % spec.values_per_group
    if pad:
        codes = np.concatenate(
            [codes, np.zeros((*lead, pad), np.uint8)], axis=-1)
    return pack_codes(codes, fmt)


def pack_codes(codes, fmt: str):
    """(..., n) int bit codes -> (..., n*bits/8) uint8; trace-safe.

    Pure shift/or/reshape (the inverse of :func:`unpack_codes`), so it
    runs on numpy or jnp arrays — including under jit in the KV-cache
    write path.  ``n`` must be a multiple of the group size (callers
    with odd tails pad first; :func:`pack` does).
    """
    spec = packed_spec(fmt)
    xp = _xp(codes)
    *lead, n = codes.shape
    g = spec.values_per_group
    if n % g:
        raise ValueError(f"pack_codes: n={n} not a multiple of the "
                         f"{fmt} group size {g}")
    grp = codes.astype(np.int32).reshape(*lead, n // g, g)
    if fmt == "float4_e2m1fn":
        by = (grp[..., 0] | (grp[..., 1] << 4))[..., None]
    else:                         # fp6: 4 codes -> 24 bits -> 3 bytes
        word = (grp[..., 0] | (grp[..., 1] << 6)
                | (grp[..., 2] << 12) | (grp[..., 3] << 18))
        by = xp.stack([word & 0xFF, (word >> 8) & 0xFF, word >> 16],
                      axis=-1)
    return by.reshape(*lead, -1).astype(np.uint8)


def unpack_codes(packed, fmt: str):
    """(..., nbytes) uint8 -> (..., values) int32 codes (padding incl.).

    Pure shift/mask/reshape — runs on numpy or jnp arrays, including
    inside Pallas kernels (the VMEM expand step of ``qmatmul_packed``).
    """
    spec = packed_spec(fmt)
    is_np = isinstance(packed, np.ndarray)
    b = packed.astype(np.int32) if is_np else packed.astype("int32")
    *lead, nb = b.shape
    if is_np:
        import numpy as xp
    else:
        import jax.numpy as xp
    if fmt == "float4_e2m1fn":
        grp = xp.stack([b & 0xF, b >> 4], axis=-1)
    else:
        tri = b.reshape(*lead, nb // spec.bytes_per_group, 3)
        word = tri[..., 0] | (tri[..., 1] << 8) | (tri[..., 2] << 16)
        grp = xp.stack([word & 0x3F, (word >> 6) & 0x3F,
                        (word >> 12) & 0x3F, (word >> 18) & 0x3F],
                       axis=-1)
    return grp.reshape(*lead, -1)


def unpack(packed, fmt: str, n: int):
    """(..., nbytes) uint8 -> (..., n) float32 (tail padding sliced off)."""
    vals = decode(unpack_codes(packed, fmt), fmt)
    return vals[..., :n]


# --------------------------------------------------------------------- #
# e8m0 scale codec (1-byte block-scale exponents, OCP MX / paper Tab V)
# --------------------------------------------------------------------- #
# e8m0 is an 8-bit *unsigned biased exponent* with no sign or mantissa:
# code c represents 2^(c - 127), c in [0, 254] (255 is NaN, never
# produced here).  Representable scales therefore span [2^-127, 2^127];
# everything below/above clamps.  All functions are pure arithmetic —
# they run on numpy or jnp arrays, under jit, and inside Pallas kernels
# (the flash_decode quantized-KV leg decodes scale bytes in VMEM).

E8M0_BIAS = 127
E8M0_MIN_EXP = -127        # code 0
E8M0_MAX_EXP = 127         # code 254

def e8m0_encode(scales):
    """Power-of-two fp32 scales -> uint8 e8m0 codes (clamped, exact for
    in-range powers of two — the round trip is bit-lossless)."""
    xp = _xp(scales)
    s = xp.maximum(scales.astype(np.float32), np.float32(1e-45))
    _, e2 = xp.frexp(s)                     # s = m * 2^e2, m in [0.5, 1)
    exp = xp.clip(e2 - 1, E8M0_MIN_EXP, E8M0_MAX_EXP)
    return (exp + E8M0_BIAS).astype(np.uint8)


def e8m0_decode(codes):
    """uint8 e8m0 codes -> fp32 power-of-two scales (2^(code - 127))."""
    xp = _xp(codes)
    return xp.exp2(codes.astype(np.float32) - np.float32(E8M0_BIAS))


def e8m0_scale_code(absmax, fmt_max: float):
    """Block absmax -> the e8m0 code of the smallest power-of-two scale
    with absmax/scale <= fmt_max: ceil(log2(absmax/fmt_max)), clamped to
    e8m0's representable exponent range.  This IS the quantizer's scale
    rule (``serve.quant._e8m0_scale`` decodes this code), so scales are
    1-byte-storable by construction."""
    xp = _xp(absmax)
    a = xp.maximum(absmax.astype(np.float32), np.float32(1e-38))
    exp = xp.ceil(xp.log2(a / np.float32(fmt_max)))
    exp = xp.clip(exp, E8M0_MIN_EXP, E8M0_MAX_EXP)
    return (exp + E8M0_BIAS).astype(np.uint8)
