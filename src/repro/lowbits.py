"""Bit-packed sub-byte storage for the emulated mma formats (Tab V).

The paper's sub-byte datatypes (e2m1 FP4, e2m3/e3m2 FP6) exist *for*
storage density: Tab V's packing discussion is explicit that fp4 tiles
pack 2 values/byte and fp6 tiles 4 values in 3 bytes.  The PR-1 compat
registry emulated these formats numerically (exact values in a 1-byte
e4m3 container) but stored them at container width — so the "~4x HBM
traffic drop" the qmatmul docstring promised was nominal, not measured.

This module is the packing layer behind ``repro.compat``'s dtype
registry:

* :class:`PackedSpec` — per-format bit layout (field widths, exponent
  bias, group geometry: how many values share how many bytes),
* :func:`encode` / :func:`decode` — value <-> bit-code conversion.
  Encoding rides ``ml_dtypes`` (its byte encoding IS the format's bit
  pattern, zero-extended into a uint8 — verified by the all-codes test);
  decoding is plain shift/mask/exp2 arithmetic so the *same* function
  body runs on numpy arrays on the host and on jnp tiles inside a
  Pallas kernel (``repro.kernels.qmatmul.qmatmul_packed_mkn`` expands
  nibble-packed k-blocks in VMEM with it),
* :func:`pack` / :func:`unpack` — vectorized (de)packing along the last
  axis, tail-padded with zero codes so odd lengths round-trip,
* :func:`packed_nbytes` — true storage accounting (0.5 B/elem fp4,
  0.75 B/elem fp6) used by the quantizer stats and benchmark artifacts.

Bit order is little-endian within a group: value ``i`` of an fp4 pair
occupies bits ``[4i, 4i+4)`` of the byte; an fp6 quad occupies the 24
bits of its 3 bytes in the same ascending order.

No ``repro`` imports here — this is a leaf module ``repro.compat``
builds its registry on top of.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import ml_dtypes
import numpy as np

__all__ = [
    "PackedSpec",
    "PACKED_FORMATS",
    "packed_spec",
    "is_packable",
    "encode",
    "decode",
    "pack",
    "unpack",
    "packed_nbytes",
]


@dataclasses.dataclass(frozen=True)
class PackedSpec:
    """Bit layout + group geometry of one sub-byte format.

    ``values_per_group`` values are stored in ``bytes_per_group`` bytes:
    fp4 packs 2/1 (nibbles), fp6 packs 4/3 (24 bits) — the Tab V tile
    packing.  ``code_dtype`` is the ``ml_dtypes`` scalar whose uint8
    encoding equals the format's bit code (used for host-side encode,
    i.e. rounding float -> code).
    """

    name: str                # canonical registry name, e.g. "float4_e2m1fn"
    bits: int                # code width
    ebits: int               # exponent field width
    mbits: int               # mantissa field width
    bias: int                # exponent bias
    values_per_group: int    # values per packed group
    bytes_per_group: int     # bytes per packed group
    code_dtype: Any          # ml_dtypes dtype for host-side encoding

    @property
    def bytes_per_element(self) -> float:
        return self.bytes_per_group / self.values_per_group

    def packed_len(self, n: int) -> int:
        """Packed byte count for ``n`` values (tail group zero-padded)."""
        g = self.values_per_group
        return (n + g - 1) // g * self.bytes_per_group


PACKED_FORMATS: Dict[str, PackedSpec] = {
    "float4_e2m1fn": PackedSpec("float4_e2m1fn", 4, ebits=2, mbits=1,
                                bias=1, values_per_group=2,
                                bytes_per_group=1,
                                code_dtype=ml_dtypes.float4_e2m1fn),
    "float6_e2m3fn": PackedSpec("float6_e2m3fn", 6, ebits=2, mbits=3,
                                bias=1, values_per_group=4,
                                bytes_per_group=3,
                                code_dtype=ml_dtypes.float6_e2m3fn),
    "float6_e3m2fn": PackedSpec("float6_e3m2fn", 6, ebits=3, mbits=2,
                                bias=3, values_per_group=4,
                                bytes_per_group=3,
                                code_dtype=ml_dtypes.float6_e3m2fn),
}


def packed_spec(name: str) -> PackedSpec:
    try:
        return PACKED_FORMATS[name]
    except KeyError:
        raise KeyError(f"format {name!r} has no packed storage layout; "
                       f"packable: {sorted(PACKED_FORMATS)}") from None


def is_packable(name: str) -> bool:
    return name in PACKED_FORMATS


def packed_nbytes(n: int, fmt: str) -> int:
    """True storage bytes for ``n`` values of ``fmt`` (no scales)."""
    return packed_spec(fmt).packed_len(n)


# --------------------------------------------------------------------- #
# value <-> code
# --------------------------------------------------------------------- #

def encode(values, fmt: str) -> np.ndarray:
    """Round float values to ``fmt`` and return uint8 bit codes (host).

    ``ml_dtypes`` encodes each sub-byte format's bit pattern in the low
    bits of one byte, so ``astype(code_dtype).view(uint8)`` is exactly
    "round, then read the code".
    """
    spec = packed_spec(fmt)
    a = np.asarray(values, dtype=np.float32)
    return a.astype(spec.code_dtype).view(np.uint8)


def decode(codes, fmt: str):
    """Bit codes -> float32 values, via shift/mask/exp2 arithmetic only.

    Works on numpy *and* jnp/traced arrays (no ml_dtypes, no table
    lookup), so Pallas kernels call this directly on VMEM tiles.
    """
    spec = packed_spec(fmt)
    c = codes.astype(np.int32) if isinstance(codes, np.ndarray) \
        else codes.astype("int32")
    m = c & ((1 << spec.mbits) - 1)
    e = (c >> spec.mbits) & ((1 << spec.ebits) - 1)
    s = c >> (spec.mbits + spec.ebits)
    frac = m.astype(np.float32) * np.float32(2.0 ** -spec.mbits)
    is_sub = (e == 0)
    # subnormal: frac * 2^(1-bias); normal: (1+frac) * 2^(e-bias)
    mag = _where(is_sub,
                 frac * np.float32(2.0 ** (1 - spec.bias)),
                 (np.float32(1.0) + frac)
                 * _exp2(e.astype(np.float32) - np.float32(spec.bias)))
    return _where(s != 0, -mag, mag)


def _where(cond, a, b):
    if isinstance(cond, np.ndarray):
        return np.where(cond, a, b)
    import jax.numpy as jnp
    return jnp.where(cond, a, b)


def _exp2(x):
    if isinstance(x, np.ndarray):
        return np.exp2(x)
    import jax.numpy as jnp
    return jnp.exp2(x)


# --------------------------------------------------------------------- #
# pack / unpack along the last axis
# --------------------------------------------------------------------- #

def pack(values, fmt: str) -> np.ndarray:
    """(..., n) float values -> (..., packed_len(n)) uint8, host-side.

    Values are rounded to ``fmt`` first (exact when they already are
    ``fmt`` values, e.g. out of ``quantize_blockwise``); a tail shorter
    than the group is zero-code padded.
    """
    spec = packed_spec(fmt)
    codes = encode(values, fmt)
    *lead, n = codes.shape
    g = spec.values_per_group
    pad = (-n) % g
    if pad:
        codes = np.concatenate(
            [codes, np.zeros((*lead, pad), np.uint8)], axis=-1)
    grp = codes.reshape(*lead, -1, g).astype(np.uint32)
    if fmt == "float4_e2m1fn":
        by = (grp[..., 0] | (grp[..., 1] << 4))[..., None]
    else:                         # fp6: 4 codes -> 24 bits -> 3 bytes
        word = (grp[..., 0] | (grp[..., 1] << 6)
                | (grp[..., 2] << 12) | (grp[..., 3] << 18))
        by = np.stack([word & 0xFF, (word >> 8) & 0xFF, word >> 16],
                      axis=-1)
    return by.reshape(*lead, -1).astype(np.uint8)


def unpack_codes(packed, fmt: str):
    """(..., nbytes) uint8 -> (..., values) int32 codes (padding incl.).

    Pure shift/mask/reshape — runs on numpy or jnp arrays, including
    inside Pallas kernels (the VMEM expand step of ``qmatmul_packed``).
    """
    spec = packed_spec(fmt)
    is_np = isinstance(packed, np.ndarray)
    b = packed.astype(np.int32) if is_np else packed.astype("int32")
    *lead, nb = b.shape
    if is_np:
        import numpy as xp
    else:
        import jax.numpy as xp
    if fmt == "float4_e2m1fn":
        grp = xp.stack([b & 0xF, b >> 4], axis=-1)
    else:
        tri = b.reshape(*lead, nb // spec.bytes_per_group, 3)
        word = tri[..., 0] | (tri[..., 1] << 8) | (tri[..., 2] << 16)
        grp = xp.stack([word & 0x3F, (word >> 6) & 0x3F,
                        (word >> 12) & 0x3F, (word >> 18) & 0x3F],
                       axis=-1)
    return grp.reshape(*lead, -1)


def unpack(packed, fmt: str, n: int):
    """(..., nbytes) uint8 -> (..., n) float32 (tail padding sliced off)."""
    vals = decode(unpack_codes(packed, fmt), fmt)
    return vals[..., :n]
