"""Fault-tolerant training loop.

Composes the substrate: stream -> jitted train_step -> metrics, with
checkpoint/restart (resume-from-latest), async snapshots, straggler
watchdog, and heartbeat — the parts of the 1000+-node posture a CPU
container can actually exercise (and tests do).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint import Checkpointer
from repro.distributed.elastic import Heartbeat, StepWatchdog


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    checkpoint_dir: Optional[str] = None
    async_checkpoint: bool = True
    straggler_deadline_factor: float = 3.0


def run_train_loop(
    train_step: Callable,
    state: Any,
    stream,                       # object with .batch(step)
    loop_cfg: TrainLoopConfig,
    on_metrics: Optional[Callable[[int, Dict[str, float]], None]] = None,
) -> Any:
    """Runs to ``total_steps``; resumes from the latest checkpoint if one
    exists in ``checkpoint_dir``.  Returns the final state."""
    ckpt = None
    start_step = 0
    if loop_cfg.checkpoint_dir:
        ckpt = Checkpointer(loop_cfg.checkpoint_dir,
                            async_save=loop_cfg.async_checkpoint)
        restored = ckpt.restore_latest(like=state)
        if restored is not None:
            state, start_step = restored
            print(f"[train] resumed from step {start_step}")
        hb = Heartbeat(loop_cfg.checkpoint_dir)
    else:
        hb = None

    watchdog = StepWatchdog(loop_cfg.straggler_deadline_factor)
    history: List[Dict[str, float]] = []

    for step in range(start_step, loop_cfg.total_steps):
        watchdog.start_step(step)
        batch = stream.batch(step)
        state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics["loss"])
        event = watchdog.end_step()
        if event is not None:
            print(f"[train] straggler step {event.step}: "
                  f"{event.duration_s:.3f}s vs median {event.median_s:.3f}s"
                  f" — snapshotting")
            if ckpt:
                ckpt.save(state, step + 1, block=False)
        if hb:
            hb.beat(step)
        if step % loop_cfg.log_every == 0 or step == loop_cfg.total_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m})
            if on_metrics:
                on_metrics(step, m)
            else:
                print(f"[train] step {step:5d} loss {m['loss']:.4f} "
                      f"acc {m['acc']:.3f} gnorm {m['grad_norm']:.2f}")
        if ckpt and (step + 1) % loop_cfg.checkpoint_every == 0:
            ckpt.save(state, step + 1, block=False)

    if ckpt:
        ckpt.save(state, loop_cfg.total_steps, block=True)
        ckpt.close()
    return state, history
