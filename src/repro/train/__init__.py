"""Training substrate: loss, train step (grad-accum microbatching), loop."""

from repro.train.step import (  # noqa: F401
    cross_entropy_loss,
    make_loss_fn,
    make_train_step,
    train_state_init,
)
from repro.train.loop import TrainLoopConfig, run_train_loop  # noqa: F401
from repro.train.local_dp import make_local_dp_train_step  # noqa: F401
