"""Explicit data-parallel trainer via shard_map — deferred gradient
reduction + int8-compressed all-reduce.

The auto-SPMD (pjit) trainer re-reduces weight gradients on EVERY
microbatch of the accumulation scan (§Perf K3: ~2 TB/device/step of dw
all-reduce on the 1T MoE cell; 8x the necessary wire bytes at accum=8).
XLA cannot express "accumulate unreduced partial gradients" under jit —
shard_map can: each data shard accumulates LOCAL gradients across all its
microbatches and the reduction happens ONCE, optionally int8-quantized
with stochastic rounding (2x wire vs fp32; unbiased — see
repro.distributed.compression).

Scope: replicated-parameter DP (no TP/FSDP inside the shard_map), i.e.
models whose params fit one device — the right tool for the <=3B archs on
data-only meshes, and the measurement vehicle for the deferred-reduction
collective win (benchmarks/collectives_bench.py).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.distributed.compression import compressed_psum_tree
from repro.models.model import Model
from repro.optim import AdamWConfig, adamw_update
from repro.train.step import make_loss_fn


def make_local_dp_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    mesh: Mesh,
    axis: str = "data",
    accum_steps: int = 1,
    compress: bool = False,
    seed: int = 0,
) -> Callable:
    """train_step(state, batch) -> (state, metrics), shard_map-DP.

    state is replicated; batch dim 0 is sharded over ``axis``.  Gradients
    are accumulated locally (fp32) over ``accum_steps`` microbatches and
    reduced exactly once.
    """
    loss_fn = make_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    world = int(mesh.shape[axis])

    def local_step(state, batch, key):
        params = state["params"]

        def micro(batch_i):
            (_, m), g = grad_fn(params, batch_i)
            return g, m

        if accum_steps == 1:
            grads, metrics = micro(batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), batch)

            def acc(carry, b_i):
                g_sum, m_sum = carry
                g, m = micro(b_i)
                return (jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g),
                    jax.tree.map(jnp.add, m_sum, m)), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            m0 = {k: jnp.zeros((), jnp.float32)
                  for k in ("loss", "ce", "acc", "moe_lb_loss",
                            "moe_z_loss", "moe_dropped")}
            (g_sum, m_sum), _ = jax.lax.scan(acc, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            metrics = jax.tree.map(lambda m: m / accum_steps, m_sum)

        # THE deferred reduction: exactly one collective per step
        if compress:
            grads = compressed_psum_tree(grads, key, axis, world)
        else:
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g, axis), grads)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics)

        new_params, new_opt = adamw_update(opt_cfg, params, grads,
                                           state["opt"])
        metrics = dict(metrics)
        metrics["grad_norm"] = jax.tree.reduce(
            jnp.add, jax.tree.map(
                lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                grads)) ** 0.5
        return {"params": new_params, "opt": new_opt}, metrics

    batch_spec = P(axis)
    mapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(P(), batch_spec, P()),
        out_specs=(P(), P()),
        check_vma=False,
    )

    @jax.jit
    def train_step(state, batch):
        step_no = state["opt"]["step"]
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step_no)
        return mapped(state, batch, key)

    return train_step
