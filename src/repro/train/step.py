"""Train step builder.

One jitted ``train_step(state, batch) -> (state, metrics)`` per
(arch x shape), with:

* fp32 cross-entropy (+ router aux losses for MoE archs),
* gradient accumulation as a ``lax.scan`` over microbatches — the carry
  holds fp32 gradient sums, so the dry-run memory analysis reflects the
  real activation footprint of one microbatch, not the whole global batch,
* global-norm clipping + AdamW inside (see ``repro.optim``),
* state donation handled at the jit call site (launch/dryrun, launch/train).

The loss slices the trunk logits to the *text* positions (VLM trunks carry
a patch prefix) and shifts by one for next-token prediction.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update

MOE_LB_WEIGHT = 0.01
MOE_Z_WEIGHT = 0.001


def cross_entropy_loss(logits: jax.Array, targets: jax.Array,
                       mask: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Mean token CE (fp32) and accuracy.  logits (b,s,v), targets (b,s)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    acc = (jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32)
    if mask is None:
        return jnp.mean(nll), jnp.mean(acc)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom, jnp.sum(acc * mask) / denom


def chunked_cross_entropy(features: jax.Array, w_out: jax.Array,
                          targets: jax.Array,
                          mask: Optional[jax.Array] = None,
                          softcap: Optional[float] = None,
                          chunk: int = 2048
                          ) -> Tuple[jax.Array, jax.Array]:
    """CE without materializing (b, s, vocab) logits.

    Scans sequence chunks; each chunk's logits ((b, chunk, v) fp32) live
    only inside a rematted step, so peak memory is O(b*chunk*v) instead
    of O(b*s*v) — at 150k vocabs this is the difference between ~5 GiB
    and ~150 MiB per device (EXPERIMENTS.md §Perf iteration 0).

    features (b, s, d), targets (b, s); returns (mean nll, accuracy).
    """
    b, s, d = features.shape
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    if s % chunk != 0:
        pad = chunk - s % chunk
        features = jnp.pad(features, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        s += pad
    n_c = s // chunk
    xc = features.reshape(b, n_c, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, n_c, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, n_c, chunk).transpose(1, 0, 2)

    def step(carry, inp):
        nll_sum, acc_sum, tok_sum = carry
        x_i, t_i, m_i = inp
        logits = jnp.einsum("bsd,dv->bsv", x_i.astype(jnp.float32),
                            w_out.astype(jnp.float32))
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_i[..., None], axis=-1)[..., 0]
        hit = (jnp.argmax(logits, axis=-1) == t_i).astype(jnp.float32)
        return (nll_sum + jnp.sum((logz - gold) * m_i),
                acc_sum + jnp.sum(hit * m_i),
                tok_sum + jnp.sum(m_i)), None

    step = jax.checkpoint(
        step, policy=jax.checkpoint_policies.nothing_saveable)
    (nll, acc, toks), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32),) * 3, (xc, tc, mc))
    toks = jnp.maximum(toks, 1.0)
    return nll / toks, acc / toks


def make_loss_fn(model: Model, ce_chunk: int = 2048) -> Callable:
    cfg = model.cfg

    def loss_fn(params: Any, batch: Dict[str, jax.Array]):
        features, aux = model.features(params, batch)
        tokens = batch["tokens"]
        features = features[:, -tokens.shape[1]:]      # text positions only
        mask = batch.get("loss_mask")
        mask = mask[:, 1:] if mask is not None else None
        ce, acc = chunked_cross_entropy(
            features[:, :-1], model.unembed_weight(params),
            tokens[:, 1:], mask, softcap=cfg.final_logit_softcap,
            chunk=min(ce_chunk, max(tokens.shape[1] - 1, 1)))
        loss = (ce + MOE_LB_WEIGHT * aux["moe_lb_loss"]
                + MOE_Z_WEIGHT * aux["moe_z_loss"])
        metrics = {"loss": loss, "ce": ce, "acc": acc, **aux}
        return loss, metrics
    return loss_fn


def train_state_init(model: Model, opt_cfg: AdamWConfig, key: jax.Array
                     ) -> dict:
    params = model.init(key)
    return {"params": params, "opt": adamw_init(opt_cfg, params)}


def _microbatch(batch: Dict[str, jax.Array], accum: int,
                dp_axes: Optional[tuple] = None) -> Dict[str, jax.Array]:
    """(b, ...) -> (accum, b/accum, ...), microbatch-major.

    The reshape splits the sharded batch dim; XLA's propagation can pick
    the WRONG factor (sharding the accum dim => replicating the batch and
    silently voiding the accumulation's memory win — caught by the
    dry-run memory analysis), so when ``dp_axes`` is given we pin the
    microbatch dim's sharding explicitly."""
    from jax.sharding import PartitionSpec as P

    def r(x):
        b = x.shape[0]
        assert b % accum == 0, f"batch {b} % accum {accum} != 0"
        out = x.reshape(accum, b // accum, *x.shape[1:])
        if dp_axes:
            spec = P(None, dp_axes, *(None for _ in x.shape[1:]))
            out = jax.lax.with_sharding_constraint(out, spec)
        return out
    return jax.tree.map(r, batch)


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    accum_steps: int = 1,
                    dp_axes: Optional[tuple] = None,
                    accum_dtype: str = "float32") -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum_dtype="bfloat16"`` halves the per-microbatch weight-gradient
    psum/regather traffic that XLA SPMD emits inside the accumulation
    scan — for the 1T-param MoE cell that traffic is ~2 TB/device/step
    at fp32 (§Perf iteration; the full fix is shard_map-local DP)."""
    loss_fn = make_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    acc_dt = jnp.dtype(accum_dtype)

    def train_step(state: dict, batch: Dict[str, jax.Array]):
        params = state["params"]
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = _microbatch(batch, accum_steps, dp_axes)

            def accum_fn(carry, mb):
                g_sum, m_sum = carry
                (_, m), g = grad_fn(params, mb)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), g_sum, g)
                m_sum = jax.tree.map(lambda a, b: a + b, m_sum, m)
                return (g_sum, m_sum), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            m0 = {k: jnp.zeros((), jnp.float32)
                  for k in ("loss", "ce", "acc", "moe_lb_loss",
                            "moe_z_loss", "moe_dropped")}
            (g_sum, m_sum), _ = jax.lax.scan(accum_fn, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            metrics = jax.tree.map(lambda m: m / accum_steps, m_sum)

        new_params, new_opt = adamw_update(opt_cfg, params, grads,
                                           state["opt"])
        metrics = dict(metrics)
        metrics["grad_norm"] = jax.tree.reduce(
            lambda a, b: a + b,
            jax.tree.map(lambda g: jnp.sum(jnp.square(
                g.astype(jnp.float32))), grads)) ** 0.5
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
