"""gemma2-2b — local/global alternating attention + logit softcaps
[arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.  head_dim=256.
Even layers: sliding window 4096; odd layers: global.  Attention logits
softcapped at 50, final logits at 30.  GeGLU MLP.

``long_500k`` RUNS: half the layers are window-bounded (KV <= 4096); the
global layers keep full 500k KV and dominate the memory term — recorded in
the roofline table.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    mlp_variant="geglu",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=4096,
    local_global_period=2,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
