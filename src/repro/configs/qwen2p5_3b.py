"""qwen2.5-3b — dense GQA transformer with QKV bias [hf:Qwen/Qwen2.5].

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.  head_dim=128.
Pure full attention => ``long_500k`` SKIPPED (see DESIGN.md §5).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    mlp_variant="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
