"""gptneox-1b — GPT-NeoX-family config for the paper's §VII.B case study.

The paper runs GPT-NeoX through TensorRT at FP32/FP16/FP8 and reports
power per precision (Tab VIII).  This config is the serving-stack subject
for our Tab VIII analogue (benchmarks.tab8_inference): a ~1B NeoX-shaped
model (16L d_model=2048 16H MHA d_ff=8192 vocab=50432).  Not part of the
assigned 10-arch pool; exists for the paper-claims validation.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gptneox-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50432,
    mlp_variant="gelu",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
