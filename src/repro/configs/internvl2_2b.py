"""internvl2-2b — InternViT + InternLM2 VLM [arXiv:2404.16821].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  head_dim=128.
The InternViT vision frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (batch, n_patches, d_model) that are
prepended to the token embeddings (early fusion into the LM trunk).
``long_500k`` SKIPPED (full attention).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    mlp_variant="swiglu",
    frontend="vision",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
