"""jamba-v0.1-52b — hybrid Mamba + attention 7:1 interleave with MoE
[arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Period-8 pattern: attention block at position 0, SSM blocks at 1..7; MoE
FFN every 2nd block.  Jamba v0.1 uses Mamba-1 (d_state=16); we implement
the mixer with our Mamba-2 SSD block at d_state=16 (DESIGN.md §5 notes the
substitution — SSD at n=16 is numerically the same state size with a
chunk-parallel form).  ``long_500k`` RUNS (4 attention layers hold full KV;
28 SSM layers carry O(1) state).

fsdp=True: 52B params exceed single-axis TP capacity at 16 GiB/chip.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    mlp_variant="swiglu",
    moe_num_experts=16,
    moe_top_k=2,
    moe_every=2,
    attn_every=8,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=256,
    fsdp=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
