"""llama3.2-3b — small llama3 dense GQA transformer [hf:meta-llama].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.  head_dim=128,
rope_theta=500000, SwiGLU.  Pure full attention => ``long_500k`` SKIPPED.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    mlp_variant="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
