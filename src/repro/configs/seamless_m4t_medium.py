"""seamless-m4t-medium — encoder-decoder multimodal backbone
[arXiv:2308.11596].

12L encoder + 12L decoder, d_model=1024 16H (kv=16, i.e. MHA) d_ff=4096
vocab=256206.  The audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, src_len, d_model); the
text decoder consumes token ids.  Decoder blocks carry cross-attention
over cached encoder output.  ``long_500k`` SKIPPED (full attention).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    mlp_variant="gelu",
    is_encoder_decoder=True,
    n_encoder_layers=12,
    frontend="audio",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
