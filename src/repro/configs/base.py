"""Architecture + shape configuration system.

Every assigned architecture is an :class:`ArchConfig` (one module per arch
under ``repro.configs``); every benchmark shape is a :class:`ShapeConfig`.
``reduced()`` yields the same-family small config used by the CPU smoke
tests — the FULL configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation).

The block-pattern abstraction: a model is ``n_layers`` blocks arranged as a
repeating *period* of heterogeneous blocks (attention / SSM mixers, dense /
MoE FFNs).  ``block_pattern()`` returns one period; the model stacks layer
parameters per position-in-period and scans over periods, which keeps HLO
size O(period) instead of O(n_layers).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One block within a period (mixer + ffn)."""

    mixer: str                    # "attn" | "ssm" | "none"
    ffn: str                      # "dense" | "moe" | "none"
    window: Optional[int] = None  # sliding-window size for local attention
    cross_attn: bool = False      # decoder block with cross-attention


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str                   # dense | ssm | hybrid | moe | audio | vlm
    # trunk dimensions
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # MLP / norm
    mlp_variant: str = "swiglu"   # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    qkv_bias: bool = False
    # attention flavor
    rope_theta: float = 10000.0
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None   # used by blocks with window
    local_global_period: int = 0  # gemma2: alternate local/global every layer
    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1            # MoE FFN every k-th block (1 = all blocks)
    moe_d_ff: int = 0             # per-expert hidden dim (0 = use d_ff)
    moe_shared_expert: bool = False
    moe_capacity_factor: float = 1.25
    # hybrid (jamba): attention block every k-th block, SSM otherwise
    attn_every: int = 1           # 1 = all attention; 8 = jamba 1:7
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # encoder-decoder
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # modality frontend stub: input is precomputed frame/patch embeddings
    frontend: Optional[str] = None   # None | "audio" | "vision"
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    cache_dtype: str = ""         # KV-cache storage ("" = compute_dtype);
                                  # fp8 halves decode weight/KV traffic
    kv_format: str = ""           # blockwise-QUANTIZED KV storage: a
                                  # repro.compat registry format (e.g.
                                  # "float8_e4m3fn", "float4_e2m1fn");
                                  # K/V held as packed codes + 1-byte
                                  # e8m0 block scales, (de)quantized in
                                  # the cache write/read paths.  "" =
                                  # plain cast storage per cache_dtype.
    kv_formats: Tuple[str, ...] = ()   # per-POSITION-IN-PERIOD override of
                                  # kv_format (mixed-precision KV: e.g.
                                  # fp8 on global-attention layers, fp4
                                  # on sliding-window locals).  Length
                                  # must equal the block period; "" at a
                                  # position falls back to kv_format.
                                  # Applies to self- AND cross-attention
                                  # KV of that position.
    attn_chunk: int = 1024        # online-softmax KV block (XLA path)
    attn_repeat_kv: bool = False  # materialize KV at full q-head count:
                                  # the (hq)->(hkv, g) grouping reshape is
                                  # unshardable when hkv < mesh 'model'
                                  # (kimi: 8 kv heads on 16-way TP) —
                                  # repeating KV keeps q-heads sharded
    attn_seq_shard: bool = False  # context-parallel attention: shard the
                                  # q sequence dim over 'model' inside the
                                  # mixer (for archs whose head count the
                                  # model axis cannot divide, e.g.
                                  # llama3.2's 24 heads on 16-way TP,
                                  # where attention otherwise computes
                                  # fully replicated on that axis)
    # distribution hints
    fsdp: bool = False            # shard params over the data axis too
    remat: str = "block"          # "none" | "block" | "full"
    # batch-dim mesh axes for activation sharding constraints; set by the
    # launcher (dataclasses.replace) — () = no constraints (CPU tests).
    # Without these, XLA resolves the FSDP-weight x DP-batch einsum
    # ambiguity by REPLICATING the batch (measured 650 GiB/dev on the
    # llama4 train cell; EXPERIMENTS.md §Perf).
    batch_axes: Tuple[str, ...] = ()

    # ------------------------------------------------------------------ #
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def kv_format_for(self, pos_in_period: int) -> Optional[str]:
        """Effective KV format for one position-in-period (None = plain).

        ``kv_formats`` (per-layer mixed precision) wins over the uniform
        ``kv_format``; empty strings in either mean unquantized storage.
        """
        if self.kv_formats:
            assert len(self.kv_formats) == len(self.block_pattern()), (
                f"{self.name}: kv_formats has {len(self.kv_formats)} "
                f"entries but the block period is "
                f"{len(self.block_pattern())}")
            fmt = self.kv_formats[pos_in_period] or self.kv_format
        else:
            fmt = self.kv_format
        return fmt or None

    def block_pattern(self) -> List[BlockSpec]:
        """One period of the layer stack (see module docstring)."""
        period = 1
        if self.attn_every > 1:
            period = max(period, self.attn_every)
        if self.moe_num_experts and self.moe_every > 1:
            period = max(period, self.moe_every)
        if self.local_global_period:
            period = max(period, self.local_global_period)
        blocks = []
        for i in range(period):
            if self.family == "ssm":
                mixer: str = "ssm"
            elif self.attn_every > 1:
                # hybrid: attention at position 0 of each period, SSM else
                mixer = "attn" if i % self.attn_every == 0 else "ssm"
            else:
                mixer = "attn"
            window = None
            if self.local_global_period and i % self.local_global_period == 0:
                window = self.sliding_window   # even positions local
            elif self.sliding_window and not self.local_global_period:
                window = self.sliding_window
            if self.family == "ssm":
                ffn = "none" if self.d_ff == 0 else "dense"
            elif self.moe_num_experts:
                ffn = "moe" if (i + 1) % self.moe_every == 0 else "dense"
            else:
                ffn = "dense"
            blocks.append(BlockSpec(mixer=mixer, ffn=ffn, window=window,
                                    cross_attn=self.is_encoder_decoder))
        return blocks

    @property
    def n_periods(self) -> int:
        period = len(self.block_pattern())
        assert self.n_layers % period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"period={period}")
        return self.n_layers // period

    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        """Total parameters (exact for our implementation)."""
        n = 0
        embed = self.vocab_size * self.d_model
        n += embed
        if not self.tie_embeddings:
            n += embed
        for blk in self.block_pattern():
            b = 0
            if blk.mixer == "attn":
                b += self.d_model * (self.q_dim + 2 * self.kv_dim)
                b += self.q_dim * self.d_model
                if self.qkv_bias:
                    b += self.q_dim + 2 * self.kv_dim
                b += 2 * self.d_model          # pre norms (attn)
                if blk.cross_attn:
                    b += self.d_model * (self.q_dim + 2 * self.kv_dim)
                    b += self.q_dim * self.d_model
                    b += self.d_model
            elif blk.mixer == "ssm":
                d_in = self.d_inner
                conv_dim = d_in + 2 * self.ssm_state
                b += self.d_model * (2 * d_in + 2 * self.ssm_state
                                     + self.ssm_heads)
                b += conv_dim * (self.ssm_conv + 1)   # conv weights + biases
                b += 3 * self.ssm_heads        # A_log, dt_bias, D
                b += d_in                      # gated norm
                b += d_in * self.d_model       # out proj
                b += self.d_model              # pre norm
            if blk.ffn == "dense":
                mult = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
                b += mult * self.d_model * self.d_ff + self.d_model
            elif blk.ffn == "moe":
                mult = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
                b += (self.moe_num_experts * mult * self.d_model
                      * self.expert_d_ff)
                b += self.d_model * self.moe_num_experts   # router
                if self.moe_shared_expert:
                    b += mult * self.d_model * self.expert_d_ff
                b += self.d_model
            n += b * self.n_periods
        if self.is_encoder_decoder:
            # encoder blocks: self-attn + dense ffn
            mult = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
            b = (self.d_model * (self.q_dim + 2 * self.kv_dim)
                 + self.q_dim * self.d_model
                 + mult * self.d_model * self.d_ff + 2 * self.d_model)
            n += b * self.n_encoder_layers
        n += self.d_model                      # final norm
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts)."""
        if not self.moe_num_experts:
            return self.param_count()
        mult = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
        expert = mult * self.d_model * self.expert_d_ff
        inactive_per_moe_block = (
            (self.moe_num_experts - self.moe_top_k) * expert)
        n_moe_blocks = sum(1 for b in self.block_pattern()
                           if b.ffn == "moe") * self.n_periods
        return self.param_count() - inactive_per_moe_block * n_moe_blocks

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        period = len(self.block_pattern())
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=period * (2 if period <= 2 else 1),
            n_encoder_layers=2 if self.is_encoder_decoder else 0,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=512,
            moe_num_experts=min(self.moe_num_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            moe_d_ff=64 if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=32,
            sliding_window=32 if self.sliding_window else None,
            fsdp=False,
            param_dtype="float32",
            compute_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark input shape (assigned per-arch in the task spec)."""

    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}"
                       ) from None


def smoke_shape(kind: str = "train") -> ShapeConfig:
    """Tiny shape for CPU smoke tests."""
    if kind == "train":
        return ShapeConfig("smoke_train", "train", 64, 2)
    if kind == "prefill":
        return ShapeConfig("smoke_prefill", "prefill", 64, 2)
    return ShapeConfig("smoke_decode", "decode", 64, 2)
