"""Config registry: 10 assigned architectures (+ the paper's GPT-NeoX case
study), 4 benchmark shapes, and the (arch x shape) applicability matrix."""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    BlockSpec,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    ShapeConfig,
    TRAIN_4K,
    get_shape,
    smoke_shape,
)

from repro.configs.mamba2_2p7b import CONFIG as MAMBA2_2P7B
from repro.configs.qwen2p5_3b import CONFIG as QWEN2P5_3B
from repro.configs.gemma2_2b import CONFIG as GEMMA2_2B
from repro.configs.llama3p2_3b import CONFIG as LLAMA3P2_3B
from repro.configs.gemma_2b import CONFIG as GEMMA_2B
from repro.configs.jamba_v0p1_52b import CONFIG as JAMBA_52B
from repro.configs.seamless_m4t_medium import CONFIG as SEAMLESS_M4T
from repro.configs.kimi_k2_1t import CONFIG as KIMI_K2
from repro.configs.llama4_maverick_400b import CONFIG as LLAMA4_MAVERICK
from repro.configs.internvl2_2b import CONFIG as INTERNVL2_2B
from repro.configs.gptneox_1b import CONFIG as GPTNEOX_1B

# The 10 assigned architectures, in the task-spec order.
ASSIGNED: Tuple[ArchConfig, ...] = (
    MAMBA2_2P7B,
    QWEN2P5_3B,
    GEMMA2_2B,
    LLAMA3P2_3B,
    GEMMA_2B,
    JAMBA_52B,
    SEAMLESS_M4T,
    KIMI_K2,
    LLAMA4_MAVERICK,
    INTERNVL2_2B,
)

REGISTRY: Dict[str, ArchConfig] = {c.name: c for c in ASSIGNED}
REGISTRY[GPTNEOX_1B.name] = GPTNEOX_1B


def get_config(name: str) -> ArchConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(REGISTRY)}") from None


def sub_quadratic(cfg: ArchConfig) -> bool:
    """Does the arch have a sub-quadratic / bounded-KV long-context path?

    SSM and hybrid archs decode with O(1)/bounded state; gemma2's sliding-
    window layers bound half its KV (global layers retained — dominant
    memory term, recorded in the roofline table).  Pure full-attention
    archs cannot hold a 500k KV usefully => long_500k is skipped for them
    (DESIGN.md §5).
    """
    if cfg.family in ("ssm", "hybrid"):
        return True
    if cfg.local_global_period and cfg.sliding_window:
        return True
    return False


def cell_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for one (arch x shape) cell."""
    if shape.name == "long_500k" and not sub_quadratic(cfg):
        return False, "pure full-attention arch: no sub-quadratic path at 500k"
    return True, ""


def all_cells() -> List[Tuple[ArchConfig, ShapeConfig, bool, str]]:
    """The full 40-cell matrix with applicability flags."""
    out = []
    for cfg in ASSIGNED:
        for shape in SHAPES.values():
            ok, why = cell_applicable(cfg, shape)
            out.append((cfg, shape, ok, why))
    return out
