"""mamba2-2.7b — SSD (state-space duality) LM [arXiv:2405.21060].

64L d_model=2560, attention-free, d_ff=0 (the Mamba-2 block replaces both
mixer and MLP), vocab=50280, ssm_state=128.  d_inner = 2*2560 = 5120,
head_dim=64 => 80 SSD heads.  ``long_500k`` RUNS: decode state is O(1).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
