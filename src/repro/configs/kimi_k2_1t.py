"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config)
[arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) per-expert d_ff=2048 vocab=163840,
MoE 384 experts top-8 + 1 shared expert on every layer.  head_dim=128
(q_dim = 64*128 = 8192 > d_model, as in the DeepSeek-family lineage).

Exercises EP + FSDP hardest: ~1.04e12 total params, ~32e9 active.
fsdp=True is mandatory — at bf16 the expert stack alone is ~2 TB.
``long_500k`` SKIPPED (full attention).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    mlp_variant="swiglu",
    moe_num_experts=384,
    moe_top_k=8,
    moe_every=1,
    moe_d_ff=2048,
    moe_shared_expert=True,
    rope_theta=50_000.0,
    fsdp=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
