"""llama4-maverick-400b-a17b — MoE with interleaved dense/MoE FFNs
[hf:meta-llama/Llama-4].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts
top-1 + shared expert on every 2nd layer (dense FFN otherwise) — the
Maverick interleave.  head_dim=128.  Early fusion noted in the card; the
text backbone is what this config models (DESIGN.md §5).
``long_500k`` SKIPPED (full attention).  fsdp=True (~0.8 TB at bf16).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    mlp_variant="swiglu",
    moe_num_experts=128,
    moe_top_k=1,
    moe_every=2,
    moe_d_ff=8192,
    moe_shared_expert=True,
    rope_theta=500_000.0,
    fsdp=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
