"""gemma-2b — MQA (kv=1) GeGLU transformer, head_dim=256 [arXiv:2403.08295].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.
Pure full attention => ``long_500k`` SKIPPED.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_variant="geglu",
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
