"""Characterization-driven autotuning — the paper's "actionable insights"
made executable (DESIGN.md §2).

The paper closes with guidance ("Blackwell favors high-ILP low-warp
kernels", "FP64 is meant to be emulated", "precision trades power for
range").  This module turns a :class:`~repro.core.device_model.DeviceModel`
plus roofline inputs into concrete decisions the framework applies:

* :func:`pick_matmul_block`  — BlockSpec tile selection for Pallas matmul
  kernels (VMEM-budgeted, MXU-aligned, HBM-traffic-minimizing),
* :func:`pick_remat_policy`  — activation checkpointing from the memory
  roofline term vs HBM capacity,
* :func:`rank_shardings`     — sharding-layout choice from predicted
  per-layer collective bytes (roofline term 3).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.device_model import DeviceModel

# Plain (non-registry) dtypes only.  Low-precision formats resolve
# through the compat dtype registry instead — the old hardcoded table
# contradicted measured packed storage (fp6 listed at 1 B/elem where
# ``repro.lowbits`` packs 0.75; fp4 at 0.5 without its e8m0 scale
# bytes), so HBM-traffic predictions disagreed with what the Tab
# IV/V/VII artifacts measure.
_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1}


def dtype_bytes(dtype: str, block_scaled: bool = False) -> float:
    """Storage bytes/element for ``dtype``, matching *measured* packed
    layouts: registry formats report their true bit-packed width (fp8 1,
    fp6 0.75, fp4 0.5 — ``compat.storage_bytes_per_element``), and
    ``block_scaled=True`` adds the 1-byte e8m0 scale amortized over the
    mxfp block of 32 (what quantized weight/KV stores actually stream)."""
    from repro import compat

    try:
        b = compat.storage_bytes_per_element(dtype, packed=True)
    except KeyError:
        return float(_DTYPE_BYTES.get(dtype, 2))
    if block_scaled:
        b += 1.0 / 32.0
    return b


@dataclasses.dataclass(frozen=True)
class BlockChoice:
    bm: int
    bn: int
    bk: int
    vmem_bytes: float
    hbm_bytes: float
    predicted_s: float


def pick_matmul_block(
    device: DeviceModel,
    m: int, n: int, k: int,
    dtype: str = "bfloat16",
    acc_dtype: str = "float32",
    vmem_fraction: float = 0.6,
    candidates: Sequence[int] = (128, 256, 512, 1024),
) -> BlockChoice:
    """Pick (bm, bn, bk) for a blocked matmul.

    Napkin model (the §Perf discipline): per-(bm,bn) output tile we stream
    the full K dimension; HBM traffic = A read n/bn times + B read m/bm
    times + C once; VMEM working set = A-block + B-block + accumulator.
    Predicted step time = max(compute, HBM traffic / bw).  MXU alignment is
    enforced by construction (candidates are multiples of the MXU tile).
    """
    # registry formats stream packed codes + their e8m0 block scales
    # (block_scaled is a no-op for plain dtypes)
    eb = dtype_bytes(dtype, block_scaled=True)
    ab = float(_DTYPE_BYTES.get(acc_dtype, 4))
    vmem_budget = device.level("vmem").capacity_bytes * vmem_fraction \
        if any(l.name == "vmem" for l in device.memory) else 64 * 2**20
    peak = device.peak_flops_for(dtype)
    hbm_bw = device.hbm.bandwidth_Bps

    best: Optional[BlockChoice] = None
    for bm, bn, bk in itertools.product(candidates, repeat=3):
        if bm > max(m, 128) or bn > max(n, 128) or bk > max(k, 128):
            continue
        vmem = (bm * bk + bk * bn) * eb + bm * bn * ab
        # double-buffered input blocks
        vmem += (bm * bk + bk * bn) * eb
        if vmem > vmem_budget:
            continue
        n_col_passes = -(-n // bn)
        n_row_passes = -(-m // bm)
        hbm = (m * k * eb) * n_col_passes + (k * n * eb) * n_row_passes \
            + m * n * ab
        compute_s = 2.0 * m * n * k / peak
        memory_s = hbm / hbm_bw
        pred = max(compute_s, memory_s)
        choice = BlockChoice(bm, bn, bk, vmem, hbm, pred)
        if best is None or choice.predicted_s < best.predicted_s:
            best = choice
    if best is None:  # tiny problem: single block
        return BlockChoice(128, 128, 128,
                           (128 * 128) * (2 * eb + ab), 0.0, 0.0)
    return best


@dataclasses.dataclass(frozen=True)
class RematPolicy:
    name: str                     # none | dots | full
    predicted_bytes: float
    fits: bool
    recompute_flops_factor: float


def pick_remat_policy(
    activation_bytes: float,
    weight_opt_bytes: float,
    device: DeviceModel,
    headroom: float = 0.9,
) -> RematPolicy:
    """Choose the cheapest checkpointing level whose footprint fits HBM."""
    cap = device.hbm.capacity_bytes * headroom
    # (name, activation retention fraction, recompute factor)
    ladder = (("none", 1.0, 1.0),
              ("dots", 0.35, 1.15),   # keep matmul outputs only
              ("full", 0.08, 1.33))   # keep layer boundaries only
    chosen = None
    for name, frac, rf in ladder:
        total = weight_opt_bytes + activation_bytes * frac
        chosen = RematPolicy(name, total, total <= cap, rf)
        if chosen.fits:
            return chosen
    return chosen  # largest remat even if still over: caller must reshard


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    name: str
    collective_bytes_per_layer: float
    notes: str


def rank_shardings(
    *,
    d_model: int,
    d_ff: int,
    n_heads: int,
    n_kv_heads: int,
    seq: int,
    batch_per_replica: int,
    tp: int,
    dtype_bytes: int = 2,
    moe_experts: int = 0,
    moe_topk: int = 0,
) -> List[ShardingPlan]:
    """Rank candidate TP layouts by per-layer collective traffic.

    Megatron-style analysis: with TP degree t, each transformer layer does
    two all-reduces (attn out + MLP out) of the activation block
    ``batch*seq*d_model`` unless sequence parallelism converts them into
    reduce-scatter + all-gather (same bytes, half latency exposure,
    overlappable).  MoE adds two all-to-alls of the routed tokens.
    """
    act = batch_per_replica * seq * d_model * dtype_bytes
    plans = []
    # 1. pure TP (Megatron): 2 all-reduce per layer, each 2x(t-1)/t ring bytes
    ring = 2.0 * (tp - 1) / tp if tp > 1 else 0.0
    plans.append(ShardingPlan(
        "tp-allreduce", 2 * act * ring,
        "2 all-reduce/layer on activations (Megatron baseline)"))
    # 2. TP + sequence parallelism: RS+AG pairs, (t-1)/t bytes each way
    sp = (tp - 1) / tp if tp > 1 else 0.0
    plans.append(ShardingPlan(
        "tp-seqparallel", 4 * act * sp * 0.5,
        "reduce-scatter + all-gather pairs; overlappable with compute"))
    # 3. MoE expert parallel: 2 all-to-all of routed tokens
    if moe_experts:
        routed = act * moe_topk
        plans.append(ShardingPlan(
            "ep-alltoall", 2 * routed * (tp - 1) / max(tp, 1),
            f"dispatch+combine all-to-all over {moe_experts} experts"))
    return sorted(plans, key=lambda p: p.collective_bytes_per_layer)
