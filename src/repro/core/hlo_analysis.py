"""Compiled-HLO analysis — the framework's "SASS inspection" (§V.B analogue).

The paper inspects generated SASS to learn which hardware pipeline each PTX
``mma`` variant actually dispatches to (HMMA/QMMA/OMMA) and to confirm that
microbenchmark instructions were not optimized away.  Our compiled artifact
is XLA HLO; this module extracts from it:

* FLOPs / bytes-accessed (via ``compiled.cost_analysis()``),
* collective-communication bytes, per collective kind, by parsing the
  optimized HLO text (``compiled.as_text()``) — these feed roofline term 3,
* per-device memory footprint (``compiled.memory_analysis()``),
* structural signals: fusion/dot/convert counts and remat-induced duplicate
  ops (duplicate ``op_name`` metadata), the §Perf "profile" on a machine with
  no real-TPU trace.

The parser is intentionally tolerant: HLO printers differ across XLA
versions, and short operand forms omit shapes (we then fall back to the
result shape, which is exact for all-reduce/all-to-all/collective-permute
and an upper bound for all-gather).
"""

from __future__ import annotations

import collections
import dataclasses
import re
from typing import Any, Dict, Iterable, Mapping, Optional, Tuple

_BYTES_PER_ELEM = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f4e2m1fn": 0.5, "f6e2m3fn": 1, "f6e3m2fn": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(sorted(_BYTES_PER_ELEM, key=len, reverse=True))
    + r")\[([0-9,]*)\]"
)

# Collective opcodes whose traffic lands on the interconnect.  ``-done`` ops
# are bookkeeping for async pairs and must not be double counted.
_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_OP_LINE_RE = re.compile(
    r"=\s+(?P<result>\([^)]*\)|\S+)\s+(?P<opcode>[a-z0-9-]+)\(")


def shape_bytes(text: str) -> float:
    """Sum bytes of every ``dtype[d0,d1,...]`` shape literal in ``text``."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES_PER_ELEM[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    """Interconnect traffic extracted from optimized HLO."""

    total_bytes: float = 0.0
    bytes_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(float))
    count_by_kind: Dict[str, int] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(int))

    def merge(self, other: "CollectiveStats") -> "CollectiveStats":
        out = CollectiveStats(self.total_bytes + other.total_bytes)
        for src in (self.bytes_by_kind, other.bytes_by_kind):
            for k, v in src.items():
                out.bytes_by_kind[k] += v
        for src in (self.count_by_kind, other.count_by_kind):
            for k, v in src.items():
                out.count_by_kind[k] += v
        return out


def _split_operands(line: str, opcode: str) -> Optional[str]:
    """Text between the opcode's '(' and its matching ')'."""
    start = line.find(opcode + "(")
    if start < 0:
        return None
    i = start + len(opcode) + 1
    depth = 1
    j = i
    while j < len(line) and depth:
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
        j += 1
    return line[i:j - 1]


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line)
        if not m:
            continue
        opcode = m.group("opcode")
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if opcode.endswith("-done"):
            continue
        if base not in _COLLECTIVE_KINDS:
            continue
        operands = _split_operands(line, opcode)
        nbytes = shape_bytes(operands) if operands else 0.0
        if nbytes == 0.0:
            # Short operand form: fall back to the result shape.
            nbytes = shape_bytes(m.group("result"))
        stats.total_bytes += nbytes
        stats.bytes_by_kind[base] += nbytes
        stats.count_by_kind[base] += 1
    return stats


@dataclasses.dataclass
class HloStructure:
    """Structural profile of the optimized HLO (the dry-run "trace")."""

    n_fusions: int = 0
    n_dots: int = 0
    n_converts: int = 0
    n_while: int = 0
    n_reshapes: int = 0
    n_transposes: int = 0
    n_custom_calls: int = 0
    remat_duplicate_ops: int = 0
    dot_bytes: float = 0.0


_METADATA_RE = re.compile(r'op_name="([^"]+)"')


def parse_structure(hlo_text: str) -> HloStructure:
    s = HloStructure()
    op_names: collections.Counter = collections.Counter()
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line)
        if not m:
            continue
        opcode = m.group("opcode")
        if opcode == "fusion":
            s.n_fusions += 1
        elif opcode == "dot":
            s.n_dots += 1
            s.dot_bytes += shape_bytes(m.group("result"))
        elif opcode == "convert":
            s.n_converts += 1
        elif opcode == "while":
            s.n_while += 1
        elif opcode == "reshape":
            s.n_reshapes += 1
        elif opcode == "transpose":
            s.n_transposes += 1
        elif opcode == "custom-call":
            s.n_custom_calls += 1
        mm = _METADATA_RE.search(line)
        if mm:
            op_names[mm.group(1)] += 1
    # Ops whose source op_name appears >1x in the final module are usually
    # remat-induced recompute (or compiler CSE failures) — §Perf hint.
    s.remat_duplicate_ops = sum(c - 1 for c in op_names.values() if c > 1)
    return s


def _first(d: Any) -> Mapping[str, float]:
    """cost_analysis() historically returned [dict] per device; now a dict."""
    if d is None:
        return {}
    if isinstance(d, (list, tuple)):
        return d[0] if d else {}
    return d


@dataclasses.dataclass
class CompiledStats:
    """Everything the roofline needs from one compiled executable.

    ``flops`` / ``bytes_accessed`` / ``collectives`` come from the
    loop-aware HLO walk (``repro.core.hlo_cost``) — ``cost_analysis()``
    counts while-loop bodies once and undercounts scan-heavy programs by
    orders of magnitude; its raw values are retained as ``xla_flops`` /
    ``xla_bytes`` for cross-checking.
    """

    flops: float
    bytes_accessed: float
    collectives: CollectiveStats
    structure: HloStructure
    # raw (loop-unaware) XLA cost_analysis values, for comparison
    xla_flops: float = 0.0
    xla_bytes: float = 0.0
    # memory_analysis numbers are *per device* under SPMD.
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    peak_bytes: int = 0

    @property
    def per_device_bytes(self) -> int:
        return self.argument_bytes + self.output_bytes + self.temp_bytes


def analyze_compiled(compiled: Any, hlo_text: Optional[str] = None
                     ) -> CompiledStats:
    """Extract :class:`CompiledStats` from a ``jax`` compiled executable."""
    from repro.core.hlo_cost import analyze_hlo_text

    cost = _first(getattr(compiled, "cost_analysis", lambda: {})() or {})
    if hlo_text is None:
        hlo_text = compiled.as_text()
    loop_aware = analyze_hlo_text(hlo_text)
    coll = loop_aware.collectives
    structure = parse_structure(hlo_text)

    arg_b = out_b = tmp_b = peak_b = 0
    try:
        mem = compiled.memory_analysis()
        arg_b = int(getattr(mem, "argument_size_in_bytes", 0) or 0)
        out_b = int(getattr(mem, "output_size_in_bytes", 0) or 0)
        tmp_b = int(getattr(mem, "temp_size_in_bytes", 0) or 0)
        alias_b = int(getattr(mem, "alias_size_in_bytes", 0) or 0)
        peak_b = arg_b + out_b + tmp_b - alias_b
    except Exception:  # pragma: no cover - backend-dependent
        pass

    return CompiledStats(
        flops=loop_aware.flops,
        bytes_accessed=loop_aware.bytes,
        collectives=coll,
        structure=structure,
        xla_flops=float(cost.get("flops", 0.0) or 0.0),
        xla_bytes=float(cost.get("bytes accessed", 0.0) or 0.0),
        argument_bytes=arg_b,
        output_bytes=out_b,
        temp_bytes=tmp_b,
        peak_bytes=peak_b,
    )
