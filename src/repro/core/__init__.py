"""Core library: the paper's contribution (microbenchmark-driven device
characterization) as a composable JAX module, plus the roofline/energy/
autotune machinery that consumes it.  See DESIGN.md §1-§3 for the
paper-to-TPU mapping."""

from repro.core.device_model import (  # noqa: F401
    DeviceModel,
    GB203,
    GH100,
    HOST_CPU,
    MemoryLevel,
    REGISTRY,
    TPU_V5E,
    detect_backend_model,
    get_device_model,
)
from repro.core.hlo_analysis import (  # noqa: F401
    CollectiveStats,
    CompiledStats,
    HloStructure,
    analyze_compiled,
    parse_collectives,
    parse_structure,
    shape_bytes,
)
from repro.core.roofline import (  # noqa: F401
    MARKDOWN_HEADER,
    RooflineReport,
    build_report,
    markdown_row,
    model_flops_dense,
    model_flops_forward,
)
from repro.core.timing import TimingResult, time_fn, timer_overhead  # noqa: F401
