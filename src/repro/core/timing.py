"""Timing infrastructure — the §IV.A "clock overhead" layer.

The paper measures clock cycles with ``%clock64`` and first characterizes the
overhead of the measurement itself (1 cycle on GB203, 2 on GH100) before
trusting any number.  TPUs (and CPUs via JAX) expose no user-readable cycle
counter inside a kernel, so the framework measures wall time around
``block_until_ready`` and applies the identical discipline:

* measure the timer's own overhead first and subtract it,
* discard warm-up iterations (the paper excludes first-run results where the
  cache had not warmed up — §IV.B),
* report medians over many repetitions, plus spread.

All probes in ``repro.core.probes`` go through :func:`time_fn`.
"""

from __future__ import annotations

import dataclasses
import math
import statistics
import time
from typing import Any, Callable, Optional, Sequence

import jax


@dataclasses.dataclass(frozen=True)
class TimingResult:
    """Statistics of a timed region, in seconds (overhead already removed)."""

    median_s: float
    mean_s: float
    min_s: float
    std_s: float
    iters: int
    warmup: int
    overhead_s: float
    samples: tuple = ()

    def per(self, n: int) -> float:
        """Median time per inner operation when the region ran ``n`` ops."""
        return self.median_s / max(n, 1)

    @property
    def median_us(self) -> float:
        return self.median_s * 1e6

    @property
    def median_ns(self) -> float:
        return self.median_s * 1e9


def measure_timer_overhead(reps: int = 1000) -> float:
    """§IV.A analogue: cost of an empty timed region.

    On the GPUs the paper reports 1 (GB203) vs 2 (GH100) cycles for two
    back-to-back ``%clock64`` reads; here it is two ``perf_counter`` calls.
    """
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        t1 = time.perf_counter()
        samples.append(t1 - t0)
    return statistics.median(samples)


_TIMER_OVERHEAD: Optional[float] = None


def timer_overhead() -> float:
    global _TIMER_OVERHEAD
    if _TIMER_OVERHEAD is None:
        _TIMER_OVERHEAD = measure_timer_overhead()
    return _TIMER_OVERHEAD


def _block(x: Any) -> None:
    jax.block_until_ready(x)


def time_fn(
    fn: Callable[..., Any],
    *args: Any,
    iters: int = 30,
    warmup: int = 3,
    keep_samples: bool = False,
) -> TimingResult:
    """Time ``fn(*args)`` with warm-up exclusion and overhead subtraction.

    ``fn`` should already be jit-compiled; the warm-up iterations absorb
    compilation and cache warm-up (the effect the paper observed as inflated
    first-run latencies on GB203, §IV.B).
    """
    ovh = timer_overhead()
    for _ in range(warmup):
        _block(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _block(fn(*args))
        t1 = time.perf_counter()
        samples.append(max(t1 - t0 - ovh, 0.0))
    return TimingResult(
        median_s=statistics.median(samples),
        mean_s=statistics.fmean(samples),
        min_s=min(samples),
        std_s=statistics.pstdev(samples) if len(samples) > 1 else 0.0,
        iters=iters,
        warmup=warmup,
        overhead_s=ovh,
        samples=tuple(samples) if keep_samples else (),
    )


def to_cycles(seconds: float, clock_hz: float) -> float:
    """Convert wall seconds to the paper's unit (clock cycles)."""
    return seconds * clock_hz


def amortized_ns(total: TimingResult, baseline: TimingResult, n: int) -> float:
    """Per-op time of the *increment* between two regions.

    Used by chain-length sweeps: ``(T(chain=n) - T(chain=0)) / n`` isolates
    the dependent-op latency from dispatch overhead, mirroring how the paper
    subtracts the empty-measurement cost.
    """
    if n <= 0:
        return 0.0
    return max(total.median_s - baseline.median_s, 0.0) / n * 1e9


def geomean(xs: Sequence[float]) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return 0.0
    return math.exp(sum(math.log(x) for x in xs) / len(xs))
