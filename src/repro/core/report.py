"""Characterization report — renders probe results as the paper's tables.

``python -m benchmarks.run`` drives the probes and uses these renderers to
emit both machine-readable CSV rows and the markdown report saved under
``results/characterization.md``.
"""

from __future__ import annotations

import dataclasses
import io
from typing import Any, Iterable, List, Mapping, Sequence


def table(headers: Sequence[str], rows: Iterable[Sequence[Any]]) -> str:
    buf = io.StringIO()
    buf.write("| " + " | ".join(headers) + " |\n")
    buf.write("|" + "|".join("---" for _ in headers) + "|\n")
    for row in rows:
        buf.write("| " + " | ".join(_fmt(c) for c in row) + " |\n")
    return buf.getvalue()


def _fmt(x: Any) -> str:
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) >= 1e5 or abs(x) < 1e-3:
            return f"{x:.3e}"
        return f"{x:.3f}"
    return str(x)


def csv_rows(name: str, rows: Iterable[Mapping[str, Any]]) -> str:
    """``name,us_per_call,derived`` style CSV lines for benchmarks.run."""
    out = []
    for row in rows:
        cells = ",".join(f"{k}={_fmt(v)}" for k, v in row.items())
        out.append(f"{name},{cells}")
    return "\n".join(out)


def dataclass_table(items: Sequence[Any],
                    fields: Sequence[str] | None = None) -> str:
    if not items:
        return "(empty)\n"
    fields = list(fields or [f.name for f in dataclasses.fields(items[0])])
    rows = [[getattr(it, f) for f in fields] for it in items]
    return table(fields, rows)


class Report:
    """Accumulates sections and writes one markdown file."""

    def __init__(self, title: str):
        self.title = title
        self.sections: List[str] = []

    def add(self, heading: str, body: str) -> None:
        self.sections.append(f"## {heading}\n\n{body}\n")

    def add_table(self, heading: str, items: Sequence[Any],
                  fields: Sequence[str] | None = None,
                  note: str = "") -> None:
        body = dataclass_table(items, fields)
        if note:
            body += f"\n> {note}\n"
        self.add(heading, body)

    def render(self) -> str:
        return f"# {self.title}\n\n" + "\n".join(self.sections)

    def write(self, path: str) -> None:
        import os
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.render())
