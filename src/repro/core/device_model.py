"""Hardware device models.

The paper (Jarmusch et al., 2025) characterizes two NVIDIA chips — GH100
(Hopper, H100 PCIe) and GB203 (Blackwell, RTX 5080) — via microbenchmarks and
tabulates execution-unit counts (Tab I), cache hierarchy (Tab II), measured
latencies (Tab III), datatype support (Tab IV/V) and power (Tab VI/VIII).

This module is the framework's equivalent artifact: a small database of
device models.  Probes (``repro.core.probes``) *measure* a model for the
backend they run on; published constants provide the *target* models (TPU
v5e for the production mesh, plus the paper's two GPUs so benchmark output
can be compared side-by-side with the paper's tables).

Everything downstream — roofline (``repro.core.roofline``), energy
(``repro.core.energy``), autotuning (``repro.core.autotune``) — consumes a
``DeviceModel``, never raw constants.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MemoryLevel:
    """One level of the memory hierarchy.

    The paper's Tab II rows (L1/shared, L2, global) map onto TPU levels
    (VMEM, HBM); ``bandwidth_Bps`` is aggregate per chip, ``latency_cycles``
    is a load-to-use latency in core cycles (the unit the paper reports).
    """

    name: str
    capacity_bytes: int
    bandwidth_Bps: float
    latency_cycles: float
    software_managed: bool = False


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """A characterized (or published) device.

    The fields mirror what the paper's microbenchmarks extract: peak compute
    per precision, the memory hierarchy, and interconnect.  ``peak_flops``
    maps dtype name -> FLOP/s for the *matrix* pipeline (tensor core / MXU);
    ``vector_flops`` is the scalar/vector (VPU / CUDA-core) pipeline.
    """

    name: str
    vendor: str
    kind: str                      # "tpu" | "gpu" | "cpu"
    clock_hz: float
    peak_flops: Dict[str, float]   # matrix pipeline, by dtype name
    vector_flops: Dict[str, float]
    memory: Tuple[MemoryLevel, ...]
    # Interconnect (per chip): aggregate off-chip link bandwidth and per-link.
    interconnect_Bps: float = 0.0
    link_Bps: float = 0.0
    num_links: int = 0
    # Matrix-unit native tile (the MXU/mma shape the paper sweeps in §V.B).
    matrix_tile: Tuple[int, int] = (0, 0)
    # Static + peak power for the energy model (§V.C / §VII).
    idle_watts: float = 0.0
    peak_watts: float = 0.0

    def level(self, name: str) -> MemoryLevel:
        for lvl in self.memory:
            if lvl.name == name:
                return lvl
        raise KeyError(f"{self.name} has no memory level {name!r}")

    @property
    def hbm(self) -> MemoryLevel:
        """The last (largest, off-core) memory level."""
        return self.memory[-1]

    def peak_flops_for(self, dtype: str) -> float:
        """Matrix-pipeline peak for ``dtype``; falls back to the widest
        supported precision the dtype would be emulated in (the paper's
        QMMA-fallback observation: FP4 rides the FP8 pipeline on GB203;
        on TPU every sub-bf16 format rides the bf16 MXU pipeline)."""
        if dtype in self.peak_flops:
            return self.peak_flops[dtype]
        if "bfloat16" in self.peak_flops:
            return self.peak_flops["bfloat16"]
        return max(self.peak_flops.values())


# ---------------------------------------------------------------------------
# Published target models
# ---------------------------------------------------------------------------

# TPU v5e — the production target for this framework.
#   197 TFLOP/s bf16 / 394 TOP/s int8, 16 GiB HBM2 @ 819 GB/s,
#   ~128 MiB VMEM per core (software-managed), 4 ICI links ~50 GB/s each.
TPU_V5E = DeviceModel(
    name="tpu-v5e",
    vendor="google",
    kind="tpu",
    clock_hz=940e6,
    peak_flops={
        "bfloat16": 197e12,
        "float32": 98.5e12,        # fp32 via MXU passthrough at half rate
        "int8": 394e12,
        # fp8/fp6/fp4 are NOT native on v5e: emulated via bf16 MXU after
        # dequant (see DESIGN.md §3) — peak_flops_for() falls back to bf16.
    },
    vector_flops={"float32": 3.9e12, "int32": 3.9e12, "float64": 0.0},
    memory=(
        MemoryLevel("vreg", 32 * 1024, 0.0, 1.0, software_managed=True),
        MemoryLevel("vmem", 128 * 1024 * 1024, 22.0e12, 20.0,
                    software_managed=True),
        MemoryLevel("hbm", 16 * 1024**3, 819e9, 450.0),
    ),
    interconnect_Bps=200e9,        # 4 links
    link_Bps=50e9,
    num_links=4,
    matrix_tile=(128, 128),
    idle_watts=60.0,
    peak_watts=220.0,
)

# GH100 (H100 PCIe) — the paper's Hopper column (Tab I/II + §VI measurements).
GH100 = DeviceModel(
    name="gh100-h100-pcie",
    vendor="nvidia",
    kind="gpu",
    clock_hz=1.755e9,
    peak_flops={
        "float8_e4m3fn": 1513e12, "float8_e5m2": 1513e12,
        "float16": 756e12, "bfloat16": 756e12,
        "float32": 378e12,          # tf32 tensor core
        "float64": 51e12,           # FP64 tensor core
        "int8": 1513e12,
    },
    vector_flops={"float32": 51.2e12, "int32": 25.6e12, "float64": 25.6e12},
    memory=(
        # Paper Tab II: 256 KB unified L1/shared per SM (227 KB configurable),
        # 50 MB L2 in 2 partitions, 80 GB HBM2e.  Latencies from the paper's
        # pointer-chase: L1 30-40 cyc, L2 ~273 cyc, global ~658.7 cyc.
        MemoryLevel("l1", 256 * 1024, 128e12, 35.0, software_managed=True),
        MemoryLevel("l2", 50 * 1024**2, 12e12, 273.0),
        MemoryLevel("hbm", 80 * 1024**3, 2000e9, 658.7),
    ),
    interconnect_Bps=64e9,          # PCIe gen5 x16
    link_Bps=64e9,
    num_links=1,
    matrix_tile=(16, 8),            # mma.m16n8k* fragment (per warp)
    idle_watts=45.0,
    peak_watts=350.0,
)

# GB203 (GeForce RTX 5080) — the paper's Blackwell column.
GB203 = DeviceModel(
    name="gb203-rtx5080",
    vendor="nvidia",
    kind="gpu",
    clock_hz=2.617e9,
    peak_flops={
        "float4_e2m1fn": 900e12,     # 5th-gen TC native FP4 (paper Tab IV)
        "float6_e2m3fn": 450e12, "float6_e3m2fn": 450e12,
        "float8_e4m3fn": 450e12, "float8_e5m2": 450e12,
        "float16": 225e12, "bfloat16": 225e12,
        "float32": 112e12,
        "float64": 0.88e12,          # 2 FP64 units/SM (paper Tab I) — scarce
        "int8": 450e12,
    },
    vector_flops={"float32": 56e12, "int32": 56e12, "float64": 0.44e12},
    memory=(
        # Tab II: 128 KB unified L1 per SM (~99 KB configurable shared),
        # 65 MB monolithic L2, 16 GB GDDR7.  Latencies from the paper:
        # L1 30-40 cyc, L2 ~358 cyc, global ~876.7 cyc.
        MemoryLevel("l1", 128 * 1024, 96e12, 35.0, software_managed=True),
        MemoryLevel("l2", 65 * 1024**2, 10e12, 358.0),
        MemoryLevel("hbm", 16 * 1024**3, 960e9, 876.7),
    ),
    interconnect_Bps=64e9,
    link_Bps=64e9,
    num_links=1,
    matrix_tile=(16, 8),
    idle_watts=30.0,
    peak_watts=360.0,
)

# Host CPU — what probes actually run on in this container; filled in by
# measurement (``repro.core.probes``) but given nominal constants so the
# roofline/energy paths are total functions.
HOST_CPU = DeviceModel(
    name="host-cpu",
    vendor="generic",
    kind="cpu",
    clock_hz=3.0e9,
    peak_flops={"float32": 200e9, "bfloat16": 200e9, "float64": 100e9},
    vector_flops={"float32": 200e9, "int32": 100e9, "float64": 100e9},
    memory=(
        MemoryLevel("l1", 32 * 1024, 400e9, 4.0),
        MemoryLevel("l2", 1 * 1024**2, 200e9, 14.0),
        MemoryLevel("l3", 32 * 1024**2, 100e9, 50.0),
        MemoryLevel("hbm", 32 * 1024**3, 25e9, 250.0),
    ),
    interconnect_Bps=10e9,
    link_Bps=10e9,
    num_links=1,
    matrix_tile=(8, 8),
    idle_watts=20.0,
    peak_watts=120.0,
)

REGISTRY: Dict[str, DeviceModel] = {
    m.name: m for m in (TPU_V5E, GH100, GB203, HOST_CPU)
}


def get_device_model(name: str) -> DeviceModel:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown device model {name!r}; known: {sorted(REGISTRY)}"
        ) from None


def detect_backend_model() -> DeviceModel:
    """Best-effort model for the backend JAX is actually running on."""
    import jax

    platform = jax.devices()[0].platform
    if platform == "tpu":
        return TPU_V5E
    if platform == "gpu":
        return GH100
    return HOST_CPU
