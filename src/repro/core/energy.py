"""Analytical energy/power model — §V.C (Tab VI), §VII (Fig 12, Tab VIII).

The paper measures wall power with ``nvidia-smi`` while sustaining mma loops
per precision format and reports:  FP4 16.75 W < FP6 ~39-47 W < FP8 ~46.8 W
on GB203, vs ~55.8 W FP8 on GH100 (Tab VI); a precision-power staircase for
transformer inference (Tab VIII); and a GEMM power curve vs matrix size
(Fig 12).

Neither a CPU container nor a Pallas kernel exposes power telemetry, so the
framework replaces the *measurement* with a first-order energy model and
keeps the paper's *questions* (how does energy scale with precision? with
matrix size? per inference step?):

    E = flops * e_flop(dtype) + sum_level bytes_level * e_byte(level)
        + P_idle * t

Per-op energies are order-of-magnitude constants from published CMOS
estimates (Horowitz, ISSCC'14 "Computing's Energy Problem", scaled from
45 nm to a ~5 nm class node) and HBM vendor figures (~3-7 pJ/bit).  The
model's *ordering* — lower precision => lower energy per op, memory energy
dominating small-arithmetic-intensity ops — is the reproducible content; the
absolute watts are estimates and labeled as such in every report.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional

from repro.core.device_model import DeviceModel

# pJ per FLOP (MAC counted as 2 FLOPs) on the matrix pipeline, by dtype.
# Scaling ~linearly with mantissa-multiplier area => ~bits^2 for multiply,
# but dominated by operand movement at low precision; we use published
# relative scalings: fp32 : bf16 : fp8 : fp6 : fp4 ~ 4 : 1 : 0.5 : 0.4 : 0.25.
ENERGY_PER_FLOP_PJ: Dict[str, float] = {
    "float64": 20.0,
    "float32": 4.0,
    "tf32": 2.4,
    "bfloat16": 1.0,
    "float16": 1.0,
    "int8": 0.4,
    "float8_e4m3fn": 0.5,
    "float8_e5m2": 0.5,
    "float6_e2m3fn": 0.4,
    "float6_e3m2fn": 0.4,
    "float4_e2m1fn": 0.25,
    "int32": 0.8,
}

# pJ per byte moved, by memory level (register ~0.1, VMEM/L1 ~1, HBM ~28
# (= 3.5 pJ/bit), interconnect ~80).
ENERGY_PER_BYTE_PJ: Dict[str, float] = {
    "vreg": 0.1,
    "l1": 1.0,
    "vmem": 1.0,
    "l2": 4.0,
    "l3": 8.0,
    "hbm": 28.0,
    "ici": 80.0,
}


@dataclasses.dataclass(frozen=True)
class EnergyEstimate:
    joules: float
    seconds: float
    dynamic_watts: float
    total_watts: float            # dynamic + idle
    breakdown: Mapping[str, float]

    @property
    def perf_per_watt(self) -> float:
        """FLOP/s per watt given the flops recorded in the breakdown."""
        fl = self.breakdown.get("_flops", 0.0)
        if self.seconds <= 0 or self.total_watts <= 0:
            return 0.0
        return (fl / self.seconds) / self.total_watts


def estimate(
    device: DeviceModel,
    *,
    flops: float,
    dtype: str,
    bytes_by_level: Optional[Mapping[str, float]] = None,
    seconds: Optional[float] = None,
) -> EnergyEstimate:
    """Energy for a region executing ``flops`` at ``dtype`` and moving
    ``bytes_by_level`` bytes.  ``seconds`` (measured or roofline-predicted)
    converts to power; if omitted, the device's compute roofline is used."""
    e_flop = ENERGY_PER_FLOP_PJ.get(dtype, ENERGY_PER_FLOP_PJ["bfloat16"])
    breakdown: Dict[str, float] = {"_flops": flops}
    joules = flops * e_flop * 1e-12
    breakdown["compute"] = joules
    for level, nbytes in (bytes_by_level or {}).items():
        e = nbytes * ENERGY_PER_BYTE_PJ.get(level, 28.0) * 1e-12
        breakdown[level] = e
        joules += e
    if seconds is None:
        peak = device.peak_flops_for(dtype)
        seconds = flops / peak if peak else 0.0
    dynamic = joules / seconds if seconds > 0 else 0.0
    total = dynamic + device.idle_watts
    # Clamp to the device's TDP: sustained draw cannot exceed peak_watts
    # (the paper's Fig 12 plateaus reflect exactly this governor).
    if device.peak_watts:
        total = min(total, device.peak_watts)
    return EnergyEstimate(
        joules=joules,
        seconds=seconds,
        dynamic_watts=dynamic,
        total_watts=total,
        breakdown=breakdown,
    )


def matmul_energy(
    device: DeviceModel, m: int, n: int, k: int, dtype: str,
    seconds: Optional[float] = None,
) -> EnergyEstimate:
    """Tab VI / Fig 12 analogue: energy of one ``m x n x k`` matmul."""
    flops = 2.0 * m * n * k
    elem = {"float64": 8, "float32": 4, "tf32": 4}.get(dtype, None)
    if elem is None:
        elem = {"bfloat16": 2, "float16": 2}.get(dtype, 1)
    hbm_bytes = float(elem) * (m * k + k * n) + 4.0 * m * n  # fp32 out
    vmem_bytes = 3.0 * hbm_bytes                              # staging reuse
    return estimate(
        device, flops=flops, dtype=dtype,
        bytes_by_level={"hbm": hbm_bytes, "vmem": vmem_bytes},
        seconds=seconds,
    )
