"""Three-term roofline model.

The paper motivates microbenchmarking as input to roofline-style reasoning
([2] in its bibliography); this module closes that loop for the framework:
given a compiled dry-run artifact (``repro.core.hlo_analysis``) and a
``DeviceModel``, produce the three roofline terms

    compute    = HLO_FLOPs      / (chips x peak_FLOP/s)
    memory     = HLO_bytes      / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw x n_links)

plus the dominant bottleneck, MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE)
and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs which exposes
remat/redundancy waste.  These feed EXPERIMENTS.md §Roofline and the §Perf
hillclimb loop.

Note on units: ``cost_analysis()`` under SPMD reports *per-device* FLOPs and
bytes, and the HLO text parsed for collectives is the per-device partitioned
module — so terms are computed per device and need no further division by
chip count.  ``chips`` is retained for the MFU-style aggregate numbers.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.device_model import DeviceModel
from repro.core.hlo_analysis import CompiledStats


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    cell: str                    # "<arch>/<shape>/<mesh>"
    chips: int
    dtype: str
    # raw inputs (per device)
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    # the three terms, in seconds (per step, per device)
    compute_s: float
    memory_s: float
    collective_s: float
    # analysis
    dominant: str                # "compute" | "memory" | "collective"
    bound_s: float               # max of the three == predicted step floor
    model_flops: float           # 6*N(_active)*D, whole step, all chips
    useful_ratio: float          # model_flops / (hlo_flops * chips)
    roofline_fraction: float     # compute_s / bound_s  (1.0 == compute-bound)
    mfu: float                   # model_flops / (bound_s * chips * peak)
    per_device_memory_bytes: int
    notes: str = ""

    def terms(self) -> Dict[str, float]:
        return {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}


def build_report(
    cell: str,
    stats: CompiledStats,
    device: DeviceModel,
    chips: int,
    dtype: str = "bfloat16",
    model_flops: float = 0.0,
    notes: str = "",
) -> RooflineReport:
    peak = device.peak_flops_for(dtype)
    hbm_bw = device.hbm.bandwidth_Bps
    ici_bw = max(device.link_Bps * max(device.num_links, 1), 1.0)

    compute_s = stats.flops / peak if peak else 0.0
    memory_s = stats.bytes_accessed / hbm_bw if hbm_bw else 0.0
    collective_s = stats.collectives.total_bytes / ici_bw

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)  # type: ignore[arg-type]
    bound_s = terms[dominant]

    total_hlo_flops = stats.flops * chips
    useful = model_flops / total_hlo_flops if total_hlo_flops else 0.0
    mfu = (model_flops / (bound_s * chips * peak)
           if bound_s > 0 and peak else 0.0)

    return RooflineReport(
        cell=cell,
        chips=chips,
        dtype=dtype,
        hlo_flops=stats.flops,
        hlo_bytes=stats.bytes_accessed,
        collective_bytes=stats.collectives.total_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        bound_s=bound_s,
        model_flops=model_flops,
        useful_ratio=useful,
        roofline_fraction=compute_s / bound_s if bound_s else 0.0,
        mfu=mfu,
        per_device_memory_bytes=stats.per_device_bytes,
        notes=notes,
    )


def model_flops_dense(n_params: float, tokens: float) -> float:
    """Kaplan 6*N*D for one training step over ``tokens`` tokens."""
    return 6.0 * n_params * tokens


def model_flops_forward(n_params: float, tokens: float) -> float:
    """2*N*D — forward-only (serving) useful FLOPs."""
    return 2.0 * n_params * tokens


def markdown_row(r: RooflineReport) -> str:
    return (
        f"| {r.cell} | {r.hlo_flops:.3e} | {r.hlo_bytes:.3e} | "
        f"{r.collective_bytes:.3e} | {r.compute_s*1e3:.3f} | "
        f"{r.memory_s*1e3:.3f} | {r.collective_s*1e3:.3f} | "
        f"**{r.dominant}** | {r.useful_ratio:.2f} | {r.mfu:.3f} | "
        f"{r.per_device_memory_bytes/2**30:.2f} |"
    )


MARKDOWN_HEADER = (
    "| cell | HLO FLOPs/dev | HLO bytes/dev | coll bytes/dev | "
    "compute (ms) | memory (ms) | collective (ms) | dominant | "
    "useful | MFU@bound | mem GiB/dev |\n"
    "|---|---|---|---|---|---|---|---|---|---|---|"
)
