"""Microbenchmark probe suite — the paper's §IV-§VI, TPU/JAX-adapted.

Each submodule mirrors one subsystem the paper dissects:

* :mod:`repro.core.probes.compute`    — §IV: execution-pipeline latency /
  completion latency / ILP ramp (Tab III, Fig 2/3)
* :mod:`repro.core.probes.memory`     — §VI: pointer-chase hierarchy walk,
  stride sweeps, streaming bandwidth, concurrency scaling (Fig 6-10)
* :mod:`repro.core.probes.matmul`     — §V: matrix-unit tile sweep and
  grid x ILP scaling (Fig 4/5, Tab VII)
* :mod:`repro.core.probes.precision`  — §V.A-C: FP4/FP6/FP8 support matrix,
  numerics, block scaling (Tab IV/V/VI)
* :mod:`repro.core.probes.collectives`— beyond-paper: interconnect
  alpha-beta characterization feeding roofline term 3

Probes are pure JAX and run on any backend; on this container's CPU they
characterize the host (methodology validation), on TPU the real target.
Pallas-kernel variants of the hot probes live in ``repro.kernels.probe_*``.
"""

from repro.core.probes import (  # noqa: F401
    collectives,
    compute,
    matmul,
    memory,
    precision,
)
