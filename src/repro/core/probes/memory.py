"""Memory-subsystem probes — paper §VI (Fig 6-10).

* :func:`pointer_chase`      — Fig 6: serialized random dependent loads over
  a swept working set; latency steps reveal hierarchy boundaries (L1/L2/HBM
  on GPU, VMEM/HBM on TPU, L1/L2/L3/DRAM on the host CPU this container
  actually runs on).
* :func:`stride_sweep`       — Fig 7/8: strided access latency (bank/lane
  conflict analogue) across concurrency levels.
* :func:`stream_bandwidth`   — Fig 10: sustained read/write/copy bandwidth.
* :func:`concurrency_scaling`— Fig 9: per-stream time as independent streams
  grow (the L2-partition-contention question, TPU/CPU analogue: does the
  shared bandwidth degrade or saturate gracefully?).
* :func:`find_boundaries`    — extracts capacity estimates from the chase
  curve like the paper reads Tab II capacities off its latency spikes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import timing
from repro.core.device_model import DeviceModel, detect_backend_model


@dataclasses.dataclass(frozen=True)
class ChasePoint:
    working_set_bytes: int
    ns_per_load: float
    cycles_per_load: float


def _permutation_chain(n: int, seed: int = 0) -> np.ndarray:
    """Single-cycle random permutation (Sattolo) => the chase visits every
    element exactly once with no shortcut the prefetcher can exploit."""
    rng = np.random.default_rng(seed)
    idx = np.arange(n, dtype=np.int32)
    for i in range(n - 1, 0, -1):
        j = rng.integers(0, i)
        idx[i], idx[j] = idx[j], idx[i]
    # idx is now a permutation; build "next" pointers following the cycle.
    nxt = np.empty(n, dtype=np.int32)
    nxt[idx[:-1]] = idx[1:]
    nxt[idx[-1]] = idx[0]
    return nxt


@partial(jax.jit, static_argnums=(1,))
def _chase(arr: jax.Array, steps: int) -> jax.Array:
    def body(_, idx):
        return arr[idx]
    return jax.lax.fori_loop(0, steps, body, jnp.int32(0))


def pointer_chase(
    working_set_bytes: int,
    steps: int = 1 << 14,
    device: DeviceModel | None = None,
    iters: int = 7,
    seed: int = 0,
) -> ChasePoint:
    """Latency of one serialized random load within ``working_set_bytes``."""
    device = device or detect_backend_model()
    n = max(working_set_bytes // 4, 16)          # int32 elements
    arr = jnp.asarray(_permutation_chain(n, seed))
    t = timing.time_fn(_chase, arr, steps, iters=iters)
    ns = t.median_s / steps * 1e9
    return ChasePoint(
        working_set_bytes=n * 4,
        ns_per_load=ns,
        cycles_per_load=ns * 1e-9 * device.clock_hz,
    )


def chase_curve(
    sizes: Sequence[int] = tuple(
        1 << p for p in range(12, 28)),          # 4 KiB .. 128 MiB
    steps: int = 1 << 14,
    device: DeviceModel | None = None,
    iters: int = 5,
) -> List[ChasePoint]:
    """Fig 6 analogue: the full hierarchy walk."""
    return [pointer_chase(s, steps, device, iters) for s in sizes]


def find_boundaries(curve: Sequence[ChasePoint],
                    jump: float = 1.4) -> List[int]:
    """Working-set sizes at which latency jumps by >= ``jump``x — the
    paper's "latency spikes correspond to cache boundaries"."""
    out = []
    for prev, cur in zip(curve, curve[1:]):
        if prev.ns_per_load > 0 and \
                cur.ns_per_load / prev.ns_per_load >= jump:
            out.append(prev.working_set_bytes)
    return out


# ---------------------------------------------------------------------------
# Strided access (Fig 7/8 — bank-conflict analogue)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StridePoint:
    stride: int
    concurrency: int
    ns_per_access: float


@partial(jax.jit, static_argnums=(1, 2, 3))
def _strided_reduce(x: jax.Array, stride: int, lanes: int,
                    accesses: int) -> jax.Array:
    # ``lanes`` independent streams each reading ``accesses`` elements at
    # ``stride`` spacing — gather-based so XLA cannot coalesce it away.
    base = jnp.arange(lanes, dtype=jnp.int32)[:, None]
    offs = (jnp.arange(accesses, dtype=jnp.int32)[None, :] * stride)
    idx = (base * accesses * stride + offs) % x.shape[0]
    return x[idx].sum()


def stride_sweep(
    strides: Sequence[int] = (1, 4),
    concurrencies: Sequence[int] = (1, 2, 4, 8, 16, 32),
    accesses: int = 4096,
    working_set_bytes: int = 1 << 22,
    iters: int = 7,
) -> List[StridePoint]:
    """Fig 7/8 analogue: latency vs concurrency for unit vs skewed stride."""
    n = working_set_bytes // 4
    x = jnp.arange(n, dtype=jnp.float32)
    out = []
    for s in strides:
        for c in concurrencies:
            t = timing.time_fn(_strided_reduce, x, s, c, accesses,
                               iters=iters)
            out.append(StridePoint(
                stride=s, concurrency=c,
                ns_per_access=t.median_s / (c * accesses) * 1e9,
            ))
    return out


# ---------------------------------------------------------------------------
# Streaming bandwidth (Fig 10)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BandwidthResult:
    mode: str                 # read | write | copy
    nbytes: int
    gbps: float


@jax.jit
def _bw_read(x):
    return x.sum()


@partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _bw_write(n, out, c):
    del out
    return jnp.full((n,), c, jnp.float32)


@jax.jit
def _bw_copy(x):
    return x * 1.0


def stream_bandwidth(
    nbytes: int = 1 << 28,
    modes: Sequence[str] = ("read", "write", "copy"),
    iters: int = 7,
) -> List[BandwidthResult]:
    n = nbytes // 4
    x = jnp.ones((n,), jnp.float32)
    out: List[BandwidthResult] = []
    for mode in modes:
        if mode == "read":
            t = timing.time_fn(_bw_read, x, iters=iters)
            moved = n * 4
        elif mode == "write":
            buf = jnp.zeros((n,), jnp.float32)
            # donate the buffer so each call truly writes n*4 bytes
            t = timing.time_fn(lambda: _bw_write(n, jnp.zeros((n,),
                               jnp.float32), jnp.float32(1.0)), iters=iters)
            moved = n * 4
            del buf
        else:
            t = timing.time_fn(_bw_copy, x, iters=iters)
            moved = 2 * n * 4
        out.append(BandwidthResult(mode, moved,
                                   moved / t.median_s / 1e9))
    return out


# ---------------------------------------------------------------------------
# Concurrency scaling (Fig 9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConcurrencyPoint:
    streams: int
    ns_per_stream_access: float
    aggregate_gbps: float


@partial(jax.jit, static_argnums=(1,))
def _multi_stream(x: jax.Array, streams: int) -> jax.Array:
    xs = x.reshape(streams, -1)
    return jax.vmap(jnp.sum)(xs).sum()


def concurrency_scaling(
    streams_list: Sequence[int] = (1, 2, 4, 8, 16, 32),
    total_bytes: int = 1 << 26,
    iters: int = 7,
) -> List[ConcurrencyPoint]:
    """Fig 9 analogue: fixed total traffic split across N concurrent
    streams; graceful saturation vs contention collapse."""
    n = total_bytes // 4
    out = []
    for s in streams_list:
        m = (n // s) * s
        x = jnp.ones((m,), jnp.float32)
        t = timing.time_fn(_multi_stream, x, s, iters=iters)
        accesses_per_stream = m // s
        out.append(ConcurrencyPoint(
            streams=s,
            ns_per_stream_access=t.median_s / accesses_per_stream * 1e9,
            aggregate_gbps=m * 4 / t.median_s / 1e9,
        ))
    return out
