"""Matrix-unit probes — paper §V (Fig 4/5) and §VII.A (Fig 11, Tab VII).

The paper sweeps ``mma`` tile shapes (m16n8k32 etc.), precision formats, and
(warp count x ILP) to locate the tensor-core saturation point, then runs a
dense-GEMM case study across matrix sizes.

TPU adaptation (DESIGN.md §3): the MXU is a 128x128 systolic array, not
per-warp fragments.  The tile axis becomes the matmul block shape — aligned
(multiples of 128) vs misaligned shapes expose padding waste; the warp axis
becomes batch/grid parallelism; the ILP axis becomes independent accumulator
chains within one dispatch.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import timing
from repro.core.device_model import DeviceModel, detect_backend_model


@dataclasses.dataclass(frozen=True)
class MatmulPoint:
    m: int
    n: int
    k: int
    dtype: str
    batch: int                 # "warp count" analogue (parallel tiles)
    ilp: int                   # independent chains per dispatch
    runtime_ms: float
    tflops: float              # (2*M*N*K*batch*ilp)/runtime — paper Eq. 2
    aligned: bool              # all dims multiples of the MXU tile


def _aligned(m: int, n: int, k: int, tile: int) -> bool:
    return m % tile == 0 and n % tile == 0 and k % tile == 0


@partial(jax.jit, static_argnums=(2,))
def _mm_ilp(a: jax.Array, b: jax.Array, ilp: int) -> jax.Array:
    """``ilp`` independent matmul chains over batched operands.

    a: (batch, ilp, m, k), b: (batch, ilp, k, n).  Each (batch, ilp) cell is
    an independent product; the sum forces completion of all of them.
    """
    out = jnp.einsum("bimk,bikn->bimn", a, b,
                     preferred_element_type=jnp.float32)
    return out.sum(axis=(1, 2, 3))


def measure_matmul(
    m: int, n: int, k: int,
    dtype: str = "bfloat16",
    batch: int = 1,
    ilp: int = 1,
    device: DeviceModel | None = None,
    iters: int = 10,
) -> MatmulPoint:
    device = device or detect_backend_model()
    dt = jnp.dtype(dtype)
    key = jax.random.PRNGKey(0)
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (batch, ilp, m, k), jnp.float32).astype(dt)
    b = jax.random.normal(kb, (batch, ilp, k, n), jnp.float32).astype(dt)
    t = timing.time_fn(_mm_ilp, a, b, ilp, iters=iters)
    flops = 2.0 * m * n * k * batch * ilp
    return MatmulPoint(
        m=m, n=n, k=k, dtype=dtype, batch=batch, ilp=ilp,
        runtime_ms=t.median_s * 1e3,
        tflops=flops / t.median_s / 1e12,
        aligned=_aligned(m, n, k, device.matrix_tile[0] or 128),
    )


def tile_sweep(
    dtype: str = "bfloat16",
    shapes: Optional[Sequence[tuple]] = None,
    device: DeviceModel | None = None,
    iters: int = 10,
) -> List[MatmulPoint]:
    """§V.B analogue: aligned vs misaligned tile shapes.

    Misaligned shapes (not multiples of the 128-wide MXU) get padded by the
    compiler — visible as a TFLOP/s drop at near-identical nominal FLOPs,
    the same operand-staging story as the paper's tile-shape table.
    """
    if shapes is None:
        shapes = [
            (128, 128, 128), (256, 256, 256), (512, 512, 512),
            (1024, 1024, 1024),
            # misaligned: +/-1 off the MXU tile and odd fractions
            (127, 127, 127), (129, 129, 129), (96, 96, 96),
            (384, 384, 100), (1000, 1000, 1000),
        ]
    return [measure_matmul(m, n, k, dtype, device=device, iters=iters)
            for (m, n, k) in shapes]


def warp_ilp_sweep(
    dtype: str = "bfloat16",
    batches: Sequence[int] = (1, 2, 4, 8, 16, 32),
    ilps: Sequence[int] = (1, 2, 4, 6, 8),
    m: int = 128, n: int = 128, k: int = 128,
    device: DeviceModel | None = None,
    iters: int = 8,
) -> List[MatmulPoint]:
    """Fig 4/5 analogue: throughput/latency vs (parallel tiles x ILP).

    The paper finds GB203 saturates at ILP=6 with 25 warps and GH100 at
    ILP=5 with 29 warps; here the analogous saturation point is where
    TFLOP/s stops growing with ``batch`` (occupancy) or ``ilp``.
    """
    out = []
    for b in batches:
        for i in ilps:
            out.append(measure_matmul(m, n, k, dtype, batch=b, ilp=i,
                                      device=device, iters=iters))
    return out


def saturation_point(points: Sequence[MatmulPoint],
                     tol: float = 0.05) -> MatmulPoint:
    """First point achieving within ``tol`` of the sweep's peak TFLOP/s —
    the paper's "maximum ILP level at which sustained throughput is
    achieved"."""
    peak = max(p.tflops for p in points)
    for p in sorted(points, key=lambda p: (p.batch, p.ilp)):
        if p.tflops >= (1 - tol) * peak:
            return p
    return points[-1]


def gemm_case_study(
    dtype: str = "bfloat16",
    sizes: Sequence[tuple] = (
        (512, 512, 512),
        (1024, 1024, 1024),
        (2048, 2048, 2048),
        (2048, 2048, 4096),
        (2048, 4096, 8192),
        (4096, 4096, 4096),
    ),
    device: DeviceModel | None = None,
    iters: int = 5,
) -> List[MatmulPoint]:
    """§VII.A (Fig 11, Tab VII): D-GEMM runtime/TFLOPs across sizes.

    The paper's 8192-cube is ~1.1 TB of fp32 intermediates on a 1-core CPU;
    the default sweep stops at 4096 and the benchmark harness extrapolates
    via the roofline model for the 8192 row (flagged as modeled).
    """
    return [measure_matmul(m, n, k, dtype, device=device, iters=iters)
            for (m, n, k) in sizes]
