"""Low-precision format probes — paper §V.A-C (Tab IV/V/VI).

The paper enumerates the FP4/FP6/FP8 ``mma`` variants Blackwell accepts
(`.kind::f8f6f4`), inspects the SASS each lowers to (QMMA vs OMMA vs HMMA —
discovering FP4 *falls back* to the FP8 QMMA pipeline unless e8m0 block
scaling is used), and measures power per format.

TPU adaptation: the formats exist as ``ml_dtypes`` (fp4 e2m1, fp6 e2m3/e3m2,
fp8 e4m3/e5m2, e8m0 scale).  The "which pipeline does it really use" probe
becomes HLO inspection: does a dot in format X lower to a native dot, or to
``convert`` -> bf16 ``dot`` (the TPU's QMMA-fallback analogue)?  Block
scaling with e8m0 exponents (MXFP-style) is implemented and validated for
numerics; energy per format comes from ``repro.core.energy``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro import compat

# The paper's Tab V rows (+ e8m0, which it excludes from mma operands —
# "only used for scaling exponents", same role here).
FORMATS: Dict[str, np.dtype] = {
    "e2m1": np.dtype(ml_dtypes.float4_e2m1fn),    # FP4
    "e2m3": np.dtype(ml_dtypes.float6_e2m3fn),    # FP6
    "e3m2": np.dtype(ml_dtypes.float6_e3m2fn),    # FP6
    "e4m3": np.dtype(ml_dtypes.float8_e4m3fn),    # FP8
    "e5m2": np.dtype(ml_dtypes.float8_e5m2),      # FP8
}
SCALE_FORMAT = np.dtype(ml_dtypes.float8_e8m0fnu)

# Format metadata (bits, max finite value) — Tab IV/V support matrix.
FORMAT_INFO: Dict[str, Dict[str, float]] = {
    "e2m1": dict(bits=4, max=6.0),
    "e2m3": dict(bits=6, max=7.5),
    "e3m2": dict(bits=6, max=28.0),
    "e4m3": dict(bits=8, max=448.0),
    "e5m2": dict(bits=8, max=57344.0),
}

# short Tab V name -> canonical repro.compat registry name
_COMPAT_NAME = {
    "e2m1": "float4_e2m1fn",
    "e2m3": "float6_e2m3fn",
    "e3m2": "float6_e3m2fn",
    "e4m3": "float8_e4m3fn",
    "e5m2": "float8_e5m2",
}


@dataclasses.dataclass(frozen=True)
class FormatSupport:
    """One Tab IV/V row: how a format actually executes on this backend."""

    fmt: str
    bits: int
    max_finite: float
    representable: bool           # array creation + cast round-trip works
    native_dot: bool              # dot without explicit convert in HLO
    lowers_via_convert: bool      # the "QMMA fallback" analogue
    pipeline: str                 # e.g. "bf16-MXU (dequant)", "native"
    compat_name: str = ""         # canonical repro.compat registry name


def _dot_hlo(fmt_dtype: np.dtype) -> str:
    def f(a, b):
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    try:
        # jnp rejects dtypes it has no lowering for (fp6 — the analogue of
        # the paper's "PTX error without .kind::f8f6f4"): report unsupported
        a = jnp.zeros((8, 8), dtype=fmt_dtype)
        b = jnp.zeros((8, 8), dtype=fmt_dtype)
        return jax.jit(f).lower(a, b).compile().as_text()
    except Exception:
        return ""


def support_matrix() -> List[FormatSupport]:
    """Enumerate what each paper format lowers to on this backend —
    the SASS-inspection (§V.B) analogue over compiled HLO."""
    out = []
    for name, dt in FORMATS.items():
        info = FORMAT_INFO[name]
        try:
            x = np.asarray([1.0, -0.5], dtype=dt)
            representable = bool(
                np.allclose(x.astype(np.float32), [1.0, -0.5]))
        except Exception:
            representable = False
        hlo = _dot_hlo(dt)
        has_dot = " dot(" in hlo or " dot." in hlo or "dot_general" in hlo
        via_convert = "convert" in hlo
        if not hlo:
            # jnp can't hold or lower the dtype — report how the compat
            # registry stages emulated formats (container + host
            # rounding), the software analogue of the paper's QMMA
            # fallback; a registered-but-unlowerable dtype stays
            # "unsupported".
            spec = compat.dtype_spec(_COMPAT_NAME[name])
            pipeline = (f"compat: {spec.describe()}" if spec.emulated
                        else "unsupported")
        elif via_convert:
            pipeline = "wide-MXU (convert/dequant)"   # QMMA-fallback analogue
        else:
            pipeline = "native"
        out.append(FormatSupport(
            fmt=name,
            bits=int(info["bits"]),
            max_finite=info["max"],
            representable=representable,
            native_dot=has_dot and not via_convert,
            lowers_via_convert=via_convert,
            pipeline=pipeline,
            compat_name=_COMPAT_NAME[name],
        ))
    return out


# ---------------------------------------------------------------------------
# Numerics: cast error + MXFP block scaling (e8m0), §V.C precision tradeoffs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CastError:
    fmt: str
    rel_err_mean: float
    rel_err_max: float
    overflow_frac: float


def cast_error(fmt: str, x: Optional[np.ndarray] = None,
               seed: int = 0, n: int = 1 << 14) -> CastError:
    """Round-trip x -> fmt -> fp32 relative error on ~N(0,1) data."""
    dt = FORMATS[fmt]
    if x is None:
        x = np.random.default_rng(seed).normal(size=n).astype(np.float32)
    q = x.astype(dt).astype(np.float32)
    finite = np.isfinite(q)
    denom = np.maximum(np.abs(x), 1e-6)
    rel = np.abs(q - x) / denom
    return CastError(
        fmt=fmt,
        rel_err_mean=float(rel[finite].mean()) if finite.any() else np.inf,
        rel_err_max=float(rel[finite].max()) if finite.any() else np.inf,
        overflow_frac=float(1.0 - finite.mean()),
    )


def block_quantize(x: jnp.ndarray, fmt: str, block: int = 32
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MXFP-style block quantization: e8m0 power-of-two scale per block.

    Returns ``(q, scales)`` with ``q`` in the target format over the last
    axis blocked by ``block``.  This is the paper's observed OMMA path:
    FP4/FP6 operands + ue8m0 block scales.
    """
    assert x.shape[-1] % block == 0, (x.shape, block)
    dt = FORMATS[fmt]
    fmax = FORMAT_INFO[fmt]["max"]
    xb = x.reshape(*x.shape[:-1], x.shape[-1] // block, block)
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    # power-of-two scale (e8m0 has no mantissa): 2^ceil(log2(absmax/fmax))
    exp = jnp.ceil(jnp.log2(jnp.maximum(absmax, 1e-30) / fmax))
    scale = jnp.exp2(exp)
    q = (xb / scale).astype(dt)
    return q.reshape(x.shape), scale.squeeze(-1)


def block_dequantize(q: jnp.ndarray, scales: jnp.ndarray, block: int = 32,
                     out_dtype=jnp.float32) -> jnp.ndarray:
    qb = q.astype(out_dtype).reshape(
        *q.shape[:-1], q.shape[-1] // block, block)
    return (qb * scales[..., None]).reshape(q.shape)


def block_roundtrip_error(fmt: str, shape=(64, 256), block: int = 32,
                          seed: int = 0) -> float:
    """Mean relative error of quantize->dequantize with e8m0 block scales —
    the numeric half of the paper's precision-tradeoff analysis."""
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * 4.0
    q, s = block_quantize(x, fmt, block)
    y = block_dequantize(q, s, block)
    rel = jnp.abs(y - x) / jnp.maximum(jnp.abs(x), 1e-6)
    return float(rel.mean())
