"""Execution-pipeline probes — paper §IV (Tab III, Fig 2/3).

The paper distinguishes:

* **True latency** — a serialized chain of *dependent* instructions
  (``mad.lo.s32`` / ``fma.rn.f32``): cycles until a result is usable by the
  next instruction.
* **Completion latency** — *independent* instructions allowed to overlap:
  cycles/instruction once the pipeline can parallelize.

and sweeps dependent-chain length 1..1024 to expose scheduler ramp-up
(Fig 2/3), plus mixed INT32/FP32 streams to expose the unified-core
behaviour of GB203 and the FP64-unit scarcity (2/SM on GB203, none on TPU).

TPU adaptation (DESIGN.md §3): the chain is a value carried through an
*unrolled* sequence of ``x*a+b`` ops — dependent => true latency; a wide
vector of independent lanes => completion latency.  "Cycles" are wall-time
converted via the device clock.  FP64 on TPU has no ALU — with JAX's default
x64-disabled config it is silently downcast, so each result records whether
the measurement is native, emulated or downcast.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.core import timing
from repro.core.device_model import DeviceModel, detect_backend_model

# Independent lanes for completion-latency/throughput probes (the analogue
# of issuing across many warps).
_LANES = 4096


def _is_x64_native(dtype) -> bool:
    return jnp.zeros((), dtype).dtype == jnp.dtype(dtype)


def _init_vals(shape, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        return (jnp.full(shape, 1, dtype), jnp.asarray(3, dtype),
                jnp.asarray(1, dtype))
    return (jnp.full(shape, 1.0001, dtype), jnp.asarray(1.0000001, dtype),
            jnp.asarray(1e-7, dtype))


def _make_chain(n: int, lanes: int, dtype) -> Callable:
    """Jitted fn: unrolled chain of ``n`` dependent mad/fma ops.

    ``lanes == 1`` -> one scalar dependent chain (true latency);
    ``lanes > 1``  -> that many independent chains (completion latency).
    Unrolled, not looped — like the paper's generated PTX sequences — so
    loop control does not pollute short chains.
    """
    shape = () if lanes == 1 else (lanes,)

    @jax.jit
    def fn(x, a, b):
        for _ in range(n):
            x = x * a + b
        return x

    return partial(fn, *_init_vals(shape, dtype))


def _make_mixed1(n: int, lanes: int) -> Callable:
    """Interleaved *independent* int32+fp32 dependent chains (co-issue
    test — the paper's Mixed 1: does a unified INT/FP pipeline co-schedule
    two pure streams?)."""
    shape = () if lanes == 1 else (lanes,)

    @jax.jit
    def fn(xi, xf, ai, bi, af, bf):
        for _ in range(n):
            xi = xi * ai + bi
            xf = xf * af + bf
        return xi, xf

    xi, ai, bi = _init_vals(shape, jnp.int32)
    xf, af, bf = _init_vals(shape, jnp.float32)
    return partial(fn, xi, xf, ai, bi, af, bf)


def _make_mixed2(n: int, lanes: int) -> Callable:
    """Cross-dependent int<->fp chain with converts (hazard test — the
    paper's Mixed 2: forces the scheduler to alternate pipelines on a
    single dependence chain)."""
    shape = () if lanes == 1 else (lanes,)

    @jax.jit
    def fn(xi, xf, af, bf):
        for _ in range(n // 2):
            xf = xf * af + xi.astype(jnp.float32)
            xi = (xf * 0.5).astype(jnp.int32) + xi
        return xi, xf

    xi, _, _ = _init_vals(shape, jnp.int32)
    xf, af, bf = _init_vals(shape, jnp.float32)
    return partial(fn, xi, xf, af, bf)


_WORKLOADS: Dict[str, dict] = {
    "int32": dict(kind="pure", dtype=jnp.int32, ops_per_step=1),
    "fp32": dict(kind="pure", dtype=jnp.float32, ops_per_step=1),
    "fp64": dict(kind="pure", dtype=jnp.float64, ops_per_step=1),
    "mixed1": dict(kind="mixed1", dtype=None, ops_per_step=2),
    "mixed2": dict(kind="mixed2", dtype=None, ops_per_step=2),
}


def _builder(workload: str):
    spec = _WORKLOADS[workload]
    if spec["kind"] == "pure":
        return lambda n, lanes: _make_chain(n, lanes, spec["dtype"])
    if spec["kind"] == "mixed1":
        return _make_mixed1
    return _make_mixed2


@dataclasses.dataclass(frozen=True)
class LatencyResult:
    """One Tab III cell: per-instruction latency, ns and device cycles."""

    workload: str
    support: str                  # native | downcast | emulated
    true_ns: float
    completion_ns: float
    true_cycles: float
    completion_cycles: float


def measure_latency(
    workload: str,
    device: DeviceModel | None = None,
    chain: int = 256,
    iters: int = 20,
) -> LatencyResult:
    """Measure one workload's true + completion latency (Tab III)."""
    device = device or detect_backend_model()
    spec = _WORKLOADS[workload]
    make = _builder(workload)
    n_ops = spec["ops_per_step"] * chain if spec["kind"] != "pure" else chain

    base1 = timing.time_fn(make(0, 1), iters=iters)
    full1 = timing.time_fn(make(chain, 1), iters=iters)
    baseL = timing.time_fn(make(0, _LANES), iters=iters)
    fullL = timing.time_fn(make(chain, _LANES), iters=iters)
    t_true = timing.amortized_ns(full1, base1, n_ops)
    t_comp = timing.amortized_ns(fullL, baseL, n_ops)

    support = "native"
    if workload == "fp64":
        if device.kind == "tpu":
            support = "emulated"
        elif not _is_x64_native(jnp.float64):
            support = "downcast"

    clock = device.clock_hz
    return LatencyResult(
        workload=workload,
        support=support,
        true_ns=t_true,
        completion_ns=t_comp,
        true_cycles=t_true * 1e-9 * clock,
        completion_cycles=t_comp * 1e-9 * clock,
    )


def latency_table(device: DeviceModel | None = None,
                  workloads: Sequence[str] = tuple(_WORKLOADS),
                  chain: int = 256, iters: int = 20) -> List[LatencyResult]:
    """The full Tab III analogue."""
    return [measure_latency(w, device, chain, iters) for w in workloads]


@dataclasses.dataclass(frozen=True)
class RampPoint:
    """One Fig 2/3 point: dependent-chain length vs cycles & throughput."""

    chain_len: int
    total_ns: float
    total_cycles: float
    ops_per_cycle: float


def ilp_ramp(
    workload: str = "fp32",
    lengths: Sequence[int] = (1, 2, 4, 6, 8, 12, 16, 24, 32, 48, 64,
                              128, 256, 512, 1024),
    lanes: int = _LANES,
    device: DeviceModel | None = None,
    iters: int = 15,
) -> List[RampPoint]:
    """Fig 2/3 analogue: sweep chain length, report total time & throughput.

    ``lanes`` independent chains of ``n`` dependent ops each — as ``n``
    grows the scheduler can hide latency across lanes; the paper observes a
    plateau past ~64 and sharper ramp differences between architectures.
    """
    device = device or detect_backend_model()
    make = _builder(workload)
    base = timing.time_fn(make(0, lanes), iters=iters)
    out: List[RampPoint] = []
    ops_per_step = _WORKLOADS[workload]["ops_per_step"]
    for n in lengths:
        t = timing.time_fn(make(n, lanes), iters=iters)
        dt = max(t.median_s - base.median_s, 1e-12)
        n_ops = n * ops_per_step * lanes
        cycles = timing.to_cycles(dt, device.clock_hz)
        out.append(RampPoint(
            chain_len=n,
            total_ns=dt * 1e9,
            total_cycles=cycles,
            ops_per_cycle=n_ops / cycles if cycles > 0 else 0.0,
        ))
    return out


def fp64_emulation_factor(device: DeviceModel | None = None,
                          iters: int = 15) -> float:
    """§IV.C: how much slower is an fp64 chain than fp32 (per op)?

    On GB203 the paper finds 63.57 vs 4 cycles (~16x) because only 2 FP64
    units exist per SM; on TPU the factor measures XLA's software emulation
    (or the downcast no-op if x64 is disabled, factor ~1).
    """
    f32 = measure_latency("fp32", device, iters=iters)
    f64 = measure_latency("fp64", device, iters=iters)
    if f32.completion_ns <= 0:
        return 0.0
    return f64.completion_ns / f32.completion_ns
