"""Interconnect probes — beyond-paper extension (DESIGN.md §3, §8.5).

The paper is single-GPU; a 1000+-node framework needs roofline term 3
(collectives).  This module characterizes each collective's alpha-beta model

    t(bytes) = alpha + bytes / beta

by timing ``psum`` / ``all_gather`` / ``ppermute`` over a device mesh when
more than one device is available, and falling back to the DeviceModel's
published link constants otherwise (this CPU container has one device; the
multi-device path is exercised in tests via a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import timing
from repro.core.device_model import DeviceModel, detect_backend_model


@dataclasses.dataclass(frozen=True)
class CollectivePoint:
    collective: str
    nbytes: int
    devices: int
    seconds: float
    algo_gbps: float            # nbytes / t — algorithm bandwidth


@dataclasses.dataclass(frozen=True)
class AlphaBeta:
    collective: str
    devices: int
    alpha_s: float              # latency term
    beta_Bps: float             # bandwidth term
    measured: bool              # False => analytical fallback


def _collective_fn(name: str, mesh: jax.sharding.Mesh) -> Callable:
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    if name == "psum":
        def inner(x):
            return jax.lax.psum(x, "d")
    elif name == "all_gather":
        def inner(x):
            return jax.lax.all_gather(x, "d")
    elif name == "ppermute":
        n = mesh.devices.size

        def inner(x):
            return jax.lax.ppermute(
                x, "d", [(i, (i + 1) % n) for i in range(n)])
    else:
        raise ValueError(name)

    return jax.jit(shard_map(inner, mesh=mesh, in_specs=P("d"),
                             out_specs=P() if name == "psum" else P("d")
                             if name == "ppermute" else P(None, "d")))


def measure_collective(
    name: str,
    nbytes: int,
    iters: int = 10,
) -> Optional[CollectivePoint]:
    """Time one collective at one size; None if <2 devices available."""
    devs = jax.devices()
    if len(devs) < 2:
        return None
    mesh = jax.sharding.Mesh(np.asarray(devs), ("d",))
    n = max(nbytes // 4, len(devs))
    n -= n % len(devs)
    x = jnp.ones((n,), jnp.float32)
    fn = _collective_fn(name, mesh)
    t = timing.time_fn(fn, x, iters=iters)
    return CollectivePoint(
        collective=name, nbytes=n * 4, devices=len(devs),
        seconds=t.median_s, algo_gbps=n * 4 / t.median_s / 1e9,
    )


def fit_alpha_beta(points: Sequence[CollectivePoint]) -> AlphaBeta:
    """Least-squares fit of t = alpha + bytes/beta."""
    xs = np.asarray([p.nbytes for p in points], np.float64)
    ts = np.asarray([p.seconds for p in points], np.float64)
    A = np.stack([np.ones_like(xs), xs], axis=1)
    (alpha, inv_beta), *_ = np.linalg.lstsq(A, ts, rcond=None)
    beta = 1.0 / inv_beta if inv_beta > 0 else float("inf")
    return AlphaBeta(
        collective=points[0].collective,
        devices=points[0].devices,
        alpha_s=max(float(alpha), 0.0),
        beta_Bps=float(beta),
        measured=True,
    )


def characterize(
    names: Sequence[str] = ("psum", "all_gather", "ppermute"),
    sizes: Sequence[int] = (1 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 24),
    device: DeviceModel | None = None,
    iters: int = 8,
) -> List[AlphaBeta]:
    """alpha-beta per collective; analytical fallback on 1 device."""
    device = device or detect_backend_model()
    out: List[AlphaBeta] = []
    for name in names:
        pts = [p for s in sizes
               if (p := measure_collective(name, s, iters)) is not None]
        if len(pts) >= 2:
            out.append(fit_alpha_beta(pts))
        else:
            # Published-constant fallback: ring latency ~1us/hop, bandwidth
            # = per-link bw (psum moves 2x data, accounted via beta/2).
            beta = device.link_Bps or 10e9
            out.append(AlphaBeta(
                collective=name, devices=max(jax.device_count(), 1),
                alpha_s=1e-6,
                beta_Bps=beta / 2 if name == "psum" else beta,
                measured=False,
            ))
    return out


def predicted_time(ab: AlphaBeta, nbytes: int) -> float:
    return ab.alpha_s + nbytes / ab.beta_Bps
