"""Loop-aware HLO cost model.

``compiled.cost_analysis()`` counts a while-loop body ONCE — a scan-heavy
program (scan-over-layers, gradient-accumulation scan, chunked attention)
is undercounted by orders of magnitude (measured: MFU "4.2" on the kimi
train cell before this module existed; see EXPERIMENTS.md §Perf).

This walks the optimized HLO text instead:

* computations are parsed into blocks; every value's shape comes from its
  def line, so operand shapes resolve without a real HLO parser;
* ``while`` ops multiply their body cost by the trip count XLA annotates
  (``backend_config={"known_trip_count":{"n":...}}``);
* ``fusion`` bytes = fusion operands + result (internal traffic is free —
  XLA's own cost semantics); fusion FLOPs = dots/convs inside the called
  computation;
* dot FLOPs = 2 * prod(result) * prod(lhs contracting dims);
* elementwise/reduce ops count 1 FLOP/output element (they are never the
  roofline-dominant term; dots and data movement are);
* collectives accumulate into :class:`~repro.core.hlo_analysis.CollectiveStats`
  with loop multipliers applied.

The result is the (FLOPs, HBM-bytes, collective-bytes) triple the roofline
consumes — per device, since the parsed module is the partitioned one.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

from repro.core.hlo_analysis import (
    CollectiveStats, _BYTES_PER_ELEM, _COLLECTIVE_KINDS)

_SHAPE_TOKEN = re.compile(
    r"\b(" + "|".join(sorted(_BYTES_PER_ELEM, key=len, reverse=True))
    + r")\[([0-9,]*)\]")

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.:-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.:-]+)\s+\(.*\)\s*->")
_OPCODE_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\],{}]+)+)\s+([\w-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.:-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\s*{\s*"n":\s*"?(\d+)"?')
_CALLS_RE = re.compile(r"(?:calls=|body=|condition=|to_apply=)%?([\w.:-]+)")
_BODY_RE = re.compile(r"body=%?([\w.:-]+)")
_COND_RE = re.compile(r"condition=%?([\w.:-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_OPS = frozenset({
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
    "copy-start", "copy-done", "add-dependency", "domain", "opt-barrier",
})


def _shape_info(text: str) -> Tuple[float, int]:
    """(bytes, element_count) summed over every shape literal in text."""
    total_b, total_n = 0.0, 0
    for dtype, dims in _SHAPE_TOKEN.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_b += n * _BYTES_PER_ELEM[dtype]
        total_n += n
    return total_b, total_n


def _result_dims(result_text: str) -> List[List[int]]:
    """All shape dim-lists in a result type string."""
    out = []
    for _, dims in _SHAPE_TOKEN.findall(result_text):
        out.append([int(d) for d in dims.split(",")] if dims else [])
    return out


@dataclasses.dataclass
class _Op:
    name: str
    result_text: str
    opcode: str
    rest: str          # full text after '=' (operands, attrs)
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: CollectiveStats = dataclasses.field(
        default_factory=CollectiveStats)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collectives.total_bytes += other.collectives.total_bytes * mult
        for k, v in other.collectives.bytes_by_kind.items():
            self.collectives.bytes_by_kind[k] += v * mult
        for k, v in other.collectives.count_by_kind.items():
            self.collectives.count_by_kind[k] += int(v * mult)


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[_Op]] = {}
        self.defs: Dict[str, Dict[str, str]] = {}   # comp -> name -> result
        self.entry: Optional[str] = None
        self._memo: Dict[str, Cost] = {}
        self._parse(hlo_text)

    # ------------------------------------------------------------------ #
    def _parse(self, text: str) -> None:
        comp = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line or line.lstrip().startswith("//"):
                continue
            if line.endswith("{") and "->" in line:
                m = _COMP_HDR_RE.match(line)
                if m:
                    comp = m.group(1)
                    self.comps[comp] = []
                    self.defs[comp] = {}
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = comp
                    continue
            if comp is None:
                continue
            if line.strip() == "}":
                comp = None
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            om = _OPCODE_RE.match(rhs)
            if not om:
                continue
            result_text, opcode = om.group(1), om.group(2)
            self.comps[comp].append(
                _Op(name, result_text, opcode, rhs, line))
            self.defs[comp][name] = result_text

    # ------------------------------------------------------------------ #
    def _fusion_param_bytes(self, callee: str) -> Dict[int, float]:
        """Traffic adjustment for a fused computation's parameters.

        A scan iteration dynamic-slices its stacked weights INSIDE a
        fusion; charging the full (n_periods, ...) operand per iteration
        inflates traffic by the trip count (measured 91% of all bytes on
        the qwen train cell).  A parameter consumed ONLY by slice-family
        ops is charged at the slice results' size instead.
        """
        ops = self.comps.get(callee, [])
        params: Dict[int, str] = {}
        for op in ops:
            if op.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", op.rest)
                if m:
                    params[int(m.group(1))] = op.name
        out: Dict[int, float] = {}
        slice_ops = ("dynamic-slice", "slice", "gather")
        for idx, pname in params.items():
            full = _shape_info(self.defs[callee].get(pname, ""))[0]
            uses = [op for op in ops
                    if op.opcode != "parameter"
                    and re.search(r"%" + re.escape(pname) + r"\b", op.rest)]
            if uses and all(u.opcode in slice_ops for u in uses):
                out[idx] = sum(_shape_info(u.result_text)[0] for u in uses)
            elif uses and all(u.opcode == "dynamic-update-slice"
                              and u.rest.find("%" + pname)
                              == u.rest.find("(") + 1 for u in uses):
                # buffer operand of a dus: aliased read-modify-write —
                # only the update (charged at the root) moves bytes
                out[idx] = 0.0
            else:
                out[idx] = full
        return out

    def _fusion_bytes(self, comp: str, op: _Op) -> float:
        """fusion traffic = adjusted parameter reads + result write.

        ``kind=kLoop`` (pure elementwise) fusions charge the result only:
        the CPU backend fragments elementwise chains into many small
        fusions that a TPU backend fuses into their consumers — charging
        their operands would bill every intermediate twice (the producer
        charges the write; the consuming dot charges the read)."""
        result_b = _shape_info(op.result_text)[0]
        cm = _CALLS_RE.search(op.rest)
        if not cm:
            return result_b + self._operand_bytes(comp, op)
        callee = cm.group(1)
        if "kind=kLoop" in op.rest:
            # scan stacking compiles to convert->dus->convert over the
            # full stacked buffer; on TPU the dus aliases in place, so
            # the traffic is the update slice, not the stack
            inner_ops = self.comps.get(callee, [])
            for o in inner_ops:
                if o.opcode == "dynamic-update-slice":
                    upd = _OPERAND_RE.findall(o.rest)
                    if len(upd) >= 2:
                        ures = self.defs[callee].get(upd[1], "")
                        ub = _shape_info(ures)[0]
                        if ub:
                            return 2.0 * ub
                    break
            # pure dtype-conversion fusions (fp8/bf16 dequant chains)
            # stream into their consumer on TPU: charge the (narrow)
            # input read, not the widened result write
            body = [o for o in inner_ops if o.opcode != "parameter"]
            if body and all(o.opcode in ("convert", "bitcast",
                                         "reduce-precision", "copy",
                                         "transpose")
                            for o in body):
                adj = self._fusion_param_bytes(callee)
                return min(sum(adj.values()), result_b) if adj \
                    else result_b
            return result_b
        adj = self._fusion_param_bytes(callee)
        # operand order == parameter index order
        start = op.rest.find(op.opcode + "(") + len(op.opcode) + 1
        depth, j = 1, start
        while j < len(op.rest) and depth:
            if op.rest[j] == "(":
                depth += 1
            elif op.rest[j] == ")":
                depth -= 1
            j += 1
        names = _OPERAND_RE.findall(op.rest[start:j - 1])
        total = result_b
        local = self.defs.get(comp, {})
        for idx, name in enumerate(names):
            if idx in adj:
                total += adj[idx]
                continue
            res = local.get(name)
            if res is None:
                for d in self.defs.values():
                    if name in d:
                        res = d[name]
                        break
            if res:
                total += _shape_info(res)[0]
        # a fusion rooted in dynamic-update-slice writes the update, not
        # the whole buffer (output aliases the input operand)
        roots = [o for o in self.comps.get(callee, [])
                 if o.line.lstrip().startswith("ROOT")]
        if roots and roots[0].opcode == "dynamic-update-slice":
            total -= result_b
            upd = _OPERAND_RE.findall(roots[0].rest)
            if len(upd) >= 2:
                ures = self.defs[callee].get(upd[1], "")
                total += _shape_info(ures)[0]
        return total

    def _operand_bytes(self, comp: str, op: _Op) -> float:
        """Sum of operand sizes, resolved from def lines."""
        # operand list = text between the opcode's parens
        start = op.rest.find(op.opcode + "(") + len(op.opcode) + 1
        depth, j = 1, start
        while j < len(op.rest) and depth:
            if op.rest[j] == "(":
                depth += 1
            elif op.rest[j] == ")":
                depth -= 1
            j += 1
        operand_text = op.rest[start:j - 1]
        total = 0.0
        local = self.defs.get(comp, {})
        for name in _OPERAND_RE.findall(operand_text):
            res = local.get(name)
            if res is None:
                for d in self.defs.values():
                    if name in d:
                        res = d[name]
                        break
            if res:
                total += _shape_info(res)[0]
        return total

    def _dot_flops(self, comp: str, op: _Op) -> float:
        result_b, result_n = _shape_info(op.result_text)
        k = 1
        cm = _CONTRACT_RE.search(op.rest)
        if cm:
            lhs_name = _OPERAND_RE.search(
                op.rest[op.rest.find("dot(") + 4:])
            lhs_dims: List[int] = []
            if lhs_name:
                res = self.defs.get(comp, {}).get(lhs_name.group(1))
                if res is None:
                    for d in self.defs.values():
                        if lhs_name.group(1) in d:
                            res = d[lhs_name.group(1)]
                            break
                if res:
                    dims_all = _result_dims(res)
                    if dims_all:
                        lhs_dims = dims_all[0]
            if lhs_dims and cm.group(1):
                for idx in cm.group(1).split(","):
                    i = int(idx)
                    if i < len(lhs_dims):
                        k *= lhs_dims[i]
        return 2.0 * result_n * k

    def _conv_flops(self, comp: str, op: _Op) -> float:
        result_b, result_n = _shape_info(op.result_text)
        # kernel = 2nd operand; flops ~ 2*prod(result)*prod(kernel)/out_ch
        names = _OPERAND_RE.findall(op.rest[op.rest.find("(") + 1:])
        if len(names) >= 2:
            res = None
            for d in self.defs.values():
                if names[1] in d:
                    res = d[names[1]]
                    break
            if res:
                dims = _result_dims(res)
                if dims and dims[0]:
                    kernel_n = 1
                    for x in dims[0]:
                        kernel_n *= x
                    out_ch = max(dims[0])
                    return 2.0 * result_n * kernel_n / max(out_ch, 1)
        return 2.0 * result_n

    # ------------------------------------------------------------------ #
    def comp_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total          # break cycles defensively
        for op in self.comps.get(comp, []):
            oc = op.opcode
            if oc in _SKIP_OPS:
                continue
            result_bytes, result_n = _shape_info(op.result_text)
            if oc == "while":
                trip = 1
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trip = int(tm.group(1))
                bm = _BODY_RE.search(op.rest)
                if bm:
                    total.add(self.comp_cost(bm.group(1)), trip)
                cm = _COND_RE.search(op.rest)
                if cm:
                    total.add(self.comp_cost(cm.group(1)), trip)
                continue
            if oc in ("call", "conditional", "async-start"):
                for callee in _CALLS_RE.findall(op.rest):
                    total.add(self.comp_cost(callee))
                continue
            if oc == "fusion":
                cm2 = _CALLS_RE.search(op.rest)
                if cm2:
                    inner = self.comp_cost(cm2.group(1))
                    total.flops += inner.flops
                # fused internal traffic is free: adjusted params + result
                total.bytes += self._fusion_bytes(comp, op)
                continue
            if oc in ("dynamic-slice", "slice", "gather"):
                total.bytes += 2.0 * result_bytes    # read slice + write
                total.flops += float(result_n)
                continue
            if oc in ("dynamic-update-slice", "scatter"):
                # writes update-sized data into an aliased buffer
                names = _OPERAND_RE.findall(op.rest)
                upd_b = 0.0
                if len(names) >= 2:
                    for d in self.defs.values():
                        if names[1] in d:
                            upd_b = _shape_info(d[names[1]])[0]
                            break
                total.bytes += 2.0 * (upd_b or result_bytes)
                total.flops += float(result_n)
                continue
            base = oc[:-6] if oc.endswith("-start") else oc
            if oc.endswith("-done"):
                continue
            if base in _COLLECTIVE_KINDS:
                nbytes = self._operand_bytes(comp, op) or result_bytes
                total.collectives.total_bytes += nbytes
                total.collectives.bytes_by_kind[base] += nbytes
                total.collectives.count_by_kind[base] += 1
                total.bytes += nbytes + result_bytes
                continue
            if oc == "dot":
                total.flops += self._dot_flops(comp, op)
            elif oc == "convolution":
                total.flops += self._conv_flops(comp, op)
            else:
                total.flops += float(result_n)   # 1 flop / output element
            total.bytes += result_bytes + self._operand_bytes(comp, op)
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        # prefer callee-flop counting inside fusions for dots: fusions that
        # wrap dots are handled in comp_cost via the `calls=` recursion
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze_hlo_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()
