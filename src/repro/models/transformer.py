"""Model assembly: heterogeneous blocks arranged in repeating periods,
scanned with ``lax.scan`` so HLO size is O(period) not O(n_layers).

Three execution modes share one parameter tree:
  * ``lm_forward``     — teacher-forced full sequence (training / scoring)
  * ``lm_prefill``     — forward + KV/SSM cache construction (serving)
  * ``lm_decode_step`` — one token against the cache (serving)

Supports: decoder-only LMs (dense/GQA/MQA, local+global windows, logit
softcaps, MoE FFNs, SSD mixers, hybrid interleaves), encoder-decoder
(seamless: audio-frontend stub -> encoder; decoder w/ cross-attention),
and VLM early fusion (patch-embedding stub prepended to the trunk).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockSpec
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import slotstate
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    apply_mlp, dense_init, embed, init_mlp, init_rms_norm, rms_norm, unembed)

# Number of vision patches the VLM frontend stub contributes to the trunk.
VLM_PATCHES = 256

AUX_KEYS = ("moe_lb_loss", "moe_z_loss", "moe_dropped")


def _shard_batch(x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Pin the batch dim to the DP mesh axes (activation sharding
    constraint at block boundaries — megatron-style batch-sharded,
    d-replicated activations).  No-op when cfg.batch_axes is unset."""
    if not cfg.batch_axes:
        return x
    from jax.sharding import PartitionSpec as P
    axes = cfg.batch_axes[0] if len(cfg.batch_axes) == 1 \
        else tuple(cfg.batch_axes)
    return jax.lax.with_sharding_constraint(
        x, P(axes, *(None for _ in x.shape[1:])))


def _zero_aux() -> Dict[str, jax.Array]:
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


def _acc_aux(acc, new):
    out = dict(acc)
    for k, v in new.items():
        out[k] = out[k] + v
    return out


# --------------------------------------------------------------------- #
# Block init / apply
# --------------------------------------------------------------------- #

def init_block(key: jax.Array, cfg: ArchConfig, blk: BlockSpec, dtype
               ) -> dict:
    ks = iter(jax.random.split(key, 8))
    p: dict = {}
    if blk.mixer == "attn":
        p["ln_mix"] = init_rms_norm(cfg.d_model, dtype)
        p["attn"] = attn.init_attention(next(ks), cfg, dtype)
        if blk.cross_attn:
            p["ln_cross"] = init_rms_norm(cfg.d_model, dtype)
            p["cross"] = attn.init_attention(next(ks), cfg, dtype)
    elif blk.mixer == "ssm":
        p["ln_mix"] = init_rms_norm(cfg.d_model, dtype)
        p["ssm"] = ssm_lib.init_ssm(next(ks), cfg, dtype)
    if blk.ffn == "dense":
        p["ln_ffn"] = init_rms_norm(cfg.d_model, dtype)
        p["mlp"] = init_mlp(next(ks), cfg.d_model, cfg.d_ff,
                            cfg.mlp_variant, dtype)
    elif blk.ffn == "moe":
        p["ln_ffn"] = init_rms_norm(cfg.d_model, dtype)
        p["moe"] = moe_lib.init_moe(next(ks), cfg, dtype)
    return p


def _self_attention_train(p, x, cfg: ArchConfig, blk: BlockSpec,
                          causal: bool = True,
                          return_kv: bool = False,
                          k_valid: Optional[jax.Array] = None):
    positions = jnp.arange(x.shape[1])
    q = attn.project_q(p, x)
    k, v = attn.project_kv(p, x)
    from repro.models.layers import apply_rope
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    ka, va = k, v
    if cfg.attn_repeat_kv and cfg.n_kv_heads < cfg.n_heads:
        g = cfg.n_heads // cfg.n_kv_heads
        ka, va = jnp.repeat(k, g, axis=2), jnp.repeat(v, g, axis=2)
    if cfg.attn_seq_shard and cfg.batch_axes:
        # context parallelism: queries sharded over 'model' (KV stays
        # full — each shard attends its query slice to all keys); the
        # causal mask is position-computed so SPMD partitions it exactly
        from jax.sharding import PartitionSpec as P
        b_ax = cfg.batch_axes[0] if len(cfg.batch_axes) == 1 \
            else tuple(cfg.batch_axes)
        q = jax.lax.with_sharding_constraint(
            q, P(b_ax, "model", None, None))
    o = attn.attention(q, ka, va, causal=causal, window=blk.window,
                       softcap=cfg.attn_logit_softcap,
                       chunk=cfg.attn_chunk, k_valid=k_valid)
    if cfg.attn_seq_shard and cfg.batch_axes:
        from jax.sharding import PartitionSpec as P
        b_ax = cfg.batch_axes[0] if len(cfg.batch_axes) == 1 \
            else tuple(cfg.batch_axes)
        o = jax.lax.with_sharding_constraint(
            o, P(b_ax, "model", None, None))
    out = attn.project_out(p, o)
    if return_kv:
        return out, (k, v)
    return out


def apply_block(p: dict, blk: BlockSpec, cfg: ArchConfig, x: jax.Array,
                enc_out: Optional[jax.Array] = None,
                causal: bool = True,
                k_valid: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, dict]:
    """Full-sequence block (training / scoring).  Returns (x, aux).

    ``k_valid`` (b, s) masks padded key positions in self-attention
    (pooled encoder batches pad frames to a fixed enc_len)."""
    aux: Dict[str, jax.Array] = {}
    x = _shard_batch(x, cfg)
    if blk.mixer == "attn":
        h = rms_norm(p["ln_mix"], x, cfg.norm_eps)
        x = x + _self_attention_train(p["attn"], h, cfg, blk, causal=causal,
                                      k_valid=k_valid)
        if blk.cross_attn and enc_out is not None:
            h = rms_norm(p["ln_cross"], x, cfg.norm_eps)
            q = attn.project_q(p["cross"], h)
            k, v = attn.project_kv(p["cross"], enc_out)
            o = attn.attention(q, k, v, causal=False)
            x = x + attn.project_out(p["cross"], o)
    elif blk.mixer == "ssm":
        h = rms_norm(p["ln_mix"], x, cfg.norm_eps)
        x = x + ssm_lib.ssm_forward(p["ssm"], h, cfg)
    if blk.ffn == "dense":
        h = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
        x = x + apply_mlp(p["mlp"], h, cfg.mlp_variant)
    elif blk.ffn == "moe":
        h = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
        y, aux = moe_lib.apply_moe(p["moe"], h, cfg)
        x = x + y
    return x, aux


# --------------------------------------------------------------------- #
# Parameter tree
# --------------------------------------------------------------------- #

def init_lm(key: jax.Array, cfg: ArchConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    pattern = cfg.block_pattern()
    n_p = cfg.n_periods
    k_embed, k_unembed, k_layers, k_enc = jax.random.split(key, 4)

    params: dict = {
        "embed": dense_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype,
                            fan_in=cfg.d_model),
        "final_norm": init_rms_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(
            k_unembed, (cfg.d_model, cfg.vocab_size), dtype,
            fan_in=cfg.d_model)

    layer_keys = jax.random.split(k_layers, len(pattern))
    stacked = {}
    for i, blk in enumerate(pattern):
        per_keys = jax.random.split(layer_keys[i], n_p)
        stacked[f"pos{i}"] = jax.vmap(
            lambda k, blk=blk: init_block(k, cfg, blk, dtype))(per_keys)
    params["layers"] = stacked

    if cfg.is_encoder_decoder:
        enc_blk = BlockSpec(mixer="attn", ffn="dense")
        enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(
                lambda k: init_block(k, cfg, enc_blk, dtype))(enc_keys),
            "final_norm": init_rms_norm(cfg.d_model, dtype),
        }
    return params


def _remat_wrap(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


# --------------------------------------------------------------------- #
# Encoder (enc-dec archs)
# --------------------------------------------------------------------- #

def encode(params: dict, frames: jax.Array, cfg: ArchConfig,
           valid: Optional[jax.Array] = None) -> jax.Array:
    """Bidirectional encoder over frontend embeddings (b, s_src, d).

    ``valid`` (b, s_src) bool masks padded frames out of every
    self-attention (outputs at padded positions are garbage and must be
    masked by the caller)."""
    enc_blk = BlockSpec(mixer="attn", ffn="dense")
    x = frames.astype(jnp.dtype(cfg.compute_dtype))

    def layer_fn(x, layer_params):
        x, _ = apply_block(layer_params, enc_blk, cfg, x, causal=False,
                           k_valid=valid)
        return x, None

    x, _ = jax.lax.scan(_remat_wrap(layer_fn, cfg), x,
                        params["encoder"]["layers"])
    return rms_norm(params["encoder"]["final_norm"], x, cfg.norm_eps)


# --------------------------------------------------------------------- #
# Full-sequence forward (training / scoring)
# --------------------------------------------------------------------- #

def trunk_inputs(params: dict, cfg: ArchConfig, batch: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Token embeddings (+ modality fusion) and optional encoder output."""
    x = embed(params["embed"], batch["tokens"])
    enc_out = None
    if cfg.frontend == "vision" and "patches" in batch:
        x = jnp.concatenate(
            [batch["patches"].astype(x.dtype), x], axis=1)
    if cfg.is_encoder_decoder:
        enc_out = encode(params, batch["frames"], cfg)
    return _shard_batch(x.astype(jnp.dtype(cfg.compute_dtype)), cfg), enc_out


def lm_features(params: dict, batch: Dict[str, jax.Array], cfg: ArchConfig
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Trunk output after the final norm, BEFORE unembedding:
    (features (b, s_trunk, d) at compute dtype, aux losses).

    The training loss consumes features + :func:`unembed_weight` and
    projects to vocab in sequence chunks — materializing the full fp32
    (b, s, vocab) logits costs ~5 GiB/device at 150k vocabs (measured in
    the dry-run before this refactor; see EXPERIMENTS.md §Perf)."""
    pattern = cfg.block_pattern()
    x, enc_out = trunk_inputs(params, cfg, batch)

    def period_fn(carry, period_params):
        x, aux = carry
        for i, blk in enumerate(pattern):
            x, a = apply_block(period_params[f"pos{i}"], blk, cfg, x,
                               enc_out=enc_out)
            aux = _acc_aux(aux, a)
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(_remat_wrap(period_fn, cfg),
                               (x, _zero_aux()), params["layers"])
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def unembed_weight(params: dict, cfg: ArchConfig) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def lm_forward(params: dict, batch: Dict[str, jax.Array], cfg: ArchConfig
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (logits (b, s_trunk, vocab) fp32, aux losses)."""
    x, aux = lm_features(params, batch, cfg)
    logits = unembed(unembed_weight(params, cfg), x,
                     softcap=cfg.final_logit_softcap)
    return logits, aux


# --------------------------------------------------------------------- #
# Serving: cache init / prefill / decode
# --------------------------------------------------------------------- #

def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               enc_len: int = 0) -> dict:
    """Cache pytree; attention capacities honor sliding windows (ring).

    ``cfg.cache_dtype`` (e.g. float8_e4m3fn) stores attention KV at
    reduced precision — decode is weight/KV-read bound, so this is the
    §VII.B serving-precision lever applied to the cache.
    ``cfg.kv_format`` goes further: truly *quantized* KV storage
    (packed fp8/fp4 codes + 1-byte e8m0 block scales; fp4 ≈ 0.53 B/elem
    measured vs 2 B/elem bf16 — the §VI.D read-bandwidth lever), and
    ``cfg.kv_formats`` mixes formats per position-in-period (fp8 global /
    fp4 local layers).  Cross-attention KV is a ring cache of the same
    layout (capacity = enc_len, slot_pos marks valid source positions),
    so it quantizes — and is evicted/cleared — exactly like self-attn KV.
    SSM conv/state stay at compute/fp32 precision (tiny, and the
    recurrence compounds rounding)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    kv_dtype = jnp.dtype(cfg.cache_dtype or cfg.compute_dtype)
    pattern = cfg.block_pattern()
    n_p = cfg.n_periods
    cache: dict = {}
    for i, blk in enumerate(pattern):
        entry: dict = {}
        kv_fmt = cfg.kv_format_for(i)
        if blk.mixer == "attn":
            cap = attn.cache_capacity(max_seq, blk.window)
            kv = attn.init_kv_cache(batch, cap, cfg.n_kv_heads,
                                    cfg.head_dim, kv_dtype,
                                    kv_format=kv_fmt)
            entry["kv"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_p,) + a.shape), kv)
            if blk.cross_attn:
                ckv = attn.init_kv_cache(batch, enc_len, cfg.n_kv_heads,
                                         cfg.head_dim, kv_dtype,
                                         kv_format=kv_fmt)
                entry["cross_kv"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (n_p,) + a.shape), ckv)
        elif blk.mixer == "ssm":
            sc = ssm_lib.init_ssm_cache(cfg, batch, dtype)
            entry["ssm"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_p,) + a.shape), sc)
        cache[f"pos{i}"] = entry
    if cfg.is_encoder_decoder:
        cache["enc_out"] = jnp.zeros((batch, enc_len, cfg.d_model), dtype)
    return cache


def kv_cache_stats(cache: dict, cfg: ArchConfig) -> dict:
    """*Measured* attention-KV storage accounting over a cache pytree.

    Walks the ``pos*`` entries' ``kv`` AND ``cross_kv`` ring caches (SSM
    state and the int32 ``slot_pos`` bookkeeping are excluded — they are
    format-independent) and reports ``sum(arr.nbytes)`` over what is
    actually stored, the number the Tab VIII / long-context artifacts
    quote:

      * ``kv_bytes``        — total stored K/V payload (codes + scales),
        self- and cross-attention combined,
      * ``cross_kv_bytes``  — the cross-attention share of ``kv_bytes``
        (0 for decoder-only archs),
      * ``bytes_per_elem``  — payload / logical K,V element count (fp4 +
        e8m0 byte scales ≈ 0.53 at head_dim 128; 2.0 for bf16),
      * ``bytes_per_token`` — HBM bytes one cached *decoder* token
        position costs across the layer stack (what each decoded token
        reads per position of context, and writes once; cross-KV is
        per-source-position, not per-decoded-token, so it is reported
        in ``cross_kv_bytes`` instead),
      * ``per_layer``       — {pos name: {format, bytes_per_elem}}
        measured per position-in-period (mixed ``kv_formats`` show
        their different widths here).
    """
    kv_bytes, cross_bytes, elems, per_token = 0, 0, 0, 0.0
    per_layer: dict = {}
    for name, entry in cache.items():
        if not name.startswith("pos"):
            continue
        i = int(name[3:])
        for part in ("kv", "cross_kv"):
            if part not in entry:
                continue
            kv = entry[part]
            n_p, b, cap = kv["slot_pos"].shape
            payload = sum(v.nbytes for k2, v in kv.items()
                          if k2 != "slot_pos")
            part_elems = 2 * n_p * b * cap * cfg.n_kv_heads * cfg.head_dim
            kv_bytes += payload
            elems += part_elems
            if part == "kv":
                per_token += payload / (b * cap)
            else:
                cross_bytes += payload
            key = name if part == "kv" else f"{name}.cross"
            per_layer[key] = {
                "format": cfg.kv_format_for(i)
                or (cfg.cache_dtype or cfg.compute_dtype),
                "bytes_per_elem": payload / part_elems,
            }
    return {"kv_format": cfg.kv_format or (cfg.cache_dtype
                                           or cfg.compute_dtype),
            "kv_bytes": int(kv_bytes),
            "cross_kv_bytes": int(cross_bytes),
            "bytes_per_elem": kv_bytes / elems if elems else 0.0,
            "bytes_per_token": per_token,
            "per_layer": per_layer}


def lm_prefill(params: dict, batch: Dict[str, jax.Array], cfg: ArchConfig,
               max_seq: int) -> Tuple[jax.Array, dict]:
    """Forward over the prompt, building the cache.  Returns
    (last-position logits (b, vocab), cache)."""
    pattern = cfg.block_pattern()
    x, enc_out = trunk_inputs(params, cfg, batch)
    s = x.shape[1]
    cache = init_cache(cfg, x.shape[0], max_seq,
                       enc_len=enc_out.shape[1] if enc_out is not None else 0)

    def period_fn(carry, period_params):
        x, aux = carry
        new_entries = {}
        for i, blk in enumerate(pattern):
            x = _shard_batch(x, cfg)
            p = period_params[f"pos{i}"]
            entry = {}
            kv_fmt = cfg.kv_format_for(i)
            if blk.mixer == "attn":
                h = rms_norm(p["ln_mix"], x, cfg.norm_eps)
                out, (k, v) = _self_attention_train(
                    p["attn"], h, cfg, blk, return_kv=True)
                x = x + out
                cap = attn.cache_capacity(max_seq, blk.window)
                kv0 = attn.init_kv_cache(x.shape[0], cap, cfg.n_kv_heads,
                                         cfg.head_dim, k.dtype,
                                         kv_format=kv_fmt)
                entry["kv"] = attn.cache_write_prefill(kv0, k, v,
                                                       kv_format=kv_fmt)
                if blk.cross_attn and enc_out is not None:
                    h = rms_norm(p["ln_cross"], x, cfg.norm_eps)
                    q = attn.project_q(p["cross"], h)
                    ck, cv = attn.project_kv(p["cross"], enc_out)
                    # cross-KV is a ring cache like self-attn KV:
                    # quantize-on-write (kv_fmt), slot_pos = source
                    # positions; the prompt attends the CACHED view so
                    # prefill, chunked prefill, and decode all read the
                    # same (possibly dequantized) cross keys
                    ckv0 = attn.init_kv_cache(
                        x.shape[0], enc_out.shape[1], cfg.n_kv_heads,
                        cfg.head_dim, k.dtype, kv_format=kv_fmt)
                    ckv = attn.cache_write_prefill(ckv0, ck, cv,
                                                   kv_format=kv_fmt)
                    kc, vc = attn.cache_kv(ckv, kv_fmt, cfg.head_dim,
                                           out_dtype=x.dtype)
                    o = attn.attention(q, kc, vc, causal=False)
                    x = x + attn.project_out(p["cross"], o)
                    entry["cross_kv"] = ckv
            elif blk.mixer == "ssm":
                h = rms_norm(p["ln_mix"], x, cfg.norm_eps)
                out, ssm_cache = ssm_lib.ssm_forward(
                    p["ssm"], h, cfg, return_state=True)
                x = x + out
                entry["ssm"] = ssm_cache
            if blk.ffn == "dense":
                h = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
                x = x + apply_mlp(p["mlp"], h, cfg.mlp_variant)
            elif blk.ffn == "moe":
                h = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
                y, a = moe_lib.apply_moe(p["moe"], h, cfg)
                x = x + y
                aux = _acc_aux(aux, a)
            new_entries[f"pos{i}"] = entry
        return (x, aux), new_entries

    (x, _), per_period = jax.lax.scan(period_fn, (x, _zero_aux()),
                                      params["layers"])
    for key in per_period:
        cache[key] = per_period[key]
    if enc_out is not None:
        cache["enc_out"] = enc_out
    x_last = rms_norm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    w_out = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = unembed(w_out, x_last, softcap=cfg.final_logit_softcap)[:, 0]
    return logits, cache


def lm_decode_step(params: dict, cache: dict, token: jax.Array,
                   pos: jax.Array, cfg: ArchConfig,
                   active: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, dict]:
    """One decode step.  token: (b,) int32; pos: (b,) int32 per-row
    position of the *incoming* token (rows advance independently under
    continuous batching; pass a broadcast scalar for lockstep decode).
    Returns (logits (b, vocab), updated cache).

    ``active`` (optional (b,) bool) masks *all* cache mutation through
    the slot-state protocol (``repro.models.slotstate.decode_advance``):
    ring KV is masked at the write site, cross-KV/enc_out are read-only,
    and every recurrent part (SSM conv/state) row-selects new-vs-old —
    one predicate, no per-mixer special cases.  That is what makes this
    step scan-compatible inside the fused multi-token decode loop for
    EVERY arch family: finished pool slots ride along at zero state cost
    (their logits are computed but garbage, and the caller masks their
    samples)."""
    from repro.models.layers import apply_rope
    pattern = cfg.block_pattern()
    x = embed(params["embed"], token[:, None])        # (b, 1, d)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    enc_out = cache.get("enc_out")
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, token.shape)
    positions = pos[:, None]                          # (b, 1)

    def period_fn(x, scanned):
        period_params, period_cache = scanned
        new_cache = {}
        for i, blk in enumerate(pattern):
            p = period_params[f"pos{i}"]
            c = period_cache[f"pos{i}"]
            kv_fmt = cfg.kv_format_for(i)
            new_parts = {}
            if blk.mixer == "attn":
                h = rms_norm(p["ln_mix"], x, cfg.norm_eps)
                q = attn.project_q(p["attn"], h)
                k, v = attn.project_kv(p["attn"], h)
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
                kv = attn.cache_write_decode(c["kv"], k, v, pos,
                                             kv_format=kv_fmt,
                                             active=active)
                kc, vc = attn.cache_kv(kv, kv_fmt, cfg.head_dim,
                                       out_dtype=x.dtype)
                o = attn.decode_attention(
                    q, kc, vc, kv["slot_pos"], pos,
                    window=blk.window, softcap=cfg.attn_logit_softcap)
                x = x + attn.project_out(p["attn"], o)
                new_parts["kv"] = kv
                if blk.cross_attn and "cross_kv" in c:
                    h = rms_norm(p["ln_cross"], x, cfg.norm_eps)
                    q = attn.project_q(p["cross"], h)
                    ck, cv = attn.cache_kv(c["cross_kv"], kv_fmt,
                                           cfg.head_dim, out_dtype=x.dtype)
                    # every valid source slot is visible (slot_pos >= 0
                    # masks padding); a huge query position makes the
                    # causal comparison vacuous
                    o = attn.cache_attention(
                        q, ck, cv, c["cross_kv"]["slot_pos"],
                        jnp.full_like(positions, jnp.int32(2 ** 30)))
                    x = x + attn.project_out(p["cross"], o)
                    new_parts["cross_kv"] = c["cross_kv"]
            elif blk.mixer == "ssm":
                h = rms_norm(p["ln_mix"], x, cfg.norm_eps)
                out, new_parts["ssm"] = ssm_lib.ssm_decode(p["ssm"], h,
                                                           c["ssm"], cfg)
                x = x + out
            entry = {part: slotstate.decode_advance(active, part, new,
                                                    c[part])
                     for part, new in new_parts.items()}
            if blk.ffn == "dense":
                h = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
                x = x + apply_mlp(p["mlp"], h, cfg.mlp_variant)
            elif blk.ffn == "moe":
                h = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
                y, _ = moe_lib.apply_moe(p["moe"], h, cfg)
                x = x + y
            new_cache[f"pos{i}"] = entry
        return x, new_cache

    layer_cache = {k: v for k, v in cache.items() if k.startswith("pos")}
    x, new_layer_cache = jax.lax.scan(
        period_fn, x, (params["layers"], layer_cache))
    out_cache = dict(new_layer_cache)
    if enc_out is not None:
        out_cache["enc_out"] = enc_out
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    w_out = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = unembed(w_out, x, softcap=cfg.final_logit_softcap)[:, 0]
    return logits, out_cache


# --------------------------------------------------------------------- #
# Chunked pooled prefill (serving admission without host scatter)
# --------------------------------------------------------------------- #

def supports_chunked_prefill(cfg: ArchConfig) -> bool:
    """Always true: the slot-state protocol gives every arch family a
    chunked-prefill leg — attention writes the chunk's ring region, SSM
    carries conv/state across chunk boundaries
    (:func:`repro.models.ssm.ssm_prefill_chunk`), enc-dec encodes once
    into slot-resident enc_out/cross-KV (:func:`lm_encode_slot`) and
    chunks the decoder prompt, and VLM chunks the patch-embedding prefix
    through the same executable (``embeds=``).  Kept as a function for
    API compatibility with the pre-protocol engine."""
    return True


def min_cache_capacity(cfg: ArchConfig, max_seq: int) -> int:
    """Smallest per-layer ring capacity (local windows shrink it) — the
    upper bound on a prefill chunk (chunk slots must be distinct)."""
    caps = [attn.cache_capacity(max_seq, b.window)
            for b in cfg.block_pattern() if b.mixer == "attn"]
    return min(caps) if caps else max_seq


def clear_slot(cache: dict, slot: jax.Array) -> dict:
    """Evict pool row ``slot`` under the slot-state protocol: ring parts
    (self- AND cross-attn KV) mark their entries empty (slot_pos = -1;
    payload bytes stay — position masking makes them unreachable), every
    other part zeroes the slot row.  Runs jitted with ``slot`` traced
    (one executable serves every slot).  See ``repro.models.slotstate``."""
    return slotstate.clear_slot(cache, slot)


def lm_prefill_chunk(params: dict, cache: dict, tokens: jax.Array,
                     slot: jax.Array, pos_offset: jax.Array,
                     valid_len: jax.Array, cfg: ArchConfig,
                     embeds: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, dict]:
    """Prefill one prompt *chunk* for pool row ``slot`` directly into the
    shared serving cache — the chunked pooled-prefill step, for every
    arch family via the slot-state protocol.

    tokens: (chunk,) int32, zero-padded past ``valid_len``;
    pos_offset: scalar int32 absolute trunk position of tokens[0];
    valid_len: scalar int32 number of real tokens in this chunk;
    embeds: optional (1, chunk, d_model) — when given, the chunk's trunk
    inputs are these precomputed embeddings instead of token lookups
    (the VLM patch prefix streams through the SAME chunk machinery; the
    engine keeps it a separate jitted executable so each stays
    compiled-exactly-once).
    slot/pos_offset/valid_len are traced, so ceil(prompt/chunk)
    dispatches of ONE compiled executable admit any prompt — no
    host-side cache pytree rematerialization, no recompilation per
    prompt length.

    Per mixer (one ``valid`` predicate drives every write):
      * attention writes the chunk's K/V (quantize-on-write under the
        position's kv format) into the slot's ring region and attends
        the chunk queries against history + itself via position masking;
      * SSM carries conv/ssm state across chunk boundaries
        (:func:`repro.models.ssm.ssm_prefill_chunk`);
      * cross-attention reads the slot's cross-KV written once by
        :func:`lm_encode_slot` (read-only here, like decode).

    Returns (logits (1, vocab) at the last valid position, updated
    cache).
    """
    from repro.models.layers import apply_rope
    pattern = cfg.block_pattern()
    s = tokens.shape[0]
    if embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = embed(params["embed"], tokens[None, :])       # (1, s, d)
        x = x.astype(jnp.dtype(cfg.compute_dtype))
    positions = pos_offset + jnp.arange(s, dtype=jnp.int32)   # (s,)
    valid = jnp.arange(s) < valid_len

    def period_fn(x, scanned):
        period_params, period_cache = scanned
        new_cache = {}
        for i, blk in enumerate(pattern):
            p = period_params[f"pos{i}"]
            c = period_cache[f"pos{i}"]
            kv_fmt = cfg.kv_format_for(i)
            entry = {}
            if blk.mixer == "attn":
                h = rms_norm(p["ln_mix"], x, cfg.norm_eps)
                q = attn.project_q(p["attn"], h)
                k, v = attn.project_kv(p["attn"], h)
                q = apply_rope(q, positions[None, :], cfg.rope_theta)
                k = apply_rope(k, positions[None, :], cfg.rope_theta)
                kv_row = slotstate.take_row(c["kv"], slot)
                # Attend against the PRE-write history concatenated with
                # the chunk's own raw K/V.  Writing first and attending
                # over the ring would be wrong once a chunk wraps a
                # sliding-window ring (capacity == window): the chunk's
                # later writes evict positions still inside its earlier
                # queries' windows.  The concat view keeps every position
                # the full-prefill oracle sees — history from the cache,
                # intra-chunk causality via the position mask — and
                # matches lm_prefill in using the chunk's unquantized K/V
                # for its own queries.
                kc, vc = attn.cache_kv(kv_row, kv_fmt, cfg.head_dim,
                                       out_dtype=x.dtype)
                chunk_sp = jnp.where(valid, positions, -1)[None, :]
                o = attn.cache_attention(
                    q,
                    jnp.concatenate([kc, k.astype(kc.dtype)], axis=1),
                    jnp.concatenate([vc, v.astype(vc.dtype)], axis=1),
                    jnp.concatenate([kv_row["slot_pos"], chunk_sp],
                                    axis=1),
                    positions[None, :], window=blk.window,
                    softcap=cfg.attn_logit_softcap)
                x = x + attn.project_out(p["attn"], o)
                kv_row = attn.cache_write_chunk(kv_row, k, v, positions,
                                                valid, kv_format=kv_fmt)
                entry["kv"] = slotstate.put_row(c["kv"], kv_row, slot)
                if blk.cross_attn and "cross_kv" in c:
                    h = rms_norm(p["ln_cross"], x, cfg.norm_eps)
                    q = attn.project_q(p["cross"], h)
                    ckv_row = slotstate.take_row(c["cross_kv"], slot)
                    ck, cv = attn.cache_kv(ckv_row, kv_fmt, cfg.head_dim,
                                           out_dtype=x.dtype)
                    o = attn.cache_attention(
                        q, ck, cv, ckv_row["slot_pos"],
                        jnp.full_like(positions, jnp.int32(2 ** 30))[
                            None, :])
                    x = x + attn.project_out(p["cross"], o)
                    entry["cross_kv"] = c["cross_kv"]    # read-only
            elif blk.mixer == "ssm":
                h = rms_norm(p["ln_mix"], x, cfg.norm_eps)
                ssm_row = slotstate.take_row(c["ssm"], slot)
                out, ssm_row = ssm_lib.ssm_prefill_chunk(
                    p["ssm"], h, ssm_row, cfg, valid, valid_len)
                x = x + out
                entry["ssm"] = slotstate.put_row(c["ssm"], ssm_row, slot)
            if blk.ffn == "dense":
                h = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
                x = x + apply_mlp(p["mlp"], h, cfg.mlp_variant)
            elif blk.ffn == "moe":
                h = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
                y, _ = moe_lib.apply_moe(p["moe"], h, cfg)
                x = x + y
            new_cache[f"pos{i}"] = entry
        return x, new_cache

    layer_cache = {k: v for k, v in cache.items() if k.startswith("pos")}
    x, new_layer_cache = jax.lax.scan(
        period_fn, x, (params["layers"], layer_cache))
    x_last = jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, axis=1)
    x_last = rms_norm(params["final_norm"], x_last, cfg.norm_eps)
    w_out = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = unembed(w_out, x_last, softcap=cfg.final_logit_softcap)[:, 0]
    out_cache = dict(new_layer_cache)
    if "enc_out" in cache:
        out_cache["enc_out"] = cache["enc_out"]          # read-only
    return logits, out_cache


def lm_verify_chunk(params: dict, cache: dict, tokens: jax.Array,
                    positions: jax.Array, cfg: ArchConfig
                    ) -> Tuple[jax.Array, dict]:
    """Speculative verify: forward ``s`` tentative tokens per pool row in
    ONE batched pass, producing logits BIT-IDENTICAL to ``s`` successive
    :func:`lm_decode_step` calls — without writing the cache.

    tokens: (b, s) int32 — row r is [last committed token, draft_1, ...,
    draft_{s-1}]; positions: (b, s) int32 — the absolute position of each
    incoming token (``pos[r] + j``; rows advance independently).  Returns
    (logits (b, s, vocab) fp32, ``info``): logits row j is the
    next-token distribution after consuming tokens[:, :j+1], and ``info``
    is the period-stacked commit payload :func:`lm_commit_chunk` consumes
    (attention: the chunk's post-rope raw K/V; SSM: discretized inputs +
    conv streams).

    Exactness per mixer (the differential conformance suite pins this):

      * attention queries attend the CONCAT of the pre-block cache view
        and the chunk's own roundtripped K/V (quantize->dequantize under
        the position's kv format — exactly the values decode reads back
        after its quantize-on-write; dense caches cast to the storage
        dtype).  The visible set matches decode at every step: a ring
        overwrite during the block evicts an entry exactly when it
        leaves the window (capacity == window), and the window mask
        hides that entry from precisely the queries whose step would
        have run post-overwrite.
      * SSM runs the decode recurrence sequentially
        (:func:`repro.models.ssm.ssm_verify_chunk`), read-only.
      * cross-attention / enc_out are read-only in decode already.

    Inactive rows produce garbage logits (their tokens are held
    constant); the engine masks them at acceptance time, exactly like
    the non-speculative loop masks its samples.
    """
    from repro.models.layers import apply_rope
    pattern = cfg.block_pattern()
    b, s = tokens.shape
    x = embed(params["embed"], tokens)                # (b, s, d)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    enc_out = cache.get("enc_out")

    def period_fn(x, scanned):
        period_params, period_cache = scanned
        info = {}
        for i, blk in enumerate(pattern):
            p = period_params[f"pos{i}"]
            c = period_cache[f"pos{i}"]
            kv_fmt = cfg.kv_format_for(i)
            leg: dict = {}
            if blk.mixer == "attn":
                h = rms_norm(p["ln_mix"], x, cfg.norm_eps)
                q = attn.project_q(p["attn"], h)
                k, v = attn.project_kv(p["attn"], h)
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
                kc, vc = attn.cache_kv(c["kv"], kv_fmt, cfg.head_dim,
                                       out_dtype=x.dtype)
                if attn.is_quantized_cache(c["kv"]):
                    # the chunk's own entries must be what decode READS
                    # after its quantize-on-write, not the raw values
                    kd = attn.dequantize_kv(*attn.quantize_kv(k, kv_fmt),
                                            kv_fmt, cfg.head_dim,
                                            out_dtype=x.dtype)
                    vd = attn.dequantize_kv(*attn.quantize_kv(v, kv_fmt),
                                            kv_fmt, cfg.head_dim,
                                            out_dtype=x.dtype)
                else:
                    kd, vd = k.astype(kc.dtype), v.astype(vc.dtype)
                o = attn.cache_attention(
                    q,
                    jnp.concatenate([kc, kd], axis=1),
                    jnp.concatenate([vc, vd], axis=1),
                    jnp.concatenate([c["kv"]["slot_pos"],
                                     positions.astype(jnp.int32)], axis=1),
                    positions, window=blk.window,
                    softcap=cfg.attn_logit_softcap)
                x = x + attn.project_out(p["attn"], o)
                leg["kv"] = {"k": k, "v": v}
                if blk.cross_attn and "cross_kv" in c:
                    h = rms_norm(p["ln_cross"], x, cfg.norm_eps)
                    q = attn.project_q(p["cross"], h)
                    ck, cv = attn.cache_kv(c["cross_kv"], kv_fmt,
                                           cfg.head_dim, out_dtype=x.dtype)
                    o = attn.cache_attention(
                        q, ck, cv, c["cross_kv"]["slot_pos"],
                        jnp.full_like(positions, jnp.int32(2 ** 30)))
                    x = x + attn.project_out(p["cross"], o)
            elif blk.mixer == "ssm":
                h = rms_norm(p["ln_mix"], x, cfg.norm_eps)
                out, leg["ssm"] = ssm_lib.ssm_verify_chunk(p["ssm"], h,
                                                           c["ssm"], cfg)
                x = x + out
            if blk.ffn == "dense":
                h = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
                x = x + apply_mlp(p["mlp"], h, cfg.mlp_variant)
            elif blk.ffn == "moe":
                h = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
                y, _ = moe_lib.apply_moe(p["moe"], h, cfg)
                x = x + y
            info[f"pos{i}"] = leg
        return x, info

    layer_cache = {k: v for k, v in cache.items() if k.startswith("pos")}
    x, info = jax.lax.scan(period_fn, x, (params["layers"], layer_cache))
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    w_out = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = unembed(w_out, x, softcap=cfg.final_logit_softcap)
    return logits, info


def lm_commit_chunk(cache: dict, info: dict, positions: jax.Array,
                    e: jax.Array, cfg: ArchConfig) -> dict:
    """Commit the first ``e`` verified positions per row into the serving
    cache — the write half :func:`lm_verify_chunk` deferred.

    positions: (b, s) as passed to verify; e: (b,) int32 accepted counts
    in [0, s] (0 for inactive/rejected-at-once rows — every write is a
    no-op there, which is what lets one executable serve all rows
    uniformly).  Attention commits through the SAME quantize-on-write
    path as decode (:func:`repro.models.attention.cache_write_rows`);
    SSM re-materializes state from the pre-block checkpoint with the
    rejected tail identity-masked
    (:func:`repro.models.ssm.ssm_commit_chunk`); cross-KV / enc_out are
    read-only.  Needs no parameters: verify's ``info`` already carries
    the post-rope K/V and discretized SSM inputs.
    """
    pattern = cfg.block_pattern()
    b, s = positions.shape
    valid = jnp.arange(s)[None, :] < e[:, None]          # (b, s)

    def period_fn(carry, scanned):
        period_cache, period_info = scanned
        new_cache = {}
        for i, blk in enumerate(pattern):
            c = period_cache[f"pos{i}"]
            leg = period_info[f"pos{i}"]
            entry = dict(c)
            if blk.mixer == "attn":
                entry["kv"] = attn.cache_write_rows(
                    c["kv"], leg["kv"]["k"], leg["kv"]["v"], positions,
                    valid, kv_format=cfg.kv_format_for(i))
            elif blk.mixer == "ssm":
                new_ssm = ssm_lib.ssm_commit_chunk(c["ssm"], leg["ssm"],
                                                   e, cfg)
                entry["ssm"] = slotstate.masked_tree(e > 0, new_ssm,
                                                     c["ssm"])
            new_cache[f"pos{i}"] = entry
        return carry, new_cache

    layer_cache = {k: v for k, v in cache.items() if k.startswith("pos")}
    _, new_layer_cache = jax.lax.scan(period_fn, 0.0, (layer_cache, info))
    out_cache = dict(new_layer_cache)
    if "enc_out" in cache:
        out_cache["enc_out"] = cache["enc_out"]
    return out_cache


def lm_rollback_chunk(cache: dict, positions: jax.Array,
                      reject: jax.Array) -> dict:
    """Invalidate speculative ring-cache writes at ``positions`` (b, s)
    where ``reject`` (b, s) — a slot_pos pointer move per self-attention
    layer (:func:`repro.models.attention.cache_rollback`), applied
    directly on the period-stacked leaves.  Cross-KV and recurrent parts
    are untouched: cross-KV is never speculatively written, and SSM
    state is committed-not-written (see :func:`lm_commit_chunk`).  Used
    on the DRAFT model's cache, whose drafting decode steps write
    eagerly and must un-write the rejected tail."""
    out: dict = {}
    for name, entry in cache.items():
        if not (name.startswith("pos") and isinstance(entry, dict)):
            out[name] = entry
            continue
        e = dict(entry)
        if "kv" in e:
            e["kv"] = attn.cache_rollback(e["kv"], positions, reject)
        out[name] = e
    return out


def lm_encode_slot(params: dict, cache: dict, frames: jax.Array,
                   slot: jax.Array, src_len: jax.Array, cfg: ArchConfig
                   ) -> dict:
    """Run the encoder ONCE for pool row ``slot`` and write the results
    slot-resident: ``enc_out`` row + every decoder layer's cross-KV ring
    row (quantize-on-write under the position's kv format, slot_pos =
    source positions, padding stays -1).  The decoder prompt then streams
    through :func:`lm_prefill_chunk` and decode reads the same cached
    cross view — encode-once, chunk-the-rest.

    frames: (1, enc_len, d_model) frontend embeddings padded to the
    pool's fixed enc_len; src_len: traced scalar int32 count of real
    frames.  ``slot``/``src_len`` traced — one compiled executable
    admits every request.
    """
    enc_len = frames.shape[1]
    valid = (jnp.arange(enc_len) < src_len)[None, :]      # (1, enc_len)
    enc = encode(params, frames, cfg, valid=valid)
    # padded encoder positions are garbage — zero them so the stored
    # enc_out row is clean (cross-attention masks them via slot_pos
    # anyway; this keeps the top-level leaf inspectable)
    enc = jnp.where(valid[..., None], enc, 0.0).astype(enc.dtype)
    positions = jnp.arange(enc_len, dtype=jnp.int32)
    pattern = cfg.block_pattern()

    def period_fn(carry, scanned):
        period_params, period_cache = scanned
        new_cross = {}
        for i, blk in enumerate(pattern):
            entry = {}
            if blk.cross_attn and "cross_kv" in period_cache[f"pos{i}"]:
                p = period_params[f"pos{i}"]
                c = period_cache[f"pos{i}"]
                ck, cv = attn.project_kv(p["cross"], enc)
                ckv_row = slotstate.take_row(c["cross_kv"], slot)
                ckv_row = attn.cache_write_chunk(
                    ckv_row, ck, cv, positions, valid[0],
                    kv_format=cfg.kv_format_for(i))
                entry["cross_kv"] = slotstate.put_row(
                    c["cross_kv"], ckv_row, slot)
            new_cross[f"pos{i}"] = entry
        return carry, new_cross

    layer_cache = {k: v for k, v in cache.items() if k.startswith("pos")}
    _, new_cross = jax.lax.scan(
        period_fn, 0.0, (params["layers"], layer_cache))
    out = dict(cache)
    for name, entry in new_cross.items():
        out[name] = {**cache[name], **entry}
    out["enc_out"] = jax.lax.dynamic_update_slice_in_dim(
        cache["enc_out"], enc.astype(cache["enc_out"].dtype), slot, 0)
    return out
