"""Mamba-2 SSD (state-space duality) block — mamba2-2.7b and the jamba
hybrid's mixer.

Chunked-parallel form (Dao & Gu, arXiv:2405.21060): the sequence is split
into chunks of length Q; within a chunk the SSM is computed as a masked
quadratic attention-like product (MXU-friendly), and chunk-final states are
propagated with a short sequential scan — O(s*Q) work for the diagonal
blocks plus O(s/Q) scan steps, instead of an O(s) elementwise recurrence.

The pure-jnp implementation here is the production XLA path *and* the
oracle for ``repro.kernels.ssd_scan`` (the Pallas twin).  Decode is the
O(1)-state recurrence — the reason mamba2/jamba run the ``long_500k`` cell
that full-attention archs must skip.

TP layout (DESIGN.md §6): projections are kept *separate* (wz/wx/wb/wc/wdt
and three depthwise convs — per-channel independent, so splitting the
fused conv is exactly equivalent) so the d_inner/head dims shard cleanly on
the 'model' axis while the head-shared B/C/state dim n stays replicated;
the SSD core then runs with zero collectives under TP.

Layout: x (b, s, h, p) heads x head_dim; B, C (b, s, n) with a single
group shared across heads (ngroups=1); A scalar per head.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import causal_conv1d, dense_init, rms_norm


# --------------------------------------------------------------------- #
# Parameters
# --------------------------------------------------------------------- #

def init_ssm(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    d, d_in, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k_conv = cfg.ssm_conv
    ks = jax.random.split(key, 10)
    # dt_bias ~ softplus^-1(dt), dt log-uniform in [1e-3, 1e-1]
    dt = jnp.exp(jnp.linspace(math.log(1e-3), math.log(1e-1), h))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "wz": dense_init(ks[0], (d, d_in), dtype, fan_in=d),
        "wx": dense_init(ks[1], (d, d_in), dtype, fan_in=d),
        "wb": dense_init(ks[2], (d, n), dtype, fan_in=d),
        "wc": dense_init(ks[3], (d, n), dtype, fan_in=d),
        "wdt": dense_init(ks[4], (d, h), dtype, fan_in=d),
        "conv_x_w": dense_init(ks[5], (d_in, k_conv), dtype, fan_in=k_conv),
        "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_b_w": dense_init(ks[6], (n, k_conv), dtype, fan_in=k_conv),
        "conv_b_b": jnp.zeros((n,), dtype),
        "conv_c_w": dense_init(ks[7], (n, k_conv), dtype, fan_in=k_conv),
        "conv_c_b": jnp.zeros((n,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "gate_norm": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[8], (d_in, d), dtype, fan_in=d_in),
    }


# --------------------------------------------------------------------- #
# Chunked SSD core (training / prefill)
# --------------------------------------------------------------------- #

def _segsum(a: jax.Array) -> jax.Array:
    """a (..., q) -> lower-triangular cumulative segment sums (..., q, q):
    out[i, j] = sum(a[j+1..i]) for i >= j, -inf above the diagonal."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x: jax.Array, dt_a: jax.Array, b: jax.Array, c: jax.Array,
                chunk: int,
                initial_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunk-parallel SSD.

    x:    (bt, s, h, p)  — already discretized (multiplied by dt)
    dt_a: (bt, s, h)     — per-step log decay (dt * A, negative)
    b, c: (bt, s, n)     — input/output projections (shared across heads)
    Returns (y (bt, s, h, p), final_state (bt, h, p, n)).  All maths fp32.
    """
    bt, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    nc = s // chunk
    x = x.astype(jnp.float32).reshape(bt, nc, chunk, h, p)
    a = dt_a.astype(jnp.float32).reshape(bt, nc, chunk, h)
    a = a.transpose(0, 3, 1, 2)                       # (bt,h,nc,q)
    bm = b.astype(jnp.float32).reshape(bt, nc, chunk, n)
    cm = c.astype(jnp.float32).reshape(bt, nc, chunk, n)

    a_cs = jnp.cumsum(a, axis=-1)                     # (bt,h,nc,q)
    # 1. intra-chunk (diagonal blocks): masked quadratic form
    el = jnp.exp(_segsum(a))                          # (bt,h,nc,q,q)
    scores = jnp.einsum("bcln,bcsn->bcls", cm, bm)    # (bt,nc,q,q)
    y_diag = jnp.einsum("bcls,bhcls,bcshp->bclhp", scores, el, x)
    # 2. chunk-final states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)     # (bt,h,nc,q)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bm, decay_states, x)
    # 3. inter-chunk recurrence (sequential scan over nc chunks)
    chunk_decay = jnp.exp(a_cs[..., -1])              # (bt,h,nc)
    h0 = (jnp.zeros((bt, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(h_prev, inp):
        s_c, d_c = inp                                # (bt,h,p,n),(bt,h)
        h_new = h_prev * d_c[..., None, None] + s_c
        return h_new, h_prev                          # emit entering state

    (final_state, prev_states) = jax.lax.scan(
        step, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (bt,nc,h,p,n)
    # 4. contribution of the entering state within each chunk
    state_decay = jnp.exp(a_cs)                       # (bt,h,nc,q)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cm, prev_states,
                       state_decay)
    y = (y_diag + y_off).reshape(bt, s, h, p)
    return y, final_state


def ssd_reference(x, dt_a, b, c, initial_state=None):
    """O(s) sequential recurrence — the oracle for ``ssd_chunked``."""
    bt, s, h, p = x.shape
    n = b.shape[-1]
    h0 = (jnp.zeros((bt, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(state, inp):
        x_t, a_t, b_t, c_t = inp                      # (bt,h,p),(bt,h),(bt,n)
        state = (state * jnp.exp(a_t)[..., None, None]
                 + x_t[..., None] * b_t[:, None, None, :])
        y_t = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y_t

    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          dt_a.astype(jnp.float32).transpose(1, 0, 2),
          b.astype(jnp.float32).transpose(1, 0, 2),
          c.astype(jnp.float32).transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), final


# --------------------------------------------------------------------- #
# Full block: proj -> conv -> SSD -> gated norm -> proj
# --------------------------------------------------------------------- #

def _discretize(p: dict, dt_raw: jax.Array):
    """dt = softplus(raw + bias); returns (dt, dt*A) in fp32."""
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])                          # (h,) negative
    return dt, dt * a


def _project(p: dict, x: jax.Array):
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xr = jnp.einsum("bsd,de->bse", x, p["wx"])
    br = jnp.einsum("bsd,dn->bsn", x, p["wb"])
    cr = jnp.einsum("bsd,dn->bsn", x, p["wc"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    return z, xr, br, cr, dt_raw


def ssm_forward(p: dict, x: jax.Array, cfg: ArchConfig,
                return_state: bool = False):
    """Full-sequence SSD block.  x: (bt, s, d_model) -> same shape."""
    bt, s, _ = x.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xr, br, cr, dt_raw = _project(p, x)
    xh = jax.nn.silu(causal_conv1d(xr, p["conv_x_w"], p["conv_x_b"]))
    b_ = jax.nn.silu(causal_conv1d(br, p["conv_b_w"], p["conv_b_b"]))
    c_ = jax.nn.silu(causal_conv1d(cr, p["conv_c_w"], p["conv_c_b"]))
    xh = xh.reshape(bt, s, h, pd)
    dt, dt_a = _discretize(p, dt_raw)
    chunk = min(cfg.ssm_chunk, s)
    x_disc, b_c, c_c = xh * dt[..., None], b_, c_
    pad = (-s) % chunk
    if pad:
        # identity-pad the tail: dt_a=0 => decay 1, x=0 => no input, so
        # outputs for real positions and the final state are exact.
        x_disc = jnp.pad(x_disc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_a = jnp.pad(dt_a, ((0, 0), (0, pad), (0, 0)))
        b_c = jnp.pad(b_c, ((0, 0), (0, pad), (0, 0)))
        c_c = jnp.pad(c_c, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_chunked(x_disc, dt_a, b_c, c_c, chunk)
    y = y[:, :s]
    y = y + p["D"][:, None] * xh.astype(jnp.float32)  # per-head skip
    y = y.reshape(bt, s, h * pd).astype(x.dtype)
    y = rms_norm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_state:
        k1 = cfg.ssm_conv - 1
        def tail(r):
            if s >= k1:
                return r[:, s - k1:, :]
            return jnp.pad(r, ((0, 0), (k1 - s, 0), (0, 0)))
        return out, {"conv_x": tail(xr).astype(x.dtype),
                     "conv_b": tail(br).astype(x.dtype),
                     "conv_c": tail(cr).astype(x.dtype),
                     "state": state}
    return out


def ssm_prefill_chunk(p: dict, x: jax.Array, cache: dict, cfg: ArchConfig,
                      valid: jax.Array, valid_len: jax.Array
                      ) -> Tuple[jax.Array, dict]:
    """One prompt *chunk* through the SSD block with state carried across
    chunk boundaries — the SSM leg of chunked pooled prefill.

    x: (bt, s, d_model) chunk activations (zero-padded past ``valid_len``);
    cache: this slot's ``{"conv_x", "conv_b", "conv_c", "state"}`` row
    (bt matches x); valid: (s,) bool prefix mask; valid_len: traced
    scalar int32.  Returns (out (bt, s, d_model), advanced cache row).

    Exactness: the depthwise convs run over ``[carried conv inputs | this
    chunk's raw inputs]`` and drop the first k-1 outputs, so every kept
    window lies entirely inside real inputs (the zero left-pad of
    ``causal_conv1d`` never reaches them); invalid tail positions are
    identity steps for the recurrence (decay 1, input 0), so the final
    state equals the full-sequence scan's state at ``valid_len`` exactly
    up to chunk-boundary float association (``ssd_chunked`` carries
    ``initial_state``).  Outputs at invalid positions are garbage and
    must not be read.
    """
    bt, s, _ = x.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    k1 = cfg.ssm_conv - 1
    z, xr, br, cr, dt_raw = _project(p, x)
    # Per-section carries concatenated along the SEQUENCE axis only.  The
    # old single-leaf layout concatenated [xr|br|cr] along channels; a
    # concatenate whose axis is sharded (d_inner rides the 'model' axis
    # under TP) miscompiles in XLA's SPMD partitioner on >2-device
    # meshes (wrong values, not a perf issue — see test_serve_sharded),
    # and sectioned carries are the layout TP wants anyway: conv_x
    # shards with wx/conv_x_w, the tiny B/C sections stay replicated.
    fx = jnp.concatenate([cache["conv_x"].astype(xr.dtype), xr], axis=1)
    fb = jnp.concatenate([cache["conv_b"].astype(br.dtype), br], axis=1)
    fc = jnp.concatenate([cache["conv_c"].astype(cr.dtype), cr], axis=1)
    xh = jax.nn.silu(causal_conv1d(fx, p["conv_x_w"], p["conv_x_b"])[:, k1:])
    b_ = jax.nn.silu(causal_conv1d(fb, p["conv_b_w"], p["conv_b_b"])[:, k1:])
    c_ = jax.nn.silu(causal_conv1d(fc, p["conv_c_w"], p["conv_c_b"])[:, k1:])
    xh = xh.reshape(bt, s, h, pd)
    dt, dt_a = _discretize(p, dt_raw)
    vm = valid[None, :]                                   # (1, s)
    # identity steps past valid_len: decay 1, input 0 — the state at the
    # chunk end is the state at valid_len
    x_disc = jnp.where(vm[..., None, None], xh * dt[..., None], 0.0)
    dt_a = jnp.where(vm[..., None], dt_a, 0.0)
    b_c = jnp.where(vm[..., None], b_, 0.0)
    c_c = c_
    chunk = min(cfg.ssm_chunk, s)
    pad = (-s) % chunk
    if pad:
        x_disc = jnp.pad(x_disc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_a = jnp.pad(dt_a, ((0, 0), (0, pad), (0, 0)))
        b_c = jnp.pad(b_c, ((0, 0), (0, pad), (0, 0)))
        c_c = jnp.pad(c_c, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_chunked(x_disc, dt_a, b_c, c_c, chunk,
                           initial_state=cache["state"])
    y = y[:, :s]
    y = y + p["D"][:, None] * xh.astype(jnp.float32)      # per-head skip
    y = y.reshape(bt, s, h * pd).astype(x.dtype)
    y = rms_norm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    # carried conv inputs: the k-1 raw rows ending at valid_len.  In the
    # ``[carry | raw]`` seq indexing the chunk's raw row j sits at
    # k1 + j, so rows [valid_len, valid_len + k1) are
    # raw[valid_len - k1 : valid_len] (reaching into the previous carry
    # when valid_len < k1) — a traced start with a static size.
    def carry(fs, old):
        sl = jax.lax.dynamic_slice_in_dim(fs, valid_len, k1, axis=1)
        return sl.astype(old.dtype)
    return out, {"conv_x": carry(fx, cache["conv_x"]),
                 "conv_b": carry(fb, cache["conv_b"]),
                 "conv_c": carry(fc, cache["conv_c"]),
                 "state": state}


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    """Sectioned depthwise-conv carry (one leaf per conv input stream —
    see the layout note in :func:`ssm_prefill_chunk`) + fp32 SSD state."""
    k1 = cfg.ssm_conv - 1
    return {
        "conv_x": jnp.zeros((batch, k1, cfg.d_inner), dtype),
        "conv_b": jnp.zeros((batch, k1, cfg.ssm_state), dtype),
        "conv_c": jnp.zeros((batch, k1, cfg.ssm_state), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
    }


def ssm_decode(p: dict, x: jax.Array, cache: dict, cfg: ArchConfig
               ) -> Tuple[jax.Array, dict]:
    """One-token recurrence.  x: (bt, 1, d_model)."""
    bt = x.shape[0]
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xr, br, cr, dt_raw = _project(p, x)
    # conv over (k-1) cached raw inputs + this one, per section — the
    # window concats run along the SEQUENCE axis, never across channel
    # sections (see the TP layout note in ssm_prefill_chunk)
    wx = jnp.concatenate([cache["conv_x"], xr], axis=1)      # (bt,k,d_in)
    wb_ = jnp.concatenate([cache["conv_b"], br], axis=1)     # (bt,k,n)
    wc_ = jnp.concatenate([cache["conv_c"], cr], axis=1)
    xh = jax.nn.silu(jnp.einsum("bkc,ck->bc", wx, p["conv_x_w"])
                     + p["conv_x_b"])[:, None, :]
    b_ = jax.nn.silu(jnp.einsum("bkc,ck->bc", wb_, p["conv_b_w"])
                     + p["conv_b_b"])[:, None, :]
    c_ = jax.nn.silu(jnp.einsum("bkc,ck->bc", wc_, p["conv_c_w"])
                     + p["conv_c_b"])[:, None, :]
    xh = xh.reshape(bt, 1, h, pd)
    dt, dt_a = _discretize(p, dt_raw)
    # state update: S <- S * exp(dt*A) + (dt*x) outer B
    xd = (xh * dt[..., None]).astype(jnp.float32)[:, 0]        # (bt,h,p)
    decay = jnp.exp(dt_a.astype(jnp.float32))[:, 0]            # (bt,h)
    state = (cache["state"] * decay[..., None, None]
             + xd[..., None] * b_.astype(jnp.float32)[:, 0, None, None, :])
    y = jnp.einsum("bhpn,bn->bhp", state, c_.astype(jnp.float32)[:, 0])
    y = y + p["D"][:, None] * xh.astype(jnp.float32)[:, 0]
    y = y.reshape(bt, 1, h * pd).astype(x.dtype)
    y = rms_norm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_cache = {"conv_x": wx[:, 1:, :], "conv_b": wb_[:, 1:, :],
                 "conv_c": wc_[:, 1:, :], "state": state}
    return out, new_cache


# --------------------------------------------------------------------- #
# Speculative verify / commit (draft-token verification — decode-exact)
# --------------------------------------------------------------------- #

def _conv_windows(f: jax.Array, s: int, k: int) -> jax.Array:
    """f (bt, k-1+s, c) -> per-position conv windows (bt, s, k, c):
    window j is rows [j, j+k) of ``[carry | raw]`` — exactly the window
    :func:`ssm_decode` sees at step j.  s and k are static."""
    return jnp.stack([f[:, j:j + k] for j in range(s)], axis=1)


def ssm_verify_chunk(p: dict, x: jax.Array, cache: dict, cfg: ArchConfig
                     ) -> Tuple[jax.Array, dict]:
    """Verify ``s`` drafted tokens through the SSD block in one batched
    pass, BIT-IDENTICAL to ``s`` successive :func:`ssm_decode` steps.

    x: (bt, s, d_model).  The cache row is read, never written: position
    j's output uses the state after j decode steps and the conv window
    ending at j, reproduced here with the decode step's literal ops — a
    sequential fp32 scan (not :func:`ssd_chunked`, whose chunk-boundary
    float association differs) and per-position windowed convolutions
    (not :func:`causal_conv1d`, whose zero left-pad differs from the
    carried window).  Returns (out (bt, s, d_model), info) where
    ``info`` carries everything :func:`ssm_commit_chunk` needs to
    advance the cache by an accepted prefix: the discretized inputs and
    the full ``[carry | raw]`` conv streams.
    """
    bt, s, _ = x.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    k = cfg.ssm_conv
    z, xr, br, cr, dt_raw = _project(p, x)
    fx = jnp.concatenate([cache["conv_x"], xr], axis=1)   # (bt, k-1+s, c)
    fb = jnp.concatenate([cache["conv_b"], br], axis=1)
    fc = jnp.concatenate([cache["conv_c"], cr], axis=1)
    xh = jax.nn.silu(jnp.einsum("bskc,ck->bsc", _conv_windows(fx, s, k),
                                p["conv_x_w"]) + p["conv_x_b"])
    b_ = jax.nn.silu(jnp.einsum("bskc,ck->bsc", _conv_windows(fb, s, k),
                                p["conv_b_w"]) + p["conv_b_b"])
    c_ = jax.nn.silu(jnp.einsum("bskc,ck->bsc", _conv_windows(fc, s, k),
                                p["conv_c_w"]) + p["conv_c_b"])
    xh = xh.reshape(bt, s, h, pd)
    dt, dt_a = _discretize(p, dt_raw)
    xd = (xh * dt[..., None]).astype(jnp.float32)         # (bt,s,h,p)
    dt_a = dt_a.astype(jnp.float32)

    def step(state, inp):
        xd_t, a_t, b_t, c_t = inp          # (bt,h,p),(bt,h),(bt,n),(bt,n)
        state = (state * jnp.exp(a_t)[..., None, None]
                 + xd_t[..., None] * b_t[:, None, None, :])
        y_t = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y_t

    _, ys = jax.lax.scan(
        step, cache["state"],
        (xd.transpose(1, 0, 2, 3), dt_a.transpose(1, 0, 2),
         b_.astype(jnp.float32).transpose(1, 0, 2),
         c_.astype(jnp.float32).transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3)                          # (bt,s,h,p)
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(bt, s, h * pd).astype(x.dtype)
    y = rms_norm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    info = {"xd": xd, "dt_a": dt_a, "b": b_,
            "fx": fx, "fb": fb, "fc": fc}
    return out, info


def ssm_commit_chunk(cache: dict, info: dict, e: jax.Array,
                     cfg: ArchConfig) -> dict:
    """Advance an SSM cache row by the first ``e`` verified positions.

    This is the rollback story for recurrent state: nothing speculative
    was ever written during verify, so "rollback" is simply committing
    only the accepted prefix — the state re-materializes from the last
    accepted checkpoint via ``ssd_chunked(initial_state=...)`` with
    rejected positions identity-masked (decay exp(0)=1, input 0), which
    reproduces ``e`` decode-step updates bit-exactly at chunk=1 (the
    inter-chunk scan performs the decode recurrence itself; the
    intra-chunk quadratic form is a single exact product).  e: (b,)
    int32 in [0, s]; e=0 rows advance by identity steps only.
    """
    bt, s = info["dt_a"].shape[:2]
    k1 = cfg.ssm_conv - 1
    ok = jnp.arange(s)[None, :] < e[:, None]              # (bt, s)
    xd = jnp.where(ok[..., None, None], info["xd"], 0.0)
    dt_a = jnp.where(ok[..., None], info["dt_a"], 0.0)
    b_ = jnp.where(ok[..., None], info["b"].astype(jnp.float32), 0.0)
    _, state = ssd_chunked(xd, dt_a, b_, jnp.zeros_like(b_), chunk=1,
                           initial_state=cache["state"])
    # conv carry: the k-1 raw rows ending at position e — in the
    # [carry | raw] indexing that is rows [e, e + k1), a per-row traced
    # start with a static size.
    def carry(f, old):
        sl = jax.vmap(
            lambda fr, er: jax.lax.dynamic_slice_in_dim(fr, er, k1, axis=0)
        )(f, e.astype(jnp.int32))
        return sl.astype(old.dtype)
    return {"conv_x": carry(info["fx"], cache["conv_x"]),
            "conv_b": carry(info["fb"], cache["conv_b"]),
            "conv_c": carry(info["fc"], cache["conv_c"]),
            "state": state}
