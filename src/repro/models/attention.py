"""Attention: GQA/MQA projections, flash-equivalent chunked softmax
(online-softmax ``lax.scan`` over KV blocks — the XLA-path twin of
``repro.kernels.flash_attention``), sliding windows, logit softcaps, and
ring-buffer KV caches for decode.

Memory behavior is the point: naive attention materializes the (sq, skv)
score matrix — 2 GiB/head at 32k — so every path here is O(sq * chunk).
Softmax statistics are always fp32 (paper §V precision discipline).

Decode at long context is bound by the KV-cache *read* (§VI.D: the KV
bytes, not the weights, dominate HBM traffic past a few k positions), so
the cache supports **quantized storage**: ``init_kv_cache(kv_format=...)``
holds K/V as fp8-container bytes or nibble-packed fp4/fp6 codes plus
1-byte e8m0 block scales along ``head_dim``, and the write paths
(:func:`cache_write_decode` / :func:`cache_write_prefill`) quantize on
the way in — trace-safe ``repro.lowbits`` arithmetic, since decode
writes happen inside a jitted step.  :func:`cache_kv` materializes the
dense view for the XLA oracle; ``repro.kernels.flash_decode`` streams
the packed bytes directly and expands them in VMEM.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat, lowbits
from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, dense_init
from repro.models.slotstate import mask_rows  # noqa: F401 — re-export;
# the per-slot write discipline lives in repro.models.slotstate now

_NEG_INF = -1.0e30

# Leaf names of a *quantized* ring cache (packed codes + 1-byte e8m0
# scales — see :func:`init_kv_cache`).  Single source of truth shared
# with ``repro.distributed.sharding.cache_rule`` so the mesh placement
# rules cannot drift from the cache layout: payload leaves carry
# (batch, capacity, heads, stored) like dense k/v, and the last dim is
# packed storage (never shardable — sub-byte groups are device-local).
QUANT_KV_LEAVES = ("k_q", "k_s", "v_q", "v_s")


# --------------------------------------------------------------------- #
# Projections
# --------------------------------------------------------------------- #

def init_attention(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads, cfg.head_dim), dtype,
                         fan_in=d),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, cfg.head_dim), dtype,
                         fan_in=d),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, cfg.head_dim), dtype,
                         fan_in=d),
        "wo": dense_init(ks[3], (cfg.n_heads, cfg.head_dim, d), dtype,
                         fan_in=cfg.n_heads * cfg.head_dim),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, cfg.head_dim), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, cfg.head_dim), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, cfg.head_dim), dtype)
    return p


def project_q(p: dict, x: jax.Array) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    return q


def project_kv(p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def project_out(p: dict, o: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# --------------------------------------------------------------------- #
# Core softmax-attention maths (grouped-query layout)
# --------------------------------------------------------------------- #

def _group(q: jax.Array, n_kv: int) -> jax.Array:
    """(b, s, hq, d) -> (b, s, n_kv, group, d)."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def _scores(q: jax.Array, k: jax.Array, scale: float,
            softcap: Optional[float]) -> jax.Array:
    """q (b,sq,h,g,d) x k (b,sk,h,d) -> fp32 logits (b,h,g,sq,sk).

    Operands stay at their native dtype (bf16 activations feed the MXU
    directly); only the ACCUMULATION is forced fp32.  Explicitly casting
    inputs to fp32 adds no information for bf16-valued activations but
    doubles HBM operand traffic and halves MXU rate (§Perf iteration)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    return s


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: Optional[int]) -> jax.Array:
    """Additive fp32 bias (sq, sk): 0 where visible, -inf-ish elsewhere."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    return jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32)


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: Optional[int] = None,
                   softcap: Optional[float] = None,
                   scale: Optional[float] = None,
                   q_positions: Optional[jax.Array] = None,
                   k_positions: Optional[jax.Array] = None,
                   k_valid: Optional[jax.Array] = None) -> jax.Array:
    """Reference O(sq*sk)-memory attention (oracle + short-seq path).

    q: (b, sq, hq, d); k, v: (b, sk, hkv, d).  Returns (b, sq, hq, d).
    ``k_valid`` (b, sk) bool masks per-row key padding (pooled encoder
    batches pad frames to a fixed enc_len).
    """
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = _group(q, hkv)
    s = _scores(qg, k, scale, softcap)
    q_pos = jnp.arange(sq) if q_positions is None else q_positions
    k_pos = jnp.arange(sk) if k_positions is None else k_positions
    s = s + _mask_bias(q_pos, k_pos, causal, window)
    if k_valid is not None:
        s = jnp.where(k_valid[:, None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, sq, hq, d).astype(q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      scale: Optional[float] = None,
                      chunk: int = 1024,
                      k_valid: Optional[jax.Array] = None) -> jax.Array:
    """Flash-equivalent attention: ``lax.scan`` over KV chunks with online
    softmax.  O(sq * chunk) live memory instead of O(sq * sk).

    Matches :func:`full_attention` to fp32-accumulation tolerance for any
    chunk size (property-tested).  This is the production XLA path; the
    Pallas twin (``repro.kernels.flash_attention``) additionally tiles sq
    and pins operands in VMEM on real TPUs.
    """
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    if sk % chunk != 0:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if k_valid is not None:
            k_valid = jnp.pad(k_valid, ((0, 0), (0, pad)))
        sk_pad = sk + pad
    else:
        sk_pad = sk
    if k_valid is None:
        k_valid = jnp.ones((b, sk_pad), bool)
    n_chunks = sk_pad // chunk
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    g = hq // hkv
    qg = _group(q, hkv)                               # (b,sq,h,g,d)
    q_pos = jnp.arange(sq)

    kc = k.reshape(b, n_chunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    kvc = k_valid.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def step(carry, inputs):
        m, l, acc = carry
        ci, k_i, v_i, kv_i = inputs
        k_pos = ci * chunk + jnp.arange(chunk)
        s = _scores(qg, k_i, scale, softcap)          # (b,h,g,sq,chunk)
        valid = k_pos < sk                            # mask padding
        bias = _mask_bias(q_pos, k_pos, causal, window)
        bias = jnp.where(valid[None, :], bias, _NEG_INF)
        s = s + bias
        s = jnp.where(kv_i[:, None, None, None, :], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_i.dtype),
                                v_i, preferred_element_type=jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc, kvc))
    l = jnp.where(l == 0.0, 1.0, l)
    o = (acc / l[..., None]).transpose(0, 3, 1, 2, 4)  # (b,sq,h,g,d)
    return o.reshape(b, sq, hq, d).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=None, softcap=None,
              scale=None, chunk: int = 1024, k_valid=None):
    """Dispatch: chunked when the KV axis is long enough to matter."""
    if k.shape[1] <= chunk:
        return full_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale, k_valid=k_valid)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, chunk=chunk,
                             k_valid=k_valid)


# --------------------------------------------------------------------- #
# Decode (single new token against a — possibly ring — KV cache)
# --------------------------------------------------------------------- #

def cache_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                    slot_pos: jax.Array, q_positions: jax.Array, *,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None) -> jax.Array:
    """Attention of ``sq`` query tokens against a (ring) cache.

    q: (b, sq, hq, d); k_cache/v_cache: (b, S, hkv, d);
    slot_pos: (b, S) int32 — absolute position held by each slot, -1 empty;
    q_positions: (b, sq) int32 absolute position of each query token.

    Masking is entirely position-computed (``slot_pos <= q_pos``), so it
    covers both decode (sq=1 attending over history) and chunked prefill
    (sq=chunk attending over history *and* itself causally — a chunk
    token sees earlier chunk tokens because their slots were written
    before this call with smaller absolute positions).
    """
    b, sq, hq, d = q.shape
    hkv = k_cache.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = _group(q, hkv)
    s = _scores(qg, k_cache, scale, softcap)          # (b,h,g,sq,S)
    sp = slot_pos[:, None, :]                         # (b, 1, S)
    qp = q_positions[:, :, None]                      # (b, sq, 1)
    ok = (sp >= 0) & (sp <= qp)                       # (b, sq, S)
    if window is not None:
        ok &= sp > qp - window
    s = jnp.where(ok[:, None, None, :, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, sq, hq, d).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     slot_pos: jax.Array, pos: jax.Array, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     scale: Optional[float] = None) -> jax.Array:
    """One-token attention against a cache (sq=1 :func:`cache_attention`).

    q: (b, 1, hq, d); pos: (b,) per-row current position (continuous
    batching: rows advance independently).  Ring buffers wrap slot_pos.
    """
    return cache_attention(q, k_cache, v_cache, slot_pos, pos[:, None],
                           window=window, softcap=softcap, scale=scale)


# --------------------------------------------------------------------- #
# KV-cache plumbing (capacity = window for local layers — the ring buffer
# is what makes gemma2 long_500k viable: 13 local layers hold 4k slots
# instead of 500k)
# --------------------------------------------------------------------- #

def cache_capacity(max_seq: int, window: Optional[int]) -> int:
    return min(max_seq, window) if window else max_seq


def kv_scale_block(head_dim: int) -> int:
    """Scale-block size along head_dim: the mxfp BLOCK (32) when it
    divides, else the largest power-of-two divisor (reduced smoke
    configs run head_dim 16)."""
    for blk in (32, 16, 8, 4, 2, 1):
        if head_dim % blk == 0:
            return blk
    return 1


def quantize_kv(x: jax.Array, kv_format: str) -> Tuple[jax.Array, jax.Array]:
    """Quantize (..., d) activations into KV-cache storage form.

    Returns (stored, scale_codes):
      * fp8: ``stored`` (..., d) in the registry container dtype,
      * fp4/fp6: ``stored`` (..., d*bits/8) uint8 nibble/3-byte-group
        packed codes (``lowbits.encode_codes`` + ``pack_codes``),
      * ``scale_codes`` (..., d/kv_scale_block(d)) uint8 e8m0 exponents.

    Pure trace-safe arithmetic throughout — this runs inside the jitted
    decode step on every token.
    """
    spec = compat.dtype_spec(kv_format)
    *lead, d = x.shape
    blk = kv_scale_block(d)
    xb = x.astype(jnp.float32).reshape(*lead, d // blk, blk)
    s_codes = lowbits.e8m0_scale_code(jnp.max(jnp.abs(xb), axis=-1),
                                      spec.max_finite)
    vals = xb / lowbits.e8m0_decode(s_codes)[..., None]
    vals = vals.reshape(*lead, d)
    if spec.packed is not None:
        if d % spec.packed.values_per_group:
            raise ValueError(
                f"head_dim {d} not a multiple of {kv_format}'s pack "
                f"group ({spec.packed.values_per_group})")
        stored = lowbits.pack_codes(
            lowbits.encode_codes(vals, kv_format), kv_format)
    else:
        stored = vals.astype(spec.container)
    return stored, s_codes


def dequantize_kv(stored: jax.Array, scale_codes: jax.Array,
                  kv_format: str, head_dim: int,
                  out_dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_kv`: (..., stored) + scale codes ->
    (..., head_dim) dense values.  Same arithmetic the Pallas
    flash-decode leg applies per VMEM tile."""
    spec = compat.dtype_spec(kv_format)
    if spec.packed is not None:
        vals = lowbits.decode(
            lowbits.unpack_codes(stored, kv_format), kv_format)
    else:
        vals = stored.astype(jnp.float32)
    *lead, d = vals.shape
    blk = kv_scale_block(head_dim)
    scales = lowbits.e8m0_decode(scale_codes)
    out = (vals.reshape(*lead, d // blk, blk) * scales[..., None])
    return out.reshape(*lead, d).astype(out_dtype)


def init_kv_cache(batch: int, capacity: int, n_kv: int, head_dim: int,
                  dtype, kv_format: Optional[str] = None) -> dict:
    """Ring-cache pytree.  Dense layout (kv_format None): full-width
    ``k``/``v`` at ``dtype``.  Quantized layout: ``k_q``/``v_q`` stored
    codes + ``k_s``/``v_s`` 1-byte e8m0 scales (see :func:`quantize_kv`);
    fp4 lands at 0.5 + 1/32 ≈ 0.53 B/elem vs 2 B/elem bf16."""
    if kv_format is None:
        return {
            "k": jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
            "v": jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
            "slot_pos": jnp.full((batch, capacity), -1, jnp.int32),
        }
    spec = compat.dtype_spec(kv_format)
    if spec.packed is not None:
        ps = spec.packed
        stored_d = head_dim // ps.values_per_group * ps.bytes_per_group
        stored_dtype = jnp.uint8
    else:
        stored_d = head_dim
        stored_dtype = spec.container
    n_blk = head_dim // kv_scale_block(head_dim)
    z = jnp.zeros((batch, capacity, n_kv, stored_d), stored_dtype)
    s = jnp.zeros((batch, capacity, n_kv, n_blk), jnp.uint8)
    return {"k_q": z, "k_s": s, "v_q": z, "v_s": s,
            "slot_pos": jnp.full((batch, capacity), -1, jnp.int32)}


def is_quantized_cache(cache: dict) -> bool:
    return "k_q" in cache


def cache_kv(cache: dict, kv_format: Optional[str], head_dim: int,
             out_dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """Dense (k, v) view of a cache, dequantizing if stored quantized.

    The XLA decode path materializes this per step (the oracle); the
    Pallas kernel leg (``repro.kernels.flash_decode_quant``) reads the
    packed arrays directly instead."""
    if not is_quantized_cache(cache):
        return cache["k"], cache["v"]
    assert kv_format is not None, "quantized cache needs its kv_format"
    k = dequantize_kv(cache["k_q"], cache["k_s"], kv_format, head_dim,
                      out_dtype)
    v = dequantize_kv(cache["v_q"], cache["v_s"], kv_format, head_dim,
                      out_dtype)
    return k, v


def cache_write_decode(cache: dict, k: jax.Array, v: jax.Array,
                       pos: jax.Array,
                       kv_format: Optional[str] = None,
                       active: Optional[jax.Array] = None) -> dict:
    """Write one (b, 1, hkv, d) k/v at per-row slot ``pos % capacity``.

    pos: (b,) — rows may sit at different positions (continuous batching),
    so the write is a per-row scatter (one distinct slot per row).
    Quantized caches encode on the way in (trace-safe).

    active: optional (b,) bool — rows where False keep their previous
    slot contents and ``slot_pos`` untouched (inactive pool slots inside
    the fused decode loop must not write; their incoming k/v is garbage
    from a held-constant last_token)."""
    sp_arr = cache["slot_pos"]
    b, cap = sp_arr.shape
    slot = (pos % cap).astype(jnp.int32)
    rows = jnp.arange(b)
    sp = sp_arr.at[rows, slot].set(
        mask_rows(active, pos.astype(jnp.int32), sp_arr[rows, slot]))

    def put(pool, new):
        return pool.at[rows, slot].set(
            mask_rows(active, new, pool[rows, slot]))

    if is_quantized_cache(cache):
        assert kv_format is not None, "quantized cache needs its kv_format"
        k_q, k_s = quantize_kv(k[:, 0], kv_format)
        v_q, v_s = quantize_kv(v[:, 0], kv_format)
        return {"k_q": put(cache["k_q"], k_q), "k_s": put(cache["k_s"], k_s),
                "v_q": put(cache["v_q"], v_q), "v_s": put(cache["v_s"], v_s),
                "slot_pos": sp}
    return {"k": put(cache["k"], k[:, 0].astype(cache["k"].dtype)),
            "v": put(cache["v"], v[:, 0].astype(cache["v"].dtype)),
            "slot_pos": sp}


def cache_write_chunk(cache: dict, k: jax.Array, v: jax.Array,
                      positions: jax.Array,
                      valid: Optional[jax.Array] = None,
                      kv_format: Optional[str] = None) -> dict:
    """Bulk-write a prompt *chunk* (b, s, hkv, d) at absolute
    ``positions`` (s,) into the (ring) cache — the chunked-prefill write.

    Unlike :func:`cache_write_prefill` this does not assume the cache
    starts empty or that positions begin at 0: ``positions`` may start at
    any offset (traced — one compiled executable serves every chunk of
    every prompt) and earlier cache contents outside the chunk survive.
    ``valid`` masks the padded tail of the last chunk (masked positions
    keep their previous contents and slot_pos).  Positions must map to
    distinct ring slots, i.e. s <= capacity (the engine clamps its chunk
    size to the smallest layer capacity).  Quantized caches encode on
    the way in — quantize-on-write, inside the jitted chunk step.
    """
    cap = cache["slot_pos"].shape[1]
    b, s = k.shape[0], k.shape[1]
    slots = (positions % cap).astype(jnp.int32)
    sp_new = jnp.broadcast_to(positions.astype(jnp.int32), (b, s))
    vmask = None if valid is None else jnp.broadcast_to(valid, (b, s))
    sp = cache["slot_pos"].at[:, slots].set(
        mask_rows(vmask, sp_new, cache["slot_pos"][:, slots]))

    def put(pool, new):
        return pool.at[:, slots].set(
            mask_rows(vmask, new, pool[:, slots]))

    if is_quantized_cache(cache):
        assert kv_format is not None, "quantized cache needs its kv_format"
        k_q, k_s = quantize_kv(k, kv_format)
        v_q, v_s = quantize_kv(v, kv_format)
        return {"k_q": put(cache["k_q"], k_q), "k_s": put(cache["k_s"], k_s),
                "v_q": put(cache["v_q"], v_q), "v_s": put(cache["v_s"], v_s),
                "slot_pos": sp}
    return {"k": put(cache["k"], k.astype(cache["k"].dtype)),
            "v": put(cache["v"], v.astype(cache["v"].dtype)),
            "slot_pos": sp}


def cache_write_rows(cache: dict, k: jax.Array, v: jax.Array,
                     positions: jax.Array,
                     valid: Optional[jax.Array] = None,
                     kv_format: Optional[str] = None) -> dict:
    """Bulk-write (b, s, hkv, d) k/v at PER-ROW absolute ``positions``
    (b, s) into the (ring) cache — the speculative-commit write.

    This is :func:`cache_write_chunk` generalized to per-row positions:
    under continuous batching each slot sits at a different absolute
    position, so committing an accepted speculative prefix is a per-row
    scatter at ``positions % capacity``.  ``valid`` (b, s) masks rejected
    draft tails and inactive rows (masked entries keep their previous
    contents and slot_pos).  Per row, positions must map to distinct
    ring slots (s <= capacity).  Quantized caches encode on the way in.
    """
    sp_arr = cache["slot_pos"]
    b, cap = sp_arr.shape
    s = k.shape[1]
    rows = jnp.arange(b)[:, None]                     # (b, 1)
    slots = (positions % cap).astype(jnp.int32)       # (b, s)
    sp = sp_arr.at[rows, slots].set(
        mask_rows(valid, positions.astype(jnp.int32), sp_arr[rows, slots]))

    def put(pool, new):
        return pool.at[rows, slots].set(
            mask_rows(valid, new, pool[rows, slots]))

    if is_quantized_cache(cache):
        assert kv_format is not None, "quantized cache needs its kv_format"
        k_q, k_s = quantize_kv(k, kv_format)
        v_q, v_s = quantize_kv(v, kv_format)
        return {"k_q": put(cache["k_q"], k_q), "k_s": put(cache["k_s"], k_s),
                "v_q": put(cache["v_q"], v_q), "v_s": put(cache["v_s"], v_s),
                "slot_pos": sp}
    return {"k": put(cache["k"], k.astype(cache["k"].dtype)),
            "v": put(cache["v"], v.astype(cache["v"].dtype)),
            "slot_pos": sp}


def cache_rollback(cache: dict, positions: jax.Array,
                   reject: jax.Array) -> dict:
    """Invalidate rejected speculative writes: a pointer move, no payload
    traffic.

    positions: (b, s) absolute positions that were speculatively written;
    reject: (b, s) bool — True where the write must be undone.  A slot is
    cleared (slot_pos -> -1) only when it STILL holds the rejected
    position (``slot_pos[row, p % cap] == p``) — a slot already
    overwritten by a later accepted position, or never written (inactive
    row), is left alone.  Payload leaves are untouched: a -1 slot_pos
    makes the entry invisible to the position-computed mask in
    :func:`cache_attention`, and the next write at that slot replaces the
    bytes.  Accepts period-stacked caches too (slot_pos (n_p, b, cap))."""
    sp = cache["slot_pos"]
    slots = (positions % sp.shape[-1]).astype(jnp.int32)   # (b, s)
    rows = jnp.arange(positions.shape[0])[:, None]         # (b, 1)
    if sp.ndim == 2:
        cur = sp[rows, slots]                              # (b, s)
        hit = reject & (cur == positions)
        sp = sp.at[rows, slots].set(jnp.where(hit, -1, cur))
    else:
        cur = sp[:, rows, slots]                           # (n_p, b, s)
        hit = reject[None] & (cur == positions[None])
        sp = sp.at[:, rows, slots].set(jnp.where(hit, -1, cur))
    return dict(cache, slot_pos=sp)


def cache_write_prefill(cache: dict, k: jax.Array, v: jax.Array,
                        kv_format: Optional[str] = None) -> dict:
    """Bulk-write a prefill's K/V (b, s, hkv, d) into the (ring) cache.

    Keeps the last ``capacity`` positions; their slots ``p % capacity`` are
    distinct, so the scatter is a permutation (well-defined).  Quantized
    caches encode the kept span on the way in.
    """
    cap = cache["slot_pos"].shape[1]
    s = k.shape[1]
    take = min(s, cap)
    positions = jnp.arange(s - take, s, dtype=jnp.int32)
    slots = positions % cap
    sp = cache["slot_pos"].at[:, slots].set(
        jnp.broadcast_to(positions, (k.shape[0], take)))
    k_t, v_t = k[:, s - take:], v[:, s - take:]
    if is_quantized_cache(cache):
        assert kv_format is not None, "quantized cache needs its kv_format"
        k_q, k_s = quantize_kv(k_t, kv_format)
        v_q, v_s = quantize_kv(v_t, kv_format)
        return {"k_q": cache["k_q"].at[:, slots].set(k_q),
                "k_s": cache["k_s"].at[:, slots].set(k_s),
                "v_q": cache["v_q"].at[:, slots].set(v_q),
                "v_s": cache["v_s"].at[:, slots].set(v_s),
                "slot_pos": sp}
    k_new = cache["k"].at[:, slots].set(k_t.astype(cache["k"].dtype))
    v_new = cache["v"].at[:, slots].set(v_t.astype(cache["v"].dtype))
    return {"k": k_new, "v": v_new, "slot_pos": sp}
