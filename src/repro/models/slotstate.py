"""Per-slot decode-state protocol — ONE discipline for every cache part.

The serving cache is a *slot-state tree*: a dict of ``pos{i}`` layer
entries (leaves stacked over the period axis) plus optional top-level
arrays (``enc_out``).  Every part a mixer can own — pooled ring KV,
cross-attention KV, SSM conv/state, encoder output — is addressed by a
pool slot index and obeys the same three-rule protocol, which is what
lets one fused decode loop and one chunked-prefill executable serve all
arch families (attn-only, SSM, hybrid, enc-dec, VLM) without per-mixer
special cases:

1. **Slot addressing.**  Outside the period scan a layer leaf is
   ``(n_periods, batch, ...)`` — the slot axis is 1; a bare top-level
   array (``enc_out``) carries the slot on axis 0.  *Inside* the period
   scan (``lax.scan`` over the period axis) the slot axis is 0, and
   :func:`take_row` / :func:`put_row` move one slot's row in and out
   with ``slot`` traced, so one executable serves every slot.

2. **Eviction** (:func:`clear_slot`) is uniform: parts with ring
   bookkeeping (a ``slot_pos`` leaf — self-attn KV *and* cross-attn KV)
   mark the slot's ring empty (``slot_pos = -1``; payload bytes stay,
   position masking makes them unreachable), every other part zeroes
   the slot row (SSM conv/state, enc_out — zero IS their empty state).

3. **Decode-step advancement** (:func:`decode_advance`) is driven by a
   single ``active`` predicate: ring KV is masked *at the write site*
   (``cache_write_decode(active=...)`` touches O(1) rows, not
   O(capacity)); read-only parts (``cross_kv``, ``enc_out`` — written
   once at admission) pass through untouched; every recurrent part
   (SSM conv/state) row-selects new-vs-old via :func:`mask_rows`.

Nothing here imports the mixers — attention/ssm/transformer import
*this* module, so the protocol stays the bottom of the model stack.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

# Parts whose writes happen inside the mixer's cache-write primitive
# (already masked by ``active`` there) — decode_advance passes them
# through as-written.
WRITE_SITE_MASKED = ("kv",)

# The engine's device-resident per-slot bookkeeping leaves (one (batch,)
# array each — see ``ServeEngine._init_state``).  Named here, at the
# bottom of the model stack, so the mesh placement rules
# (``repro.distributed.sharding.state_specs``) and the engine agree on
# what the slot-state protocol owns.  The ``spec_*`` leaves exist only
# on speculative engines (``ServeEngine(spec=...)``): a per-slot token
# history ring + n-gram hash table that drive self-speculative drafting
# (``repro.serve.spec``), plus device-side acceptance accounting — the
# history/table rows are 2-D (batch, width) but obey the same replicated
# placement as the scalar bookkeeping.
SLOT_STATE_FIELDS = ("pos", "remaining", "last_token", "active", "seed",
                     "fault_pos", "fault_kind",
                     "spec_hist", "spec_ngram", "spec_accept",
                     "spec_blocks")

# Parts written once at admission and only *read* during decode.
READ_ONLY_IN_DECODE = ("cross_kv", "enc_out")


def mask_rows(mask: Optional[jax.Array], new: jax.Array,
              old: jax.Array) -> jax.Array:
    """Select ``new`` where ``mask`` (leading-dims bool) else ``old``."""
    if mask is None:
        return new
    m = mask.reshape(mask.shape + (1,) * (new.ndim - mask.ndim))
    return jnp.where(m, new, old)


def masked_tree(mask: Optional[jax.Array], new: Any, old: Any) -> Any:
    """:func:`mask_rows` over every leaf of a part tree."""
    if mask is None:
        return new
    return jax.tree.map(lambda n, o: mask_rows(mask, n, o), new, old)


def decode_advance(active: Optional[jax.Array], part: str,
                   new: Any, old: Any) -> Any:
    """Advance one cache part after a decode step under the protocol
    (rule 3 above).  ``active``: (b,) bool or None (all rows live)."""
    if part in WRITE_SITE_MASKED:
        return new
    if part in READ_ONLY_IN_DECODE:
        return old
    return masked_tree(active, new, old)


def take_row(tree: Any, slot: jax.Array) -> Any:
    """Slice one slot's row (kept as a size-1 axis) out of every leaf of
    a part tree *inside* the period scan (slot axis 0, ``slot`` traced)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, 0), tree)


def put_row(pool: Any, row: Any, slot: jax.Array) -> Any:
    """Inverse of :func:`take_row`: write the size-1 row back."""
    return jax.tree.map(
        lambda p, r: jax.lax.dynamic_update_slice_in_dim(p, r, slot, 0),
        pool, row)


def clear_slot(cache: dict, slot: jax.Array) -> dict:
    """Evict pool row ``slot`` from the whole slot-state tree (rule 2).

    Runs jitted with ``slot`` traced — one executable serves every slot.
    Ring parts are O(capacity) bookkeeping (slot_pos only); recurrent
    parts are an O(row) zero."""
    out: dict = {}
    for name, entry in cache.items():
        if not isinstance(entry, dict):
            # bare top-level array (enc_out): slot on axis 0
            out[name] = entry.at[slot].set(jnp.zeros_like(entry[0]))
            continue
        e: dict = {}
        for part, tree in entry.items():
            if isinstance(tree, dict) and "slot_pos" in tree:
                # ring part (self- or cross-attn KV): empty = slot_pos -1
                e[part] = dict(
                    tree, slot_pos=tree["slot_pos"].at[:, slot].set(-1))
            else:
                # recurrent part: zero IS the empty state
                e[part] = jax.tree.map(
                    lambda a: a.at[:, slot].set(jnp.zeros_like(a[:, 0])),
                    tree)
        out[name] = e
    return out
