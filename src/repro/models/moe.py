"""Mixture-of-Experts FFN — GShard/Switch-style capacity dispatch, TPU-native.

Design notes (these ARE the perf decisions; see DESIGN.md §6 and the
roofline hillclimb in EXPERIMENTS.md §Perf):

* Tokens are routed within fixed-size *subgroups* (default 512) so the
  dispatch/combine einsums stay matmul-shaped for the MXU and the one-hot
  tensors stay O(t_g^2 * k) per group — independent of the expert count.
  Dispatch-FLOPs overhead vs expert compute = 2*t_g*cf / (6*d_ff) ~ 10%
  at t_g=512, d_ff=2048.
* Expert weights (E, d, f) carry E on the 'model' mesh axis (EP) and are
  additionally FSDP-sharded for the >=400B archs; XLA's SPMD partitioner
  inserts the token all-to-all implied by the dispatch einsum.
* Capacity factor 1.25 with top-k renormalized gates; dropped tokens fall
  through the residual (standard Switch behavior).
* Aux losses: load-balance (Switch eq. 4) + router z-loss.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, init_mlp, apply_mlp

MOE_SUBGROUP = 512


def init_moe(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.moe_num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32, fan_in=d),
        "w1": dense_init(ks[1], (e, d, f), dtype, fan_in=d),
        "w2": dense_init(ks[2], (e, f, d), dtype, fan_in=f),
    }
    if cfg.mlp_variant in ("swiglu", "geglu"):
        p["w3"] = dense_init(ks[3], (e, d, f), dtype, fan_in=d)
    if cfg.moe_shared_expert:
        p["shared"] = init_mlp(ks[4], d, f, cfg.mlp_variant, dtype)
    return p


def _capacity(t_g: int, e: int, k: int, cf: float) -> int:
    return max(1, int(math.ceil(t_g * k * cf / e)))


def _expert_ffn(p: dict, x: jax.Array, variant: str) -> jax.Array:
    """x (g, e, c, d) through per-expert MLP weights (e, d, f)."""
    h = jnp.einsum("gecd,edf->gecf", x, p["w1"])
    if variant == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", x, p["w3"])
    elif variant == "geglu":
        h = jax.nn.gelu(h, approximate=True) \
            * jnp.einsum("gecd,edf->gecf", x, p["w3"])
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("gecf,efd->gecd", h, p["w2"])


def apply_moe(p: dict, x: jax.Array, cfg: ArchConfig,
              subgroup: int = MOE_SUBGROUP
              ) -> Tuple[jax.Array, dict]:
    """MoE FFN.  x: (b, s, d) -> (y, aux) with aux = {lb_loss, z_loss,
    dropped_frac-ish stats}."""
    b, s, d = x.shape
    e, k, cf = cfg.moe_num_experts, cfg.moe_top_k, cfg.moe_capacity_factor
    t_g = min(subgroup, s)
    assert s % t_g == 0, f"seq {s} not divisible by subgroup {t_g}"
    g = b * (s // t_g)
    xg = x.reshape(g, t_g, d)

    # --- routing (fp32) ---
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)               # (g, t, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # --- position-in-expert via cumsum over the (t*k) flat priority ---
    c = _capacity(t_g, e, k, cf)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # (g, t, k, e)
    flat = onehot.reshape(g, t_g * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                # (g, t*k, e)
    pos = jnp.sum(pos * flat, axis=-1).reshape(g, t_g, k)
    keep = (pos < c)
    gate = gate * keep.astype(gate.dtype)

    # --- dispatch / combine one-hots (bf16 matmul operands) ---
    oh_e = onehot.astype(x.dtype)                     # (g,t,k,e)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, c), c, dtype=x.dtype)
    dispatch = jnp.einsum("gtke,gtkc->gtec", oh_e, oh_c)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", oh_e, oh_c,
                         gate.astype(x.dtype))

    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
    expert_out = _expert_ffn(p, expert_in, cfg.mlp_variant)
    y = jnp.einsum("gtec,gecd->gtd", combine, expert_out)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], xg, cfg.mlp_variant)

    # --- aux losses (Switch eq.4 load balance + z-loss) ---
    density = jnp.mean(onehot.astype(jnp.float32)[:, :, 0, :], axis=1)
    prob_mean = jnp.mean(probs, axis=1)               # (g, e)
    lb_loss = e * jnp.mean(jnp.sum(density * prob_mean, axis=-1))
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_dropped": dropped}
    return y.reshape(b, s, d), aux
