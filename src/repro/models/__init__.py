"""Model substrate: layer library + 10-architecture assembly."""

from repro.models.model import (  # noqa: F401
    Model,
    batch_fields,
    batch_spec,
    build_model,
    decode_inputs_spec,
    make_batch,
)
from repro.models.transformer import kv_cache_stats  # noqa: F401
