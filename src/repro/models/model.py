"""Unified model API over the 10 architecture families.

``build_model(cfg)`` returns a :class:`Model` whose methods close over the
config; batches are plain dicts.  ``make_batch`` produces real (smoke-test)
arrays; ``batch_spec`` produces ``ShapeDtypeStruct`` stand-ins for the
multi-pod dry-run (no allocation — the shannon/kernels pattern).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.transformer import (
    VLM_PATCHES, clear_slot, init_cache, init_lm, kv_cache_stats,
    lm_commit_chunk, lm_decode_step, lm_encode_slot, lm_features,
    lm_forward, lm_prefill, lm_prefill_chunk, lm_rollback_chunk,
    lm_verify_chunk, min_cache_capacity, supports_chunked_prefill,
    unembed_weight)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- parameters -------------------------------------------------- #
    def init(self, key: jax.Array) -> dict:
        return init_lm(key, self.cfg)

    # -- execution modes --------------------------------------------- #
    def forward(self, params: dict, batch: Dict[str, jax.Array]):
        return lm_forward(params, batch, self.cfg)

    def features(self, params: dict, batch: Dict[str, jax.Array]):
        return lm_features(params, batch, self.cfg)

    def unembed_weight(self, params: dict):
        return unembed_weight(params, self.cfg)

    def prefill(self, params: dict, batch: Dict[str, jax.Array],
                max_seq: int):
        return lm_prefill(params, batch, self.cfg, max_seq)

    def decode_step(self, params: dict, cache: dict, token: jax.Array,
                    pos: jax.Array, active: Optional[jax.Array] = None):
        return lm_decode_step(params, cache, token, pos, self.cfg,
                              active=active)

    # -- serving hot-path API (fused loop / chunked pooled prefill) --- #
    def prefill_chunk(self, params: dict, cache: dict, tokens: jax.Array,
                      slot: jax.Array, pos_offset: jax.Array,
                      valid_len: jax.Array,
                      embeds: Optional[jax.Array] = None):
        return lm_prefill_chunk(params, cache, tokens, slot, pos_offset,
                                valid_len, self.cfg, embeds=embeds)

    def encode_slot(self, params: dict, cache: dict, frames: jax.Array,
                    slot: jax.Array, src_len: jax.Array) -> dict:
        """Encode one request's frames into slot-resident enc_out +
        cross-KV (see ``repro.models.transformer.lm_encode_slot``)."""
        return lm_encode_slot(params, cache, frames, slot, src_len,
                              self.cfg)

    def clear_slot(self, cache: dict, slot: jax.Array) -> dict:
        return clear_slot(cache, slot)

    # -- speculative decoding (verify / commit / rollback) ------------ #
    def verify_chunk(self, params: dict, cache: dict, tokens: jax.Array,
                     positions: jax.Array):
        """Batched draft verification: decode-exact logits for s
        tentative tokens per row, read-only on the cache (see
        ``repro.models.transformer.lm_verify_chunk``)."""
        return lm_verify_chunk(params, cache, tokens, positions, self.cfg)

    def commit_chunk(self, cache: dict, info: dict, positions: jax.Array,
                     e: jax.Array) -> dict:
        """Write the accepted prefix (e tokens per row) of a verified
        block through the quantized cache-write path."""
        return lm_commit_chunk(cache, info, positions, e, self.cfg)

    def rollback_chunk(self, cache: dict, positions: jax.Array,
                       reject: jax.Array) -> dict:
        """Pointer-invalidate speculative ring writes (draft-model cache
        leg)."""
        return lm_rollback_chunk(cache, positions, reject)

    @property
    def supports_chunked_prefill(self) -> bool:
        return supports_chunked_prefill(self.cfg)

    def min_cache_capacity(self, max_seq: int) -> int:
        return min_cache_capacity(self.cfg, max_seq)

    def init_cache(self, batch: int, max_seq: int, enc_len: int = 0):
        return init_cache(self.cfg, batch, max_seq, enc_len)

    def kv_cache_stats(self, cache: dict) -> dict:
        """Measured attention-KV byte accounting for ``cache`` (see
        ``repro.models.transformer.kv_cache_stats``)."""
        return kv_cache_stats(cache, self.cfg)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)


# --------------------------------------------------------------------- #
# Batch construction (real arrays / dry-run specs)
# --------------------------------------------------------------------- #

def vlm_patches(seq_len: int) -> int:
    """Patch-prefix length for VLM trunks (shrinks for tiny smoke seqs)."""
    return min(VLM_PATCHES, max(1, seq_len // 2))


def _text_len(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.frontend == "vision":
        return seq_len - vlm_patches(seq_len)
    return seq_len


def batch_fields(cfg: ArchConfig, shape: ShapeConfig
                 ) -> Dict[str, Tuple[tuple, str]]:
    """{name: (shape, dtype)} for a *forward/prefill* batch."""
    b, s = shape.global_batch, shape.seq_len
    emb_dtype = cfg.compute_dtype
    fields: Dict[str, Tuple[tuple, str]] = {}
    if cfg.is_encoder_decoder:
        # audio frontend stub: precomputed frame embeddings
        fields["frames"] = ((b, s, cfg.d_model), emb_dtype)
        fields["tokens"] = ((b, s), "int32")
    elif cfg.frontend == "vision":
        fields["patches"] = ((b, vlm_patches(s), cfg.d_model), emb_dtype)
        fields["tokens"] = ((b, _text_len(cfg, s)), "int32")
    else:
        fields["tokens"] = ((b, s), "int32")
    return fields


def make_batch(cfg: ArchConfig, shape: ShapeConfig, key: jax.Array
               ) -> Dict[str, jax.Array]:
    out = {}
    for name, (shp, dtype) in batch_fields(cfg, shape).items():
        key, sub = jax.random.split(key)
        if dtype == "int32":
            out[name] = jax.random.randint(sub, shp, 0, cfg.vocab_size,
                                           jnp.int32)
        else:
            out[name] = jax.random.normal(sub, shp, jnp.dtype(dtype)) * 0.02
    return out


def batch_spec(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    return {name: jax.ShapeDtypeStruct(shp, jnp.dtype(dtype))
            for name, (shp, dtype) in batch_fields(cfg, shape).items()}


def decode_inputs_spec(cfg: ArchConfig, shape: ShapeConfig):
    """(cache, token, pos) ShapeDtypeStructs for a decode-shape cell."""
    b, s = shape.global_batch, shape.seq_len
    enc_len = s if cfg.is_encoder_decoder else 0
    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, s, enc_len=enc_len))
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    return cache, token, pos
