"""Primitive layers shared by every architecture: norms, embeddings, RoPE,
MLP variants, initializers.  Pure-functional: parameters are plain pytrees
of ``jnp`` arrays; every ``apply`` is ``f(params, x, ...)``.

Numerics discipline (informed by the paper's §V precision study): parameters
are stored at ``param_dtype``, activations flow at ``compute_dtype``, and
reductions that are precision-critical (norm statistics, softmax, final
logits) are computed in float32 regardless.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------- #
# Initialization
# --------------------------------------------------------------------- #

def normal_init(key: jax.Array, shape, stddev: float, dtype) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


def dense_init(key: jax.Array, shape, dtype, fan_in: Optional[int] = None
               ) -> jax.Array:
    """Scaled (1/sqrt(fan_in)) truncated-normal; fan_in defaults to
    ``shape[-2]`` (the contraction dim of a ``x @ w`` matmul)."""
    if fan_in is None:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    return normal_init(key, shape, 1.0 / math.sqrt(max(fan_in, 1)), dtype)


# --------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------- #

def rms_norm(w: jax.Array, x: jax.Array, eps: float = 1e-6,
             gemma_style: bool = False) -> jax.Array:
    """RMSNorm; statistics in fp32.  ``gemma_style`` uses (1 + w) scaling."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if gemma_style \
        else w.astype(jnp.float32)
    return (xf * scale).astype(dtype)


def init_rms_norm(d: int, dtype, gemma_style: bool = False) -> jax.Array:
    return jnp.zeros((d,), dtype) if gemma_style else jnp.ones((d,), dtype)


# --------------------------------------------------------------------- #
# Embedding / unembedding
# --------------------------------------------------------------------- #

def embed(w: jax.Array, tokens: jax.Array, scale_by_dim: bool = False
          ) -> jax.Array:
    """Token embedding lookup; gemma-family scales by sqrt(d_model)."""
    x = jnp.take(w, tokens, axis=0)
    if scale_by_dim:
        x = x * jnp.asarray(math.sqrt(w.shape[-1]), x.dtype)
    return x


def unembed(w: jax.Array, x: jax.Array,
            softcap: Optional[float] = None) -> jax.Array:
    """Project to vocab logits (fp32) with optional final-logit softcap."""
    logits = jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


# --------------------------------------------------------------------- #
# Rotary position embedding
# --------------------------------------------------------------------- #

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,), fp32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """Rotate ``x`` (..., seq, heads, head_dim) by ``positions`` (..., seq).

    Split-half convention (llama/gemma): pairs are (x[:d/2], x[d/2:]).
    Computed in fp32, returned at x.dtype.
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (...,s,d/2)
    cos = jnp.cos(angles)[..., None, :]   # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# MLP variants
# --------------------------------------------------------------------- #

def init_mlp(key: jax.Array, d_model: int, d_ff: int, variant: str, dtype
             ) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], (d_model, d_ff), dtype),
         "w2": dense_init(ks[1], (d_ff, d_model), dtype)}
    if variant in ("swiglu", "geglu"):
        p["w3"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def apply_mlp(p: dict, x: jax.Array, variant: str) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w1"])
    if variant == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("...d,df->...f", x, p["w3"])
    elif variant == "geglu":
        h = jax.nn.gelu(h, approximate=True) \
            * jnp.einsum("...d,df->...f", x, p["w3"])
    elif variant == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    else:
        raise ValueError(f"unknown mlp variant {variant!r}")
    return jnp.einsum("...f,fd->...d", h, p["w2"])


# --------------------------------------------------------------------- #
# Misc
# --------------------------------------------------------------------- #

def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (batch, seq, channels); kernel (C, K)."""
    k = w.shape[-1]
    out = jax.lax.conv_general_dilated(
        x, w[:, None, :],                       # (C, 1, K)
        window_strides=(1,),
        padding=[(k - 1, 0)],
        dimension_numbers=("NWC", "OIW", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b
