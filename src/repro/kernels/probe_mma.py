"""MXU tile-sweep probe, TPU Pallas — the paper's §V.B/§V.D (Fig 4/5).

The paper sweeps ``mma`` tile shapes and (warps x ILP) to find the
throughput/latency surface of the tensor core.  TPU adaptation
(DESIGN.md §3): the MXU's native tile is 128x128; the probe runs a blocked
matmul whose BlockSpec tile (bm, bn, bk) is the swept axis — misaligned
(non-multiple-of-128) tiles expose padding waste, exactly the paper's
operand-staging story — and ``ilp`` independent fp32 accumulators per grid
step expose the MXU pipeline depth (the paper's ILP axis; grid programs
play the role of warps).

Validated against jnp.dot in interpret mode; on a real TPU the wall-time
sweep is benchmarks/fig4_5_matmul.py.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(x_ref, y_ref, o_ref, acc, *, ilp: int, bm: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    # ilp independent (bm, bk) x (bk, bn) products per step — separate
    # accumulator slices, no cross-dependency (the ILP axis)
    for t in range(ilp):
        x_t = x_ref[t]                                # (bm, bk)
        acc[t] += jax.lax.dot(x_t, y_ref[...],
                              preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _emit():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def mma_probe(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
              bk: int = 128, ilp: int = 1,
              interpret: Optional[bool] = None) -> jax.Array:
    """x (ilp, m, k) @ y (k, n) -> (ilp, m, n), blocked (bm, bn, bk)."""
    ilp_, m, k = x.shape
    n = y.shape[1]
    assert ilp_ == ilp and m % bm == 0 and n % bn == 0 and k % bk == 0
    kernel = functools.partial(_kernel, ilp=ilp, bm=bm)
    return compat.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((ilp, bm, bk), lambda i, j, kk: (0, i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((ilp, bm, bn), lambda i, j, kk: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((ilp, m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((ilp, bm, bn), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(x, y)
