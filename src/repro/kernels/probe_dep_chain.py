"""Dependency-chain compute probe, TPU Pallas — the paper's §IV.B/§IV.D.

The paper measures *true latency* with a serialized dependent chain
(``mad.lo.s32`` r1 <- r1*r2+r3) and *completion latency* with independent
chains.  TPU adaptation (DESIGN.md §3): a VREG-resident (8, 128) tile is
carried through ``chain_len`` fused-multiply-adds inside a ``fori_loop``;
``ilp`` independent tiles interleave to expose instruction-level
parallelism to the VPU — the exact true-vs-completion axis, with warps
replaced by grid programs.

On a real TPU the wall-time slope over ``chain_len`` gives cycles/op; in
interpret mode the kernel is validated against the closed form
(x * a^n + b * (a^n - 1)/(a - 1)).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat

TILE = (8, 128)


def _kernel(x_ref, o_ref, *, chain_len: int, ilp: int, a: float, b: float):
    def body(_, carry):
        return tuple(c * a + b for c in carry)

    tiles = tuple(x_ref[i] for i in range(ilp))
    tiles = jax.lax.fori_loop(0, chain_len, body, tiles)
    for i in range(ilp):
        o_ref[i] = tiles[i]


def dep_chain(x: jax.Array, chain_len: int, ilp: int = 1,
              a: float = 1.0001, b: float = 0.5,
              interpret: Optional[bool] = None) -> jax.Array:
    """x (ilp, 8, 128) fp32 -> same shape after ``chain_len`` serial FMAs
    per tile (tiles are mutually independent => ILP axis)."""
    assert x.shape == (ilp,) + TILE
    kernel = functools.partial(_kernel, chain_len=chain_len, ilp=ilp,
                               a=a, b=b)
    return compat.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(x.shape, lambda: (0, 0, 0))],
        out_specs=pl.BlockSpec(x.shape, lambda: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def dep_chain_closed_form(x: jax.Array, chain_len: int,
                          a: float = 1.0001, b: float = 0.5) -> jax.Array:
    """Oracle: x*a^n + b*(a^n-1)/(a-1)."""
    an = a ** chain_len
    return x * an + b * (an - 1.0) / (a - 1.0)
