"""Public jit'd wrappers for the Pallas kernels.

Each op auto-selects ``interpret=True`` off-TPU via ``repro.compat``
(this container's CPU validates the kernel bodies; a real v5e compiles
them via Mosaic) and handles layout/padding so callers use model-native
shapes.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat, lowbits
from repro.kernels import flash_attention as _fa
from repro.kernels import qmatmul as _qm
from repro.kernels import ssd_scan as _ssd
# imported up-front: the submodule name is shadowed by this module's
# flash_decode wrapper once repro.kernels.__init__ finishes
from repro.kernels.flash_decode import flash_decode_bhd as _flash_decode_bhd
from repro.kernels.flash_decode import (
    flash_decode_quant_bhd as _flash_decode_quant_bhd)
from repro.kernels.probe_chase import chase, make_chase_buffer  # noqa: F401
from repro.kernels.probe_dep_chain import dep_chain  # noqa: F401
from repro.kernels.probe_mma import mma_probe  # noqa: F401
from repro.serve.quant import BLOCK, quantize_blockwise


def _interpret() -> bool:
    return compat.pallas_interpret_default()


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bk"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    bq: int = 128, bk: int = 128) -> jax.Array:
    """Model-layout flash attention: q (b, sq, hq, d), k/v (b, skv, hkv, d).

    sq is padded to bq internally (extra queries attend causally and are
    sliced off)."""
    b, sq, hq, d = q.shape
    pad = (-sq) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = _fa.flash_attention_bhsd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal, window=window, softcap=softcap, scale=scale,
        bq=bq, bk=bk, interpret=_interpret())
    out = out.transpose(0, 2, 1, 3)
    return out[:, :sq] if pad else out


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "scale", "bk"))
def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 slot_pos: jax.Array, pos: jax.Array, *,
                 window: Optional[int] = None,
                 softcap: Optional[float] = None,
                 scale: Optional[float] = None,
                 bk: int = 512) -> jax.Array:
    """Model-layout flash-decoding: q (b, 1, hq, d), cache (b, S, hkv, d),
    slot_pos (b, S), pos (b,) -> (b, 1, hq, d)."""
    out = _flash_decode_bhd(
        q[:, 0], k_cache.transpose(0, 2, 1, 3),
        v_cache.transpose(0, 2, 1, 3),
        slot_pos, pos, window=window, softcap=softcap, scale=scale,
        bk=bk, interpret=_interpret())
    return out[:, None]


@functools.partial(jax.jit, static_argnames=(
    "fmt", "window", "softcap", "scale", "bk"))
def flash_decode_quant(q: jax.Array, kv_cache: dict, pos: jax.Array, *,
                       fmt: str,
                       window: Optional[int] = None,
                       softcap: Optional[float] = None,
                       scale: Optional[float] = None,
                       bk: int = 512) -> jax.Array:
    """Model-layout flash decode over a *quantized* KV cache.

    q (b, 1, hq, d); ``kv_cache`` is the quantized ring-cache dict from
    ``repro.models.attention.init_kv_cache(kv_format=fmt)`` (``k_q``/
    ``v_q`` packed codes (b, S, hkv, stored_d), ``k_s``/``v_s`` 1-byte
    e8m0 scales, ``slot_pos``); pos (b,) -> (b, 1, hq, d).  The kernel
    streams the packed bytes and expands them in VMEM — HBM KV traffic
    is the true stored byte count (fp4 ≈ 0.53 B/elem), not the dense
    width."""
    t = lambda a: a.transpose(0, 2, 1, 3)
    out = _flash_decode_quant_bhd(
        q[:, 0], t(kv_cache["k_q"]), t(kv_cache["k_s"]),
        t(kv_cache["v_q"]), t(kv_cache["v_s"]),
        kv_cache["slot_pos"], pos, fmt=fmt,
        window=window, softcap=softcap, scale=scale,
        bk=bk, interpret=_interpret())
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x: jax.Array, dt_a: jax.Array, b: jax.Array, c: jax.Array,
             chunk: int = 256,
             initial_state: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Model-layout SSD: x (bt, s, h, p) pre-discretized (x*dt),
    dt_a (bt, s, h), b/c (bt, s, n).  Pads s to the chunk (identity tail).
    ``initial_state`` (bt, h, p, n) seeds the scan (zeros when omitted) —
    the chunked-prefill carry between a slot's successive chunks.
    Returns (y (bt, s, h, p), final_state (bt, h, p, n))."""
    bt, s, h, p = x.shape
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_a = jnp.pad(dt_a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    y, state = _ssd.ssd_scan_bhsp(
        x.transpose(0, 2, 1, 3), dt_a.transpose(0, 2, 1),
        b, c, chunk=chunk, initial_state=initial_state,
        interpret=_interpret())
    y = y.transpose(0, 2, 1, 3)
    return (y[:, :s] if pad else y), state


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def qmatmul(x: jax.Array, qw: jax.Array, scales: jax.Array, *,
            bm: int = 128, bn: int = 128, bk: int = 128) -> jax.Array:
    """x (m, k) @ dequant(qw (n, k)).T with e8m0 block scales (n, k/32)."""
    m, k = x.shape
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    out = _qm.qmatmul_mkn(x, qw, scales, bm=bm, bn=bn, bk=bk,
                          interpret=_interpret())
    return out[:m] if pad_m else out


@functools.partial(jax.jit, static_argnames=("fmt", "bm", "bn", "bk"))
def qmatmul_packed(x: jax.Array, pw: jax.Array, scales: jax.Array,
                   fmt: str, *,
                   bm: int = 128, bn: int = 128, bk: int = 128
                   ) -> jax.Array:
    """x (m, k) @ dequant(unpack(pw), scales).T with bit-packed weights.

    ``pw`` is (n, k*bits/8) uint8 from :func:`pack_for_qmatmul` — true
    0.5 B/elem (fp4) / 0.75 B/elem (fp6) HBM-resident storage, expanded
    in VMEM; bit-exact with :func:`qmatmul` on the same quantized
    values."""
    m, _ = x.shape
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    out = _qm.qmatmul_packed_mkn(x, pw, scales, fmt, bm=bm, bn=bn, bk=bk,
                                 interpret=_interpret())
    return out[:m] if pad_m else out


def quantize_for_qmatmul(w: jax.Array, fmt: str
                         ) -> Tuple[jax.Array, jax.Array]:
    """w (k, n) -> (qw (n, k) quantized along k, scales (n, k/32))."""
    return quantize_blockwise(w.T, fmt)


def pack_for_qmatmul(w: jax.Array, fmt: str
                     ) -> Tuple[jax.Array, jax.Array]:
    """w (k, n) -> (pw (n, k*bits/8) uint8 bit-packed, scales (n, k/32)).

    Same quantization as :func:`quantize_for_qmatmul` (so the packed and
    container kernels see identical values), then ``repro.lowbits.pack``
    along k.  ``fmt`` must be packable (fp4/fp6)."""
    qw, scales = quantize_blockwise(w.T, fmt)
    pw = lowbits.pack(np.asarray(qw.astype(jnp.float32)), fmt)
    return jnp.asarray(pw), scales
