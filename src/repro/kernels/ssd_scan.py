"""Mamba-2 SSD chunked scan, TPU Pallas.

The SSD hot loop (DESIGN.md §4): per (batch, head) the sequence is
processed chunk-by-chunk; each chunk does three MXU-shaped products
(C@B^T, masked-decay quadratic @ x, C @ state) entirely in VMEM while the
(p x n) running state lives in fp32 scratch across the sequential chunk
grid dimension.  HBM traffic is O(s*(p+n)) — the recurrent state never
round-trips.

Grid: (b, h, s/q), KV-chunk dim innermost + arbitrary.  Inputs are
pre-discretized (x*dt, dt*A) by ``ops.ssd_scan`` — matching the pure-jnp
twin ``repro.models.ssm.ssd_chunked`` (the oracle).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(x_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, state_ref, s_scr,
            *, q: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        # seed the running state from the caller's carry (zeros for a
        # fresh sequence; a slot's cached state for chunked prefill)
        s_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)               # (q, p)
    a = a_ref[0, 0].astype(jnp.float32)               # (q,)
    bm = b_ref[0].astype(jnp.float32)                 # (q, n)
    cm = c_ref[0].astype(jnp.float32)                 # (q, n)

    acs = jnp.cumsum(a)                               # (q,)
    seg = acs[:, None] - acs[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    el = jnp.where(tril, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot(scores * el, x,
                         preferred_element_type=jnp.float32)
    # contribution of the state entering this chunk
    state = s_scr[...]                                # (p, n)
    y_off = jax.lax.dot_general(cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_diag + y_off * jnp.exp(acs)[:, None]).astype(
        y_ref.dtype)
    # state update: decay whole chunk + inject chunk inputs
    decay_out = jnp.exp(acs[-1] - acs)                # (q,)
    inj = jax.lax.dot_general(x, bm * decay_out[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (p,n)
    s_scr[...] = state * jnp.exp(acs[-1]) + inj

    @pl.when(ci == nc - 1)
    def _emit_state():
        state_ref[0, 0] = s_scr[...]


def ssd_scan_bhsp(x_disc: jax.Array, dt_a: jax.Array, b: jax.Array,
                  c: jax.Array, chunk: int = 256,
                  initial_state: Optional[jax.Array] = None,
                  interpret: Optional[bool] = None):
    """x_disc (bt, h, s, p) = x*dt;  dt_a (bt, h, s);  b, c (bt, s, n);
    optional initial_state (bt, h, p, n) carried into chunk 0 (zeros when
    omitted — a fresh sequence).

    Returns (y (bt, h, s, p) at x dtype, final_state (bt, h, p, n) fp32).
    s must be a multiple of ``chunk`` (ops pads identically to the jnp
    twin: dt_a=0 / x=0 tail is an exact identity).
    """
    bt, h, s, p = x_disc.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    h0 = (jnp.zeros((bt, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    assert h0.shape == (bt, h, p, n), (h0.shape, (bt, h, p, n))
    kernel = functools.partial(_kernel, q=chunk)
    y, state = compat.pallas_call(
        kernel,
        grid=(bt, h, s // chunk),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, h, s, p), x_disc.dtype),
            jax.ShapeDtypeStruct((bt, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(x_disc, dt_a, b, c, h0)
    return y, state
