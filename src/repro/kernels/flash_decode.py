"""Flash-decoding, TPU Pallas — single-token attention against a (ring)
KV cache.

The serving hot path (decode_32k / long_500k cells): one query row
attends to S cached positions.  The XLA path materializes the (1, S)
score row per head in HBM; this kernel streams KV blocks through VMEM
with the m/l/acc partial-softmax state in scratch — HBM traffic is the
KV read itself (the roofline floor), which is why the quantized-KV
lever composes: :func:`flash_decode_quant_bhd` streams fp8-container or
nibble-packed fp4 KV blocks plus their 1-byte e8m0 scales and expands
them in VMEM on the way in (``repro.lowbits`` shift/mask/exp2 — the
same codec the cache write path encodes with), so the HBM read per
cached token is the true packed byte count (fp4 ≈ 0.53 B/elem vs 2
B/elem bf16 — the §VI.D read-bandwidth story).

Grid (batch*q_heads, S/bk), KV-block dim innermost/arbitrary.  Ring-cache
semantics match ``repro.models.attention.decode_attention`` (the oracle):
slot visibility = 0 <= slot_pos <= pos (and > pos - window for local
layers).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat, lowbits

NEG_INF = -1.0e30


def _attend_block(q, k, v, slot_pos, pos, o_ref, m_scr, l_scr, acc_scr, *,
                  window: Optional[int], softcap: Optional[float],
                  scale: float):
    """Shared online-softmax body: one (1, d) query against one (bk, d)
    KV block, scratch-carried m/l/acc, finalize on the last block."""
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    ok = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        ok &= slot_pos > pos - window
    s = jnp.where(ok[None, :], s, NEG_INF)            # (1, bk)

    m_prev = m_scr[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * corr[:, None] + jnp.sum(p, axis=1,
                                                      keepdims=True)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot(p, v,
                                  preferred_element_type=jnp.float32))
    m_scr[...] = m_new[:, None]

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _kernel(pos_ref, q_ref, k_ref, v_ref, sp_ref, o_ref,
            m_scr, l_scr, acc_scr, *,
            bk: int, window: Optional[int], softcap: Optional[float],
            scale: float):
    q = q_ref[0].astype(jnp.float32)                  # (1, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)                  # (bk, d)
    _attend_block(q, k, v, sp_ref[0], pos_ref[0], o_ref,
                  m_scr, l_scr, acc_scr,
                  window=window, softcap=softcap, scale=scale)


def _expand_kv_tile(stored, s_codes, *, fmt: str, packed: bool, d: int,
                    blk: int):
    """(bk, stored_d) codes/container + (bk, d/blk) e8m0 bytes ->
    (bk, d) fp32, in VMEM — dequant-on-the-way-in (shift/mask/exp2 only,
    no ml_dtypes: the ``repro.lowbits`` in-kernel codec)."""
    if packed:
        vals = lowbits.decode(lowbits.unpack_codes(stored, fmt), fmt)
    else:
        vals = stored.astype(jnp.float32)
    scales = lowbits.e8m0_decode(s_codes)             # (bk, d/blk)
    bkk = vals.shape[0]
    return (vals.reshape(bkk, d // blk, blk)
            * scales[:, :, None]).reshape(bkk, d)


def _quant_kernel(pos_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref, sp_ref,
                  o_ref, m_scr, l_scr, acc_scr, *,
                  bk: int, window: Optional[int], softcap: Optional[float],
                  scale: float, fmt: str, packed: bool, d: int, blk: int):
    q = q_ref[0].astype(jnp.float32)                  # (1, d)
    k = _expand_kv_tile(kq_ref[0], ks_ref[0], fmt=fmt, packed=packed,
                        d=d, blk=blk)                 # (bk, d)
    v = _expand_kv_tile(vq_ref[0], vs_ref[0], fmt=fmt, packed=packed,
                        d=d, blk=blk)                 # (bk, d)
    _attend_block(q, k, v, sp_ref[0], pos_ref[0], o_ref,
                  m_scr, l_scr, acc_scr,
                  window=window, softcap=softcap, scale=scale)


def flash_decode_bhd(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     slot_pos: jax.Array, pos: jax.Array, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     scale: Optional[float] = None,
                     bk: int = 512,
                     interpret: Optional[bool] = None) -> jax.Array:
    """q (b, hq, d); k/v cache (b, hkv, S, d); slot_pos (b, S) int32;
    pos (b,) int32 -> (b, hq, d).  S padded to bk (empty slots carry
    slot_pos = -1 and mask out)."""
    b, hq, d = q.shape
    hkv, S = k_cache.shape[1], k_cache.shape[2]
    ratio = hq // hkv
    pad = (-S) % bk
    if pad:
        k_cache, v_cache = _pad_s(k_cache, pad), _pad_s(v_cache, pad)
        slot_pos = jnp.pad(slot_pos, ((0, 0), (0, pad)),
                           constant_values=-1)
    S_pad = S + pad
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    qf = q.reshape(b * hq, 1, d)
    kf = k_cache.reshape(b * hkv, S_pad, d)
    vf = v_cache.reshape(b * hkv, S_pad, d)

    def kv_index(g, j):
        return (g // hq) * hkv + (g % hq) // ratio, j, 0

    kernel = functools.partial(_kernel, bk=bk, window=window,
                               softcap=softcap, scale=scale)
    out = compat.pallas_call(
        kernel,
        grid=(b * hq, S_pad // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda g, j: (g // hq,)),        # pos
            pl.BlockSpec((1, 1, d), lambda g, j: (g, 0, 0)),    # q
            pl.BlockSpec((1, bk, d), kv_index),                 # k
            pl.BlockSpec((1, bk, d), kv_index),                 # v
            pl.BlockSpec((1, bk), lambda g, j: (g // hq, j)),   # slot_pos
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda g, j: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        dimension_semantics=("parallel", "arbitrary"),
        interpret=interpret,
    )(pos.astype(jnp.int32), qf, kf, vf, slot_pos)
    return out.reshape(b, hq, d)


def _pad_s(x: jax.Array, pad: int, fill=0) -> jax.Array:
    """Pad axis 2 (the S axis of (b, h, S, ...) arrays) by ``pad``."""
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[2] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def flash_decode_quant_bhd(q: jax.Array,
                           k_q: jax.Array, k_s: jax.Array,
                           v_q: jax.Array, v_s: jax.Array,
                           slot_pos: jax.Array, pos: jax.Array, *,
                           fmt: str,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None,
                           bk: int = 512,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Quantized-KV flash decode: the dequant-in-VMEM leg.

    q (b, hq, d); k_q/v_q (b, hkv, S, stored_d) — nibble/3-byte-group
    packed uint8 codes for sub-byte ``fmt``, container bytes for fp8;
    k_s/v_s (b, hkv, S, d/blk) uint8 e8m0 block-scale codes (the layout
    ``repro.models.attention.init_kv_cache(kv_format=...)`` holds, head/
    seq axes swapped); slot_pos (b, S) int32; pos (b,) int32 ->
    (b, hq, d).  HBM reads per cached token are the true packed bytes +
    1-byte scales; expansion happens on the VMEM tile on the way into
    the dot (``lowbits.decode``/``e8m0_decode``).
    """
    spec = compat.dtype_spec(fmt)
    b, hq, d = q.shape
    hkv, S, stored_d = k_q.shape[1], k_q.shape[2], k_q.shape[3]
    n_blk = k_s.shape[3]
    packed = spec.packed is not None
    if packed:
        ps = spec.packed
        assert stored_d == d // ps.values_per_group * ps.bytes_per_group, \
            (stored_d, d, fmt)
    else:
        assert stored_d == d, (stored_d, d, fmt)
    assert d % n_blk == 0, (d, n_blk)
    blk = d // n_blk
    ratio = hq // hkv
    pad = (-S) % bk
    if pad:
        k_q, v_q = _pad_s(k_q, pad), _pad_s(v_q, pad)
        k_s, v_s = _pad_s(k_s, pad), _pad_s(v_s, pad)
        slot_pos = jnp.pad(slot_pos, ((0, 0), (0, pad)),
                           constant_values=-1)
    S_pad = S + pad
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    qf = q.reshape(b * hq, 1, d)
    kqf = k_q.reshape(b * hkv, S_pad, stored_d)
    ksf = k_s.reshape(b * hkv, S_pad, n_blk)
    vqf = v_q.reshape(b * hkv, S_pad, stored_d)
    vsf = v_s.reshape(b * hkv, S_pad, n_blk)

    def kv_index(g, j):
        return (g // hq) * hkv + (g % hq) // ratio, j, 0

    kernel = functools.partial(
        _quant_kernel, bk=bk, window=window, softcap=softcap, scale=scale,
        fmt=fmt, packed=packed, d=d, blk=blk)
    out = compat.pallas_call(
        kernel,
        grid=(b * hq, S_pad // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda g, j: (g // hq,)),          # pos
            pl.BlockSpec((1, 1, d), lambda g, j: (g, 0, 0)),      # q
            pl.BlockSpec((1, bk, stored_d), kv_index),            # k codes
            pl.BlockSpec((1, bk, n_blk), kv_index),               # k scales
            pl.BlockSpec((1, bk, stored_d), kv_index),            # v codes
            pl.BlockSpec((1, bk, n_blk), kv_index),               # v scales
            pl.BlockSpec((1, bk), lambda g, j: (g // hq, j)),     # slot_pos
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda g, j: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        dimension_semantics=("parallel", "arbitrary"),
        interpret=interpret,
    )(pos.astype(jnp.int32), qf, kqf, ksf, vqf, vsf, slot_pos)
    return out.reshape(b, hq, d)
