"""Flash-decoding, TPU Pallas — single-token attention against a (ring)
KV cache.

The serving hot path (decode_32k / long_500k cells): one query row
attends to S cached positions.  The XLA path materializes the (1, S)
score row per head in HBM; this kernel streams KV blocks through VMEM
with the m/l/acc partial-softmax state in scratch — HBM traffic is the
KV read itself (the roofline floor), which is why the fp8-KV lever
(§Perf iter 3) composes: the dequant happens in VMEM on the way in.

Grid (batch*q_heads, S/bk), KV-block dim innermost/arbitrary.  Ring-cache
semantics match ``repro.models.attention.decode_attention`` (the oracle):
slot visibility = 0 <= slot_pos <= pos (and > pos - window for local
layers).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1.0e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, sp_ref, o_ref,
            m_scr, l_scr, acc_scr, *,
            bk: int, window: Optional[int], softcap: Optional[float],
            scale: float):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (1, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)                  # (bk, d)
    slot_pos = sp_ref[0]                              # (bk,) int32
    pos = pos_ref[0]                                  # () int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    ok = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        ok &= slot_pos > pos - window
    s = jnp.where(ok[None, :], s, NEG_INF)            # (1, bk)

    m_prev = m_scr[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * corr[:, None] + jnp.sum(p, axis=1,
                                                      keepdims=True)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot(p, v,
                                  preferred_element_type=jnp.float32))
    m_scr[...] = m_new[:, None]

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_decode_bhd(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     slot_pos: jax.Array, pos: jax.Array, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     scale: Optional[float] = None,
                     bk: int = 512,
                     interpret: Optional[bool] = None) -> jax.Array:
    """q (b, hq, d); k/v cache (b, hkv, S, d); slot_pos (b, S) int32;
    pos (b,) int32 -> (b, hq, d).  S padded to bk (empty slots carry
    slot_pos = -1 and mask out)."""
    b, hq, d = q.shape
    hkv, S = k_cache.shape[1], k_cache.shape[2]
    ratio = hq // hkv
    pad = (-S) % bk
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        slot_pos = jnp.pad(slot_pos, ((0, 0), (0, pad)),
                           constant_values=-1)
    S_pad = S + pad
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    qf = q.reshape(b * hq, 1, d)
    kf = k_cache.reshape(b * hkv, S_pad, d)
    vf = v_cache.reshape(b * hkv, S_pad, d)

    def kv_index(g, j):
        return (g // hq) * hkv + (g % hq) // ratio, j, 0

    kernel = functools.partial(_kernel, bk=bk, window=window,
                               softcap=softcap, scale=scale)
    out = compat.pallas_call(
        kernel,
        grid=(b * hq, S_pad // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda g, j: (g // hq,)),        # pos
            pl.BlockSpec((1, 1, d), lambda g, j: (g, 0, 0)),    # q
            pl.BlockSpec((1, bk, d), kv_index),                 # k
            pl.BlockSpec((1, bk, d), kv_index),                 # v
            pl.BlockSpec((1, bk), lambda g, j: (g // hq, j)),   # slot_pos
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda g, j: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        dimension_semantics=("parallel", "arbitrary"),
        interpret=interpret,
    )(pos.astype(jnp.int32), qf, kf, vf, slot_pos)
    return out.reshape(b, hq, d)
