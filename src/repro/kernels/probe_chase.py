"""Pointer-chase memory probe, TPU Pallas — the paper's §VI.A (Fig 6).

The paper walks a random permutation with serialized dependent loads to
expose each cache level's load-to-use latency.  TPU adaptation: the
permutation lives in a VMEM-resident (rows, 128) int32 buffer; each step
loads row ``idx`` and takes lane 0 as the next index — a serialized
VMEM-load chain.  Sweeping ``rows`` across the VMEM capacity boundary (and
running the jnp twin over HBM-sized buffers) reproduces the hierarchy-walk
methodology; on CPU the same sweep walks the host L1/L2/L3 (the
methodology-validation plot in benchmarks/fig6_memory.py).

Validated against a numpy chase in interpret mode.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro import compat


def _kernel(buf_ref, o_ref, *, steps: int):
    def body(_, idx):
        row = buf_ref[idx]                 # dependent VMEM load
        return row[0]

    idx = jax.lax.fori_loop(0, steps, body, jnp.int32(0))
    o_ref[0, 0] = idx


def chase(buf: jax.Array, steps: int,
          interpret: Optional[bool] = None) -> jax.Array:
    """buf (rows, 128) int32 — buf[i, 0] = next row.  Returns final index."""
    kernel = functools.partial(_kernel, steps=steps)
    return compat.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(buf.shape, lambda: (0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(buf)[0, 0]


def make_chase_buffer(rows: int, seed: int = 0) -> jax.Array:
    """Random single-cycle permutation broadcast across 128 lanes."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(rows - 1) + 1     # cycle visiting every row
    nxt = np.zeros(rows, np.int32)
    cur = 0
    for p in perm:
        nxt[cur] = p
        cur = p
    nxt[cur] = 0
    return jnp.asarray(np.broadcast_to(nxt[:, None], (rows, 128)).copy())


def chase_reference(buf: np.ndarray, steps: int) -> int:
    idx = 0
    col = np.asarray(buf)[:, 0]
    for _ in range(steps):
        idx = int(col[idx])
    return idx
