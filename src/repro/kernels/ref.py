"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import full_attention
from repro.models.ssm import ssd_chunked, ssd_reference
from repro.serve.quant import dequantize_blockwise


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """O(s^2)-memory attention (repro.models.attention.full_attention)."""
    return full_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, scale=scale)


def ssd_ref(x: jax.Array, dt_a: jax.Array, b: jax.Array, c: jax.Array,
            sequential: bool = False,
            initial_state: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Chunk-parallel (default) or strictly-sequential SSD oracle.
    Model layout: x (bt, s, h, p); optional carried state (bt, h, p, n)."""
    if sequential:
        return ssd_reference(x, dt_a, b, c, initial_state=initial_state)
    return ssd_chunked(x, dt_a, b, c, chunk=min(64, x.shape[1]),
                       initial_state=initial_state)


def qmatmul_ref(x: jax.Array, qw: jax.Array, scales: jax.Array
                ) -> jax.Array:
    """Dequantize fully, then dense matmul (fp32 accumulation)."""
    w = dequantize_blockwise(qw, scales, jnp.float32)   # (n, k)
    return jnp.dot(x.astype(jnp.float32), w.T).astype(jnp.bfloat16)


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Oracle for probe_mma: batched x (ilp, m, k) @ y (k, n)."""
    return jnp.einsum("tmk,kn->tmn", x.astype(jnp.float32),
                      y.astype(jnp.float32)).astype(x.dtype)
