"""Block-scaled low-precision matmul (qmatmul), TPU Pallas.

The paper's §V.B subject adapted to TPU (DESIGN.md §3): v5e's MXU has no
FP8/FP6/FP4 pipeline (the paper's own observation that FP4 mma falls back
to the QMMA/FP8 pipeline is the same story one step earlier), so low
precision on TPU is a *storage* format: weights stay quantized in HBM
with e8m0 (power-of-two) block scales — mxfp-style, 32 elements/scale —
and are dequantized to bf16 *inside the kernel*, in VMEM, on the way into
the MXU.  HBM weight traffic drops ~2x (fp8) to ~4x (fp4: the packed
variant below stores true 0.5 B/elem nibbles, fp6 0.75 B/elem — Tab V's
tile packing, accounted as measured bytes by the benchmarks).

Two entry points:

* :func:`qmatmul_mkn` — weights in the registry *container* dtype
  (1 B/elem; the numerical oracle for the packed path),
* :func:`qmatmul_packed_mkn` — weights bit-packed (``repro.lowbits``):
  each k-block loads a nibble/fp6-packed uint8 tile and expands it in
  VMEM (shift/mask/exp2 — no ml_dtypes in-kernel) before the same
  scale-multiply + fp32-accumulator dot, so the two paths are bit-exact.

Layout: x (m, k) bf16; qw (n, k) quantized along k (packed: (n, k*b/8)
uint8); scales (n, k/32) fp32 (power-of-two values = e8m0 content).
Grid (m/bm, n/bn, k/bk), k innermost/arbitrary with an fp32 VMEM
accumulator.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat, lowbits
from repro.serve.quant import BLOCK


def _accumulate(x_ref, s_ref, o_ref, acc, w, *, bk: int):
    """Shared tail of both kernels: scale w, dot, accumulate, emit."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[...].astype(jnp.float32)                 # (bm, bk)
    sc = s_ref[...]                                    # (bn, bk/32)
    bn = w.shape[0]
    w = (w.reshape(bn, bk // BLOCK, BLOCK) * sc[..., None]
         ).reshape(bn, bk)
    acc[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _emit():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def _kernel(x_ref, qw_ref, s_ref, o_ref, acc, *, bk: int):
    w = qw_ref[...].astype(jnp.float32)                # (bn, bk)
    _accumulate(x_ref, s_ref, o_ref, acc, w, bk=bk)


def _packed_kernel(x_ref, pw_ref, s_ref, o_ref, acc, *, bk: int, fmt: str):
    # (bn, bk*b/8) uint8 -> expand to (bn, bk) fp32 in VMEM
    codes = lowbits.unpack_codes(pw_ref[...], fmt)
    w = lowbits.decode(codes, fmt)
    _accumulate(x_ref, s_ref, o_ref, acc, w, bk=bk)


def qmatmul_mkn(x: jax.Array, qw: jax.Array, scales: jax.Array, *,
                bm: int = 128, bn: int = 128, bk: int = 128,
                out_dtype=jnp.bfloat16,
                interpret: Optional[bool] = None) -> jax.Array:
    """x (m, k) @ dequant(qw (n, k), scales (n, k/32)).T -> (m, n).

    ``interpret=None`` auto-selects native Mosaic on TPU vs. the Pallas
    interpreter elsewhere (``repro.compat``)."""
    m, k = x.shape
    n = qw.shape[0]
    assert qw.shape == (n, k) and scales.shape == (n, k // BLOCK)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k)
    assert bk % BLOCK == 0
    kernel = functools.partial(_kernel, bk=bk)
    return compat.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // BLOCK), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(x, qw, scales)


def qmatmul_packed_mkn(x: jax.Array, pw: jax.Array, scales: jax.Array,
                       fmt: str, *,
                       bm: int = 128, bn: int = 128, bk: int = 128,
                       out_dtype=jnp.bfloat16,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Like :func:`qmatmul_mkn` but with bit-packed weight storage.

    ``pw`` is (n, k * bits/8) uint8 out of ``repro.lowbits.pack`` (fp4:
    (n, k/2), fp6: (n, 3k/4)); each k-block tile is expanded to fp32 in
    VMEM before the identical scale/dot/accumulate, so the result is
    bit-exact with the container-storage kernel while the HBM weight
    read is the true packed byte count.
    """
    spec = lowbits.packed_spec(fmt)
    m, k = x.shape
    n = pw.shape[0]
    g, bpg = spec.values_per_group, spec.bytes_per_group
    assert k % g == 0 and bk % g == 0, (k, bk, fmt)
    kb, bkb = k * bpg // g, bk * bpg // g      # packed bytes: total, block
    assert pw.shape == (n, kb) and scales.shape == (n, k // BLOCK), \
        (pw.shape, scales.shape, n, kb)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k)
    assert bk % BLOCK == 0
    kernel = functools.partial(_packed_kernel, bk=bk, fmt=fmt)
    return compat.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bkb), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // BLOCK), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(x, pw, scales)
