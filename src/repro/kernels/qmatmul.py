"""Block-scaled low-precision matmul (qmatmul), TPU Pallas.

The paper's §V.B subject adapted to TPU (DESIGN.md §3): v5e's MXU has no
FP8/FP6/FP4 pipeline (the paper's own observation that FP4 mma falls back
to the QMMA/FP8 pipeline is the same story one step earlier), so low
precision on TPU is a *storage* format: weights stay quantized in HBM
with e8m0 (power-of-two) block scales — mxfp-style, 32 elements/scale —
and are dequantized to bf16 *inside the kernel*, in VMEM, on the way into
the MXU.  HBM weight traffic drops ~2x (fp8) to ~4x (fp4, with true bit
packing; here 1 B/elem containers, documented).

Layout: x (m, k) bf16; qw (n, k) quantized along k; scales (n, k/32) fp32
(power-of-two values = e8m0 content).  Grid (m/bm, n/bn, k/bk), k
innermost/arbitrary with an fp32 VMEM accumulator.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.serve.quant import BLOCK


def _kernel(x_ref, qw_ref, s_ref, o_ref, acc, *, bk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[...].astype(jnp.float32)                 # (bm, bk)
    qw = qw_ref[...].astype(jnp.float32)               # (bn, bk)
    sc = s_ref[...]                                    # (bn, bk/32)
    bn = qw.shape[0]
    w = (qw.reshape(bn, bk // BLOCK, BLOCK) * sc[..., None]
         ).reshape(bn, bk)
    acc[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _emit():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def qmatmul_mkn(x: jax.Array, qw: jax.Array, scales: jax.Array, *,
                bm: int = 128, bn: int = 128, bk: int = 128,
                out_dtype=jnp.bfloat16,
                interpret: Optional[bool] = None) -> jax.Array:
    """x (m, k) @ dequant(qw (n, k), scales (n, k/32)).T -> (m, n).

    ``interpret=None`` auto-selects native Mosaic on TPU vs. the Pallas
    interpreter elsewhere (``repro.compat``)."""
    m, k = x.shape
    n = qw.shape[0]
    assert qw.shape == (n, k) and scales.shape == (n, k // BLOCK)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k)
    assert bk % BLOCK == 0
    kernel = functools.partial(_kernel, bk=bk)
    return compat.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // BLOCK), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(x, qw, scales)
