"""Flash attention, TPU Pallas.

TPU-native adaptation of the attention hot-spot (DESIGN.md §3): the online
softmax runs over (bq x bk) VMEM tiles feeding the MXU; HBM traffic is
O(sq*d + skv*d) instead of O(sq*skv).  The grid is
(batch*q_heads, sq/bq, skv/bk) with the KV dim innermost and *arbitrary*
(sequential) semantics — m/l/acc scratch persists across KV steps because
the output block index is unchanged.

Supports causal masks, sliding windows (gemma2 local layers), logit
softcaps, and GQA (kv head = q head // ratio, resolved in the index_map —
no KV replication in HBM).

Causal/window block skipping: fully-masked (i, j) tiles are skipped via
``pl.when`` — the MXU never sees them, which is the FLOPs win the §Perf
log quantifies (~2x on causal prefill).

Oracle: ``repro.kernels.ref.attention_ref`` (== models.attention path).
Validated with ``interpret=True`` over shape/dtype sweeps in
tests/test_kernel_flash_attention.py.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, skv: int, causal: bool,
            window: Optional[int], softcap: Optional[float],
            scale: float, q_offset: int):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # kv block
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + i * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # static block-level visibility (skip fully-masked tiles)
    run = True
    if causal:
        run = jnp.asarray(j * bk <= q_offset + i * bq + bq - 1)
    if window is not None:
        run = jnp.logical_and(
            run, j * bk + bk - 1 >= q_offset + i * bq - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        ok = k_pos < skv                              # padding
        if causal:
            ok &= q_pos >= k_pos
        if window is not None:
            ok &= q_pos - k_pos < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = (l_scr[...] * corr[:, None]
                      + jnp.sum(p, axis=1, keepdims=True))
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot(p, v,
                                      preferred_element_type=jnp.float32))
        m_scr[...] = m_new[:, None]

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True,
                         window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         scale: Optional[float] = None,
                         q_offset: int = 0,
                         bq: int = 128, bk: int = 128,
                         interpret: Optional[bool] = None) -> jax.Array:
    """q (b, hq, sq, d); k, v (b, hkv, skv, d) -> (b, hq, sq, d).

    sq must be a multiple of bq; skv is padded to bk internally (the
    padding mask handles the tail).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    ratio = hq // hkv
    assert sq % bq == 0, (sq, bq)
    pad = (-skv) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    skv_pad = skv + pad
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv_pad, d)
    vf = v.reshape(b * hkv, skv_pad, d)

    def kv_index(g, i, j):
        return (g // hq) * hkv + (g % hq) // ratio, j, 0

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, skv=skv, causal=causal, window=window,
        softcap=softcap, scale=scale, q_offset=q_offset)

    out = compat.pallas_call(
        kernel,
        grid=(b * hq, sq // bq, skv_pad // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
