"""Pallas TPU kernels (validated in interpret mode on CPU; Mosaic on TPU).

Compute hot-spots: flash_attention, ssd_scan, qmatmul.
Probe kernels (the paper's methodology): probe_mma, probe_chase,
probe_dep_chain.  Public API in ``repro.kernels.ops``; oracles in
``repro.kernels.ref``.
"""

from repro.kernels.ops import (  # noqa: F401
    chase,
    dep_chain,
    flash_attention,
    flash_decode,
    flash_decode_quant,
    make_chase_buffer,
    mma_probe,
    pack_for_qmatmul,
    qmatmul,
    qmatmul_packed,
    quantize_for_qmatmul,
    ssd_scan,
)
