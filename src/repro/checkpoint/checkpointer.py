"""Checkpointer: atomic, async, elastic.

Layout:
    <dir>/step_<n>/arrays.npz     flattened pytree ("/"-joined keys)
    <dir>/step_<n>/manifest.json  treedef keys, dtypes, logical specs
    <dir>/LATEST                  pointer file (atomic os.replace)

Properties the tests exercise:
  * atomicity — a snapshot is written to ``step_<n>.tmp`` and renamed;
    a crash mid-save never corrupts LATEST,
  * async — ``save(block=False)`` snapshots device arrays to host
    (cheap) and writes on a worker thread; training continues,
  * elasticity — manifests store *logical* PartitionSpecs, so
    ``restore`` + ``repro.distributed.remesh`` re-shards onto any mesh.

Single-process container note: arrays are gathered to host fully; on a
real multi-host pod each process would write its addressable shards
(process-local files keyed by shard index) — the directory format
already carries the spec metadata needed for that.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec


# --------------------------------------------------------------------- #
# Pytree <-> flat dict
# --------------------------------------------------------------------- #

def _flatten(tree: Any, prefix: str = "", is_leaf=None) -> dict:
    out = {}
    if is_leaf is not None and is_leaf(tree):
        out[prefix[:-1]] = tree
    elif isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/", is_leaf))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/", is_leaf))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(like: Any, flat: dict, prefix: str = "") -> Any:
    if isinstance(like, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in like.items()}
    if isinstance(like, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(like)]
        return type(like)(vals)
    return flat[prefix[:-1]]


def _spec_to_json(spec) -> list:
    out = []
    for e in tuple(spec):
        if e is None:
            out.append(None)
        elif isinstance(e, str):
            out.append(e)
        else:
            out.append(list(e))
    return out


def _spec_from_json(lst) -> PartitionSpec:
    return PartitionSpec(*[tuple(e) if isinstance(e, list) else e
                           for e in lst])


# --------------------------------------------------------------------- #
# Save / load one tree
# --------------------------------------------------------------------- #

def save_tree(path: str, tree: Any, step: int,
              specs: Optional[Any] = None) -> None:
    """Write ``tree`` atomically to ``path`` (a step directory)."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    # npz can't represent extension dtypes (bfloat16, fp8): store raw
    # bytes and record dtype/shape in the manifest.
    arrays, dtypes, shapes = {}, {}, {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        arrays[k] = np.ascontiguousarray(a).reshape(-1).view(np.uint8)
        dtypes[k] = a.dtype.name if a.dtype.names is None else str(a.dtype)
        shapes[k] = list(a.shape)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "keys": sorted(arrays),
                "dtypes": dtypes, "shapes": shapes}
    if specs is not None:
        # PartitionSpec subclasses tuple: without is_leaf the generic
        # flatten recursed INTO each spec (an empty P() vanished
        # entirely), so restores got {} back — treat specs as leaves.
        flat_specs = _flatten(
            specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
        manifest["specs"] = {k: _spec_to_json(v)
                             for k, v in flat_specs.items()}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)


def load_tree(path: str, like: Any) -> Tuple[Any, int, Optional[dict]]:
    import ml_dtypes  # registers extension dtype names with numpy

    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {}
    for k in data.files:
        dtype = np.dtype(getattr(ml_dtypes, manifest["dtypes"][k],
                                 manifest["dtypes"][k]))
        flat[k] = data[k].view(dtype).reshape(manifest["shapes"][k])
    tree = _unflatten_into(like, flat)
    specs = None
    if "specs" in manifest:
        specs = {k: _spec_from_json(v) for k, v in manifest["specs"].items()}
    return tree, int(manifest["step"]), specs


# --------------------------------------------------------------------- #
# Checkpointer
# --------------------------------------------------------------------- #

class Checkpointer:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- paths -------------------------------------------------------- #
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "LATEST")
        try:
            with open(ptr) as f:
                return int(f.read().strip())
        except (FileNotFoundError, ValueError):
            return None

    # -- save ---------------------------------------------------------- #
    def save(self, tree: Any, step: int, specs: Optional[Any] = None,
             block: bool = True) -> None:
        self.wait()
        # snapshot to host NOW so training can mutate device arrays
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save_tree(self._step_dir(step), host, step, specs)
            tmp = os.path.join(self.dir, "LATEST.tmp")
            with open(tmp, "w") as f:
                f.write(str(step))
            os.replace(tmp, os.path.join(self.dir, "LATEST"))
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -------------------------------------------------------- #
    def restore(self, step: int, like: Any):
        tree, s, specs = load_tree(self._step_dir(step), like)
        return tree, s, specs

    def restore_latest(self, like: Any):
        step = self.latest_step()
        if step is None:
            return None
        tree, s, _ = self.restore(step, like)
        return tree, s

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def close(self) -> None:
        self.wait()
