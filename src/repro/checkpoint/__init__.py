"""Checkpointing: sharded-logical save/restore with elastic re-mesh."""

from repro.checkpoint.checkpointer import (  # noqa: F401
    Checkpointer,
    load_tree,
    save_tree,
)
