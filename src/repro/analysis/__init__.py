"""Static analysis & runtime sanitizers for the microbenchmark harness.

The source paper's methodology only works because its microbenchmarks are
tightly controlled: a stray host sync, a silent recompile, or a dtype
upcast and you are measuring the harness, not the hardware
(arXiv:2605.04178 makes the same point for the measured-vs-predicted
loop; arXiv:2402.13499 stresses hand-verified kernel contracts).  This
package is the checker built from the bug classes this repo has actually
shipped:

* :mod:`repro.analysis.lint` — AST lint over ``src/`` and
  ``benchmarks/``: host ops on tracers, Python control flow on traced
  values, mutation of jit-captured attributes (the PR-4 ``temperature``
  class), wall-clock/RNG in traced scope, memo caches keyed on mutable
  registry state (the PR-3 ``_format_table`` class).
* :mod:`repro.analysis.contracts` — jaxpr contract checking for the hot
  entry points: packed fp4/fp6/e8m0 buffers are never widened before
  their in-kernel expand, no host callbacks survive in hot paths,
  quantize-on-write keeps cache leaves at storage width.
* :mod:`repro.analysis.pallas_check` — static Pallas write-race /
  aliasing / VMEM-footprint checker over every ``pallas_call`` in
  ``repro.kernels``.
* :mod:`repro.analysis.sanitize` — runtime sanitizers: compile counters,
  host-sync counters, and a scripted serving scenario under
  ``jax.transfer_guard`` proving each serving executable compiles
  exactly once and the fused decode loop performs zero implicit host
  transfers.

CLI: ``python -m tools.jaxlint src benchmarks`` (the tier-1 CI gate).
"""

from repro.analysis.lint import (  # noqa: F401
    Finding, LintConfig, RULES, lint_paths, lint_source, load_baseline,
    write_baseline)
from repro.analysis.pallas_check import (  # noqa: F401
    PallasSite, check_kernels, check_sites, pallas_call_sites)
from repro.analysis.sanitize import (  # noqa: F401
    CompileCounter, SyncCounter, sanitize_serving)

__all__ = [
    "Finding", "LintConfig", "RULES", "lint_paths", "lint_source",
    "load_baseline", "write_baseline",
    "PallasSite", "check_kernels", "check_sites", "pallas_call_sites",
    "CompileCounter", "SyncCounter", "sanitize_serving",
]
