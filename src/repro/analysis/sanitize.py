"""Runtime sanitizers: compile counters, host-sync counters, and the
scripted serving scenario (layer 2's runtime half).

Static checks can't see everything: a recompile caused by a changed
static arg, or a host sync hidden behind a library call, only exists at
runtime.  The paper's §IV discipline (characterize dispatch/measurement
overhead before trusting numbers) translates here to two counters:

* :class:`CompileCounter` — counts XLA backend compiles via
  ``jax.monitoring`` duration events.  Zero inside a measured region
  means the timings in ``BENCH_serve.json`` are steady-state, not
  trace+compile noise.
* :class:`SyncCounter` — counts forced per-value host materializations
  (``float()``/``int()``/``.item()``/``.tolist()``/``device_get``) by
  wrapping the array ``_value`` materialization hook.  The engine's one
  batched ``np.asarray`` per K tokens reads the buffer directly and is
  the *designed* sync; everything this counter sees inside the fused
  loop is an accidental round trip.

:func:`sanitize_serving` wraps a scripted serving scenario in
``jax.transfer_guard`` plus both counters and returns a report proving
(a) each serving executable compiled exactly once, (b) the fused K-step
decode loop performed zero implicit host transfers, and (c) what
``quantize_tree`` costs in syncs per tree (2 after the PR-6 fix; 2 per
*leaf* before it).

With ``mesh=...`` the same scenario runs through the mesh-native
engine and the report gains the *collective* half of the story: the
fused loop's partitioned HLO is parsed for all-gather/all-reduce ops
and the report asserts no all-gather materializes anything larger than
the logits — the designed sample-point gather.  A weight or KV-pool
gather inside the scan body would mean GSPMD decided to unshard the
state every step, silently erasing the per-device bandwidth win the
sharded engine exists for.
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, List, Optional

COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


class SyncCounter:
    """Counts forced host materializations of device arrays.

    Implemented by wrapping ``ArrayImpl._value`` — the property every
    ``float()``/``int()``/``bool()``/``.item()``/``.tolist()``/
    ``jax.device_get`` materialization funnels through.  ``np.asarray``
    on a committed CPU array short-circuits via the buffer protocol and
    is not counted; that path is the engine's explicit batched sync, so
    "zero counted syncs" is exactly "zero *implicit* transfers".
    """

    def __init__(self):
        self.count = 0
        self._orig = None

    def __enter__(self) -> "SyncCounter":
        import jax._src.array as _array

        orig = _array.ArrayImpl.__dict__["_value"]
        self._orig = orig
        fget = orig.fget if isinstance(orig, property) else orig
        counter = self

        def counting(arr):
            counter.count += 1
            return fget(arr)

        _array.ArrayImpl._value = property(counting)
        return self

    def __exit__(self, *exc) -> None:
        import jax._src.array as _array

        _array.ArrayImpl._value = self._orig
        self._orig = None


class CompileCounter:
    """Counts XLA backend compiles via ``jax.monitoring`` events."""

    def __init__(self):
        self.count = 0
        self.events: List[str] = []

    def _listener(self, event: str, duration: float, **kw) -> None:
        if event == COMPILE_EVENT:
            self.count += 1
            self.events.append(event)

    def __enter__(self) -> "CompileCounter":
        import jax

        jax.monitoring.register_event_duration_secs_listener(
            self._listener)
        return self

    def __exit__(self, *exc) -> None:
        from jax._src import monitoring as _mon

        unreg = getattr(
            _mon, "_unregister_event_duration_listener_by_callback", None)
        if unreg is not None:
            unreg(self._listener)
        else:                                   # pragma: no cover
            _mon.clear_event_listeners()


@contextlib.contextmanager
def no_implicit_transfers():
    """``jax.transfer_guard("disallow")`` when available (on CPU the
    committed-array read path bypasses the guard, so SyncCounter is the
    belt that works everywhere; on real accelerators the guard also
    catches implicit D2H/H2D the counter can't see)."""
    import jax

    guard = getattr(jax, "transfer_guard", None)
    if guard is None:                           # pragma: no cover
        yield
        return
    with guard("disallow"):
        yield


def jit_cache_sizes(fns: Dict[str, Any]) -> Dict[str, int]:
    """``name -> _cache_size()`` for a dict of jitted callables."""
    out: Dict[str, int] = {}
    for name, fn in fns.items():
        size = getattr(fn, "_cache_size", None)
        out[name] = int(size()) if callable(size) else -1
    return out


def _used(fn) -> bool:
    size = getattr(fn, "_cache_size", None)
    return callable(size) and size() > 0


def _engine_executables(eng) -> Dict[str, Any]:
    fns = {f"decode_loop[k={k}]": fn for k, fn in eng._loops.items()}
    for n, fn in getattr(eng, "_spec_loops", {}).items():
        fns[f"spec_loop[n={n}]"] = fn
    fns["prefill_chunk"] = eng._prefill_chunk_fn
    fns["admit"] = eng._admit_fn
    fns["clear_slot"] = eng._clear_slot_fn
    # draft-model speculation executables (present iff a draft model is
    # attached; the draft clear is dispatched at every admission)
    if getattr(eng, "_draft_cache", None) is not None:
        fns["draft_prefill"] = eng._draft_prefill_fn
        fns["draft_clear"] = eng._draft_clear_fn
    # arch-conditional admission executables (enc-dec encode, VLM
    # embed-chunk) — present iff the engine serves that family
    if hasattr(eng, "_encode_slot_fn"):
        fns["encode_slot"] = eng._encode_slot_fn
    if hasattr(eng, "_prefill_embeds_fn"):
        fns["prefill_embeds"] = eng._prefill_embeds_fn
    # robustness executables (cancel / fault-arm / cache poisoners) are
    # dispatched only when a cancel, deadline, or injected fault fires —
    # include them iff they were exercised, so compile-exactly-once
    # stays assertable for happy-path scenarios that never touch them
    # (an untouched jit has cache size 0, which would read as a lie)
    if _used(getattr(eng, "_cancel_fn", None)):
        fns["cancel"] = eng._cancel_fn
    if _used(getattr(eng, "_fault_arm_fn", None)):
        fns["fault_arm"] = eng._fault_arm_fn
    for key, fn in getattr(eng, "_fault_cache_fns", {}).items():
        if _used(fn):
            fns[f"fault[{key[0]}]"] = fn
    return fns


def _drive(eng, prompts, max_new: int, k: int, loops: int,
           frames=None, flush_steps: int = 4):
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=max_new,
                   frames=None if frames is None else frames[i])
    eng._admit()                 # prefill + first-token sampling (syncs
    # here are per-admission and expected; the measured region below is
    # the pure fused loop)
    with no_implicit_transfers(), SyncCounter() as sc, \
            CompileCounter() as cc:
        for _ in range(loops):
            eng.decode_loop(k)
    results = eng.run(max_steps=flush_steps)  # flush stragglers (untimed)
    return results, sc.count, cc.count


_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "collective-permute", "all-to-all")


def collective_report(hlo: str, logits_elems: int) -> Dict:
    """Parse partitioned HLO text for collectives.

    Returns op counts plus every all-gather whose *output* (per-device,
    post-gather) exceeds ``logits_elems`` elements — the sample-point
    logits gather is the largest collective the sharded decode loop is
    allowed; anything bigger is a weight/KV unshard.
    """
    import re

    counts: Dict[str, int] = {}
    oversized: List[str] = []
    lhs = re.compile(r"=\s*(.+?)\s(" + "|".join(_COLLECTIVE_OPS) + r")\(")
    dims_pat = re.compile(r"\[([0-9,]*)\]")
    for line in hlo.splitlines():
        m = lhs.search(line)
        if not m:
            continue
        shape_s, op = m.groups()
        counts[op] = counts.get(op, 0) + 1
        for dm in dims_pat.finditer(shape_s):
            n = 1
            for d in dm.group(1).split(","):
                if d:
                    n *= int(d)
            if op == "all-gather" and n > logits_elems:
                oversized.append(f"{op} -> {shape_s.strip()}")
                break
    return {"counts": counts, "oversized_gathers": oversized}


def sanitize_serving(kv_format: Optional[str] = None,
                     weight_format: Optional[str] = None,
                     arch: str = "gptneox-1b", mesh=None) -> Dict:
    """Scripted serving scenario under the full sanitizer stack.

    Two passes of the same script: a warm-up pass that is *allowed* to
    compile, then a measured pass (after ``reset()``, which keeps the
    executables) in which every compile and every implicit sync is a
    finding.  ``arch`` selects the family — every arch runs the same
    fused loop + chunked prefill protocol, so the SSM (``mamba2-2.7b``)
    and enc-dec (``seamless-m4t-medium``) scenarios assert the identical
    compile-once / zero-sync discipline, including the enc-dec
    ``encode_slot`` admission executable.  Returns a report dict; the
    tier-1 test asserts on it.

    ``mesh``: run the scenario through the mesh-native engine.  The
    compile-once / zero-implicit-transfer assertions are identical (the
    engine's ``out_shardings``-pinned executables must not trigger
    resharding recompiles, and slot admission must not introduce
    cross-device host syncs); additionally the fused loop's partitioned
    HLO is parsed for collectives — see ``collective_report`` — and the
    report's ``no_oversized_gathers`` proves nothing larger than the
    sample-point logits gather appears in the scan.
    """
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import ServeEngine
    from repro.serve.quant import quantize_tree

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    k, loops = 4, 2
    prompts = [[1, 2, 3, 4], [5, 6, 7, 8]]
    max_new = 1 + k * loops          # admit token + exactly `loops` K-blocks

    frames = None
    if cfg.is_encoder_decoder:
        # deterministic per-request source frames (warm/measured token
        # match requires bit-identical inputs across the two passes)
        frames = [0.02 * np.sin(np.arange(6 * cfg.d_model, dtype=np.float32)
                                + i).reshape(6, cfg.d_model)
                  for i in range(len(prompts))]

    eng = ServeEngine(model, params, batch=2, max_seq=64,
                      kv_format=kv_format, weight_format=weight_format,
                      decode_block=k, prefill_chunk=4, mesh=mesh)

    warm_results, _, warm_compiles = _drive(eng, prompts, max_new, k,
                                            loops, frames=frames)

    eng.reset()
    results, loop_syncs, loop_compiles = _drive(
        eng, prompts, max_new, k, loops, frames=frames)

    cache_sizes = jit_cache_sizes(_engine_executables(eng))

    # satellite probe: quantize_tree's host-sync bill (the PR-6 fix
    # accumulates MSE/byte stats on device and syncs once per tree)
    with SyncCounter() as qc:
        quantize_tree(params, "float4_e2m1fn", packed=True)
    n_leaves = len(jax.tree_util.tree_leaves(params))

    wd = eng.watchdog_report()
    report = {
        "arch": arch,
        "kv_format": kv_format or "none",
        "warm_compiles": warm_compiles,
        "measured_compiles": loop_compiles,
        "measured_loop_syncs": loop_syncs,
        "compile_cache_sizes": cache_sizes,
        "compiled_exactly_once": all(
            v == 1 for v in cache_sizes.values()),
        "zero_implicit_loop_transfers": loop_compiles == 0
        and loop_syncs == 0,
        "tokens_match_warmup": (
            [r.tokens for r in results]
            == [r.tokens for r in warm_results]),
        "watchdog_ok": wd["ok"],
        "watchdog_findings": wd["findings"],
        "quantize_tree_syncs": qc.count,
        "quantize_tree_leaves": n_leaves,
    }

    if mesh is not None:
        # collective half: lower the fused loop (cache hit — it already
        # compiled once above; AOT lowering does not touch the jit
        # dispatch cache the compile-once assertion reads) and parse the
        # partitioned HLO.  The logits gather (batch × vocab, the
        # sample point) is the ceiling.
        hlo = eng._loops[k].lower(
            eng.params, eng.cache, eng.state,
            eng._sample_key).compile().as_text()
        coll = collective_report(hlo, logits_elems=eng.batch
                                 * cfg.vocab_size)
        report["mesh"] = "x".join(str(s) for s in mesh.devices.shape)
        report["loop_collectives"] = coll["counts"]
        report["oversized_gathers"] = coll["oversized_gathers"]
        report["no_oversized_gathers"] = not coll["oversized_gathers"]
    else:
        report["mesh"] = "none"
    return report


def sanitize_spec(kv_format: Optional[str] = None,
                  arch: str = "gptneox-1b", draft_tokens: int = 3) -> Dict:
    """Speculative serving scenario under the full sanitizer stack.

    Same two-pass discipline as :func:`sanitize_serving`, but the engine
    decodes through the speculative draft→verify→commit loop.  The
    report proves (a) the speculative executables (spec loop, admit with
    n-gram seeding) compile exactly once, (b) the fused speculative
    dispatches perform zero implicit host transfers — drafting, chunk
    sampling, acceptance, and commit are all device-resident — and
    (c) the emitted streams are token-identical to a NON-speculative
    engine run over the same requests (the differential conformance
    claim, asserted inside the sanitizer scenario too)."""
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import ServeEngine
    from repro.serve.spec import SpecConfig

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    k, loops = 4, 2
    prompts = [[1, 2, 3, 4, 1, 2, 3, 4], [5, 6, 7, 5, 6, 7]]
    max_new = 1 + k * loops

    eng = ServeEngine(model, params, batch=2, max_seq=64,
                      kv_format=kv_format, decode_block=k,
                      prefill_chunk=4,
                      spec=SpecConfig(draft_tokens=draft_tokens,
                                      ngram_table=128))
    warm_results, _, warm_compiles = _drive(eng, prompts, max_new, k,
                                            loops, flush_steps=64)
    eng.reset()
    results, loop_syncs, loop_compiles = _drive(eng, prompts, max_new,
                                                k, loops, flush_steps=64)
    cache_sizes = jit_cache_sizes(_engine_executables(eng))

    ref = ServeEngine(model, params, batch=2, max_seq=64,
                      kv_format=kv_format, decode_block=k,
                      prefill_chunk=4)
    ref_results, _, _ = _drive(ref, prompts, max_new, k, loops,
                               flush_steps=64)
    by_id = lambda rs: {r.request_id: r.tokens for r in rs}

    return {
        "arch": arch,
        "kv_format": kv_format or "none",
        "draft_tokens": draft_tokens,
        "warm_compiles": warm_compiles,
        "measured_compiles": loop_compiles,
        "measured_loop_syncs": loop_syncs,
        "compile_cache_sizes": cache_sizes,
        "compiled_exactly_once": all(
            v == 1 for v in cache_sizes.values()),
        "zero_implicit_loop_transfers": loop_compiles == 0
        and loop_syncs == 0,
        "tokens_match_warmup": (
            [r.tokens for r in results]
            == [r.tokens for r in warm_results]),
        "tokens_match_nonspec": by_id(results) == by_id(ref_results),
        "spec_report": eng.spec_report(),
    }


def sanitize_robust(kv_format: Optional[str] = None,
                    arch: str = "gptneox-1b") -> Dict:
    """Robust-serving scenario under the sanitizer stack: admission
    shedding, deadline expiry, in-flight cancellation, and fault
    injection + recovery, all in one scripted pass.

    Same two-pass discipline as :func:`sanitize_serving` — a warm-up
    pass that may compile, then a measured pass after ``reset()`` in
    which ANY compile is a finding.  This is the compile-once proof for
    the robustness executables (cancel / fault-arm / cache poisoner):
    they join ``compile_cache_sizes`` once exercised, and the measured
    pass shows that cancelling, expiring, and faulting requests reuses
    the warm executables bit-for-bit.  The report also carries the
    exact-accounting identity (submitted = ok + truncated + shed +
    deadline_exceeded + faulted) and the watchdog verdict.
    """
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.admission import AdmissionConfig
    from repro.serve.engine import ServeEngine

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    k = 4
    eng = ServeEngine(model, params, batch=2, max_seq=64,
                      kv_format=kv_format, decode_block=k,
                      prefill_chunk=4,
                      admission=AdmissionConfig(queue_limit=8))
    clock = [0.0]
    eng.set_clock(lambda: clock[0])
    # kv_format engines exercise a cache poisoner; dense engines the
    # in-loop logits injector — both end in status="faulted"
    fault_kind = "e8m0_overflow" if kv_format else "logits_nan"

    def script():
        eng.reset()
        eng.set_clock(lambda: clock[0])
        a = eng.submit([1, 2, 3, 4], max_new_tokens=1 + 2 * k)
        b = eng.submit([5, 6, 7, 8], max_new_tokens=1 + 2 * k)
        c = eng.submit([2, 4, 6], max_new_tokens=1 + 8 * k,
                       deadline_ms=100)
        d = eng.submit([9, 8, 7], max_new_tokens=1 + k)
        eng.decode_loop(k)                 # admits a, b
        eng.inject_fault(a, fault_kind)
        eng.cancel(b)                      # in-flight cancel state-write
        eng.decode_loop(k)                 # sentinel trips a
        clock[0] += 10.0                   # c expires while still queued
        results = eng.run(max_steps=64)    # admits d -> ok
        return {r.request_id: r.status for r in results}

    with CompileCounter() as warm_cc:
        warm_statuses = script()
    # measured pass: only the compile counter wraps the WHOLE script —
    # admission/cancel host reads are designed syncs (the per-loop
    # zero-sync discipline is sanitize_serving's assertion); what must
    # hold here is that the robustness paths reuse warm executables
    with CompileCounter() as cc:
        statuses = script()

    cache_sizes = jit_cache_sizes(_engine_executables(eng))
    acc = eng.accounting()
    wd = eng.watchdog_report()
    return {
        "arch": arch,
        "kv_format": kv_format or "none",
        "fault_kind": fault_kind,
        "warm_compiles": warm_cc.count,
        "measured_compiles": cc.count,
        "compile_cache_sizes": cache_sizes,
        "compiled_exactly_once": all(
            v == 1 for v in cache_sizes.values()),
        "statuses": sorted(statuses.values()),
        "statuses_match_warmup": (sorted(statuses.values())
                                  == sorted(warm_statuses.values())),
        "accounting": acc,
        "accounting_balanced": bool(acc["balanced"]),
        "watchdog_ok": wd["ok"],
        "watchdog_findings": wd["findings"],
    }
