"""Pallas write-race / aliasing / VMEM checker (layer 3).

Pallas semantics make one class of bug uniquely silent: two grid
instances on *parallel* dimensions whose output ``index_map``s resolve
to the same block are a write race — on the interpreter (how this repo
runs off-TPU) the sequential emulation quietly picks a winner, so tests
pass and the kernel is wrong only on real hardware.  Revisiting an
output block across *sequential* ("arbitrary") dimensions is the legal
accumulator pattern (``qmatmul``'s k-loop, ``ssd_scan``'s state
emission), so the checker needs real semantics, not a grep.

Two cooperating passes:

* :func:`pallas_call_sites` — AST enumeration of every
  ``compat.pallas_call`` / ``pl.pallas_call`` site under ``kernels/``
  (coverage denominator: a driver must exercise each one).
* :func:`capture` — monkeypatches :func:`repro.compat.pallas_call` to
  record each call's grid / BlockSpecs / aliases / scratch and return a
  stand-in producing zeros of ``out_shape``, so wrapper-level shape
  logic runs but no kernel executes.  ``index_map``s are then evaluated
  over the concrete grid, which is the only honest way to check them
  (they are lambdas, not data).

Checks per captured site:

``PC201 write-race``       two grid points with different parallel
                           coordinates write the same output block.
``PC202 unsound-alias``    ``input_output_aliases`` pairs operands of
                           mismatched shape/dtype, or the aliased
                           input's blocks don't track the output's.
``PC203 vmem-overflow``    per-grid-step block + scratch bytes exceed
                           :func:`repro.compat.vmem_budget_bytes`.
``PC200 uncovered-site``   a ``pallas_call`` in the source was never
                           exercised by any driver (coverage hole).
"""

from __future__ import annotations

import ast
import contextlib
import dataclasses
import itertools
import os
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.lint import Finding

_GRID_POINT_CAP = 65536


@dataclasses.dataclass
class PallasSite:
    """One recorded ``compat.pallas_call`` invocation."""
    path: str                 # wrapper source file
    line: int                 # line of the pallas_call site
    scope: str                # wrapper function qualname
    grid: Tuple[int, ...]
    in_specs: Sequence[Any]
    out_specs: Sequence[Any]          # normalised to a list
    out_shapes: Sequence[Any]         # jax.ShapeDtypeStruct, same arity
    multi_out: bool
    dimension_semantics: Optional[Tuple[str, ...]]
    input_output_aliases: Dict[int, int]
    scratch_shapes: Sequence[Any]
    arg_shapes: Sequence[Tuple[Tuple[int, ...], Any]] = ()

    def describe(self) -> str:
        return (f"{self.scope} grid={self.grid} "
                f"semantics={self.dimension_semantics}")


# ---------------------------------------------------------------------------
# AST coverage pass


def pallas_call_sites(paths: Sequence[str]) -> List[Tuple[str, int, str]]:
    """(path, line, enclosing function) for every pallas_call site."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            files.extend(os.path.join(p, f) for f in sorted(os.listdir(p))
                         if f.endswith(".py"))
        else:
            files.append(p)
    sites: List[Tuple[str, int, str]] = []
    for fp in files:
        with open(fp, "r", encoding="utf-8") as fh:
            tree = ast.parse(fh.read())
        stack: List[Tuple[ast.AST, str]] = [(tree, "<module>")]
        scopes: Dict[int, str] = {}

        def walk(node, scope):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                scope = node.name
            if isinstance(node, ast.Call):
                func = node.func
                name = func.attr if isinstance(func, ast.Attribute) else \
                    getattr(func, "id", "")
                if name == "pallas_call":
                    sites.append((fp, node.lineno, scope))
            for child in ast.iter_child_nodes(node):
                walk(child, scope)

        walk(tree, "<module>")
        del stack, scopes
    return sites


# ---------------------------------------------------------------------------
# capture harness


def _as_list(x) -> List[Any]:
    if x is None:
        return []
    if isinstance(x, (list, tuple)):
        return list(x)
    return [x]


@contextlib.contextmanager
def capture() -> Iterator[List[PallasSite]]:
    """Record every ``compat.pallas_call`` made inside the block.

    The patched call returns a stand-in that yields zeros of
    ``out_shape`` so wrapper post-processing (reshape, slicing) still
    runs; no kernel body executes.
    """
    import inspect

    import jax.numpy as jnp

    from repro import compat

    sites: List[PallasSite] = []
    real = compat.pallas_call

    def fake_pallas_call(kernel, *, interpret=None,
                         dimension_semantics=None, compiler_params=None,
                         **kwargs):
        caller = inspect.stack()[1]
        out_shape = kwargs.get("out_shape")
        multi = isinstance(out_shape, (list, tuple))
        site = PallasSite(
            path=caller.filename,
            line=caller.lineno,
            scope=caller.function,
            grid=tuple(kwargs.get("grid", ()) or ()),
            in_specs=_as_list(kwargs.get("in_specs")),
            out_specs=_as_list(kwargs.get("out_specs")),
            out_shapes=_as_list(out_shape),
            multi_out=multi,
            dimension_semantics=(tuple(dimension_semantics)
                                 if dimension_semantics else None),
            input_output_aliases=dict(
                kwargs.get("input_output_aliases") or {}),
            scratch_shapes=_as_list(kwargs.get("scratch_shapes")),
        )

        def run(*arrays):
            site.arg_shapes = tuple(
                (tuple(a.shape), a.dtype) for a in arrays)
            sites.append(site)
            outs = [jnp.zeros(s.shape, s.dtype) for s in site.out_shapes]
            return outs if multi else outs[0]

        return run

    compat.pallas_call = fake_pallas_call
    try:
        yield sites
    finally:
        compat.pallas_call = real


# ---------------------------------------------------------------------------
# checks


def _block_shape(spec) -> Optional[Tuple[Optional[int], ...]]:
    shape = getattr(spec, "block_shape", None)
    return tuple(shape) if shape is not None else None


def _index_map(spec) -> Optional[Callable]:
    return getattr(spec, "index_map", None)


def _grid_points(grid: Tuple[int, ...]) -> Tuple[List[Tuple[int, ...]], bool]:
    total = 1
    for g in grid:
        total *= int(g)
    pts = itertools.product(*(range(int(g)) for g in grid))
    if total <= _GRID_POINT_CAP:
        return list(pts), False
    return list(itertools.islice(pts, _GRID_POINT_CAP)), True


def _semantics(site: PallasSite) -> Tuple[str, ...]:
    if site.dimension_semantics is not None:
        return site.dimension_semantics
    # No declared semantics: Mosaic may parallelise any grid dimension,
    # so the only safe assumption for race checking is all-parallel.
    return tuple("parallel" for _ in site.grid)


def _finding(site: PallasSite, rule: str, msg: str) -> Finding:
    return Finding(path=site.path, line=site.line, col=1, rule=rule,
                   message=msg, context=site.scope)


def _nbytes(shape, dtype) -> int:
    n = 1
    for s in shape:
        if s is not None:
            n *= int(s)
    return n * np.dtype(dtype).itemsize


def check_sites(sites: Sequence[PallasSite],
                vmem_budget: Optional[int] = None) -> List[Finding]:
    from repro import compat

    budget = vmem_budget if vmem_budget is not None \
        else compat.vmem_budget_bytes()
    findings: List[Finding] = []
    for site in sites:
        findings.extend(_check_write_races(site))
        findings.extend(_check_aliases(site))
        findings.extend(_check_vmem(site, budget))
    return findings


def _check_write_races(site: PallasSite) -> List[Finding]:
    out: List[Finding] = []
    if not site.grid:
        return out
    sem = _semantics(site)
    par_axes = [i for i, s in enumerate(sem) if s == "parallel"]
    if not par_axes:
        return out
    points, truncated = _grid_points(site.grid)
    for oi, spec in enumerate(site.out_specs):
        imap = _index_map(spec)
        if imap is None:
            continue
        writers: Dict[Tuple, Tuple] = {}   # block idx -> parallel coords
        raced = False
        for p in points:
            try:
                blk = imap(*p)
            except Exception as exc:   # index_map arity mismatch etc.
                out.append(_finding(
                    site, "PC201",
                    f"output {oi} index_map raised {exc!r} at grid "
                    f"point {p} (arity/grid mismatch)"))
                raced = True
                break
            blk = tuple(blk) if isinstance(blk, tuple) else (blk,)
            par = tuple(p[a] for a in par_axes)
            prev = writers.get(blk)
            if prev is None:
                writers[blk] = par
            elif prev != par:
                out.append(_finding(
                    site, "PC201",
                    f"write race on output {oi}: grid points with "
                    f"parallel coords {prev} and {par} both write "
                    f"block {blk} (grid={site.grid}, "
                    f"semantics={sem}); make the racing dimension "
                    "'arbitrary' or give each instance its own block"))
                raced = True
                break
        if raced:
            continue
        if truncated:
            out.append(_finding(
                site, "PC201",
                f"grid {site.grid} exceeds {_GRID_POINT_CAP} points; "
                f"race check for output {oi} covered only a prefix — "
                "shrink the driver shapes"))
    return out


def _check_aliases(site: PallasSite) -> List[Finding]:
    out: List[Finding] = []
    if not site.input_output_aliases:
        return out
    points, _ = _grid_points(site.grid) if site.grid else ([()], False)
    for ii, oi in site.input_output_aliases.items():
        if ii >= len(site.arg_shapes) or oi >= len(site.out_shapes):
            out.append(_finding(
                site, "PC202",
                f"input_output_aliases maps input {ii} -> output {oi} "
                f"but the call has {len(site.arg_shapes)} inputs / "
                f"{len(site.out_shapes)} outputs"))
            continue
        in_shape, in_dtype = site.arg_shapes[ii]
        o = site.out_shapes[oi]
        if tuple(o.shape) != in_shape or np.dtype(o.dtype) != \
                np.dtype(in_dtype):
            out.append(_finding(
                site, "PC202",
                f"unsound alias input {ii} -> output {oi}: shapes/"
                f"dtypes differ ({in_shape}/{in_dtype} vs "
                f"{tuple(o.shape)}/{o.dtype}) — donation would "
                "reinterpret the buffer"))
            continue
        in_spec = site.in_specs[ii] if ii < len(site.in_specs) else None
        out_spec = site.out_specs[oi] if oi < len(site.out_specs) else None
        in_map, out_map = _index_map(in_spec), _index_map(out_spec)
        if in_map is None or out_map is None:
            continue
        if _block_shape(in_spec) != _block_shape(out_spec):
            out.append(_finding(
                site, "PC202",
                f"unsound alias input {ii} -> output {oi}: block "
                f"shapes differ ({_block_shape(in_spec)} vs "
                f"{_block_shape(out_spec)}) — in-place blocks must "
                "coincide"))
            continue
        for p in points:
            try:
                if tuple(np.ravel(in_map(*p))) != \
                        tuple(np.ravel(out_map(*p))):
                    out.append(_finding(
                        site, "PC202",
                        f"unsound alias input {ii} -> output {oi}: at "
                        f"grid point {p} the input block "
                        f"{in_map(*p)} != output block {out_map(*p)} — "
                        "the kernel would read memory the alias "
                        "already overwrote"))
                    break
            except Exception:
                break
    return out


def _check_vmem(site: PallasSite, budget: int) -> List[Finding]:
    total = 0
    parts: List[str] = []

    def add(label, shape, dtype):
        nonlocal total
        b = _nbytes(shape, dtype)
        total += b
        if b:
            parts.append(f"{label}={b}")

    for i, spec in enumerate(site.in_specs):
        shape = _block_shape(spec)
        if shape is None:
            if i < len(site.arg_shapes):
                shape = site.arg_shapes[i][0]
            else:
                continue
        dtype = site.arg_shapes[i][1] if i < len(site.arg_shapes) \
            else np.float32
        # None entries in a block shape mean "not blocked over" and
        # occupy the full axis only when taken from arg shape; treat
        # None as 1 (conservatively small) — packed sub-byte storage is
        # already reflected in the uint8 arg dtype, so bytes are true
        # storage bytes.
        add(f"in{i}", shape, dtype)
    for oi, spec in enumerate(site.out_specs):
        shape = _block_shape(spec)
        if shape is None and oi < len(site.out_shapes):
            shape = tuple(site.out_shapes[oi].shape)
        dtype = site.out_shapes[oi].dtype if oi < len(site.out_shapes) \
            else np.float32
        add(f"out{oi}", shape or (), dtype)
    for si, scr in enumerate(site.scratch_shapes):
        shape = getattr(scr, "shape", None)
        dtype = getattr(scr, "dtype", np.float32)
        if shape is not None:
            add(f"scratch{si}", tuple(shape), dtype)
    if total > budget:
        return [_finding(
            site, "PC203",
            f"per-grid-step VMEM footprint {total} bytes "
            f"({', '.join(parts)}) exceeds budget {budget} bytes — "
            "shrink block sizes (double-buffering needs headroom on "
            "top of this)")]
    return []


# ---------------------------------------------------------------------------
# repo drivers: exercise every kernels/ pallas_call with tiny shapes


def _repo_driver_sites() -> List[PallasSite]:
    import importlib

    import jax.numpy as jnp

    from repro import lowbits

    # repro.kernels.__init__ shadows submodule names with the jitted ops
    # wrappers — resolve the submodules via importlib
    mod = lambda name: importlib.import_module(f"repro.kernels.{name}")
    flash_attention, flash_decode = mod("flash_attention"), mod("flash_decode")
    probe_chase, probe_dep_chain = mod("probe_chase"), mod("probe_dep_chain")
    probe_mma, qmatmul, ssd_scan = (mod("probe_mma"), mod("qmatmul"),
                                    mod("ssd_scan"))

    with capture() as sites:
        # flash attention: b=1, hq=4, hkv=2 exercises the GQA map
        q = jnp.zeros((1, 4, 8, 8), jnp.float32)
        kv = jnp.zeros((1, 2, 12, 8), jnp.float32)
        flash_attention.flash_attention_bhsd(q, kv, kv, bq=4, bk=4)

        # flash decode, container KV
        qd = jnp.zeros((2, 4, 8), jnp.float32)
        kc = jnp.zeros((2, 2, 8, 8), jnp.float32)
        sp = jnp.zeros((2, 8), jnp.int32)
        pos = jnp.zeros((2,), jnp.int32)
        flash_decode.flash_decode_bhd(qd, kc, kc, sp, pos, bk=4)

        # flash decode, packed fp4 KV (+ e8m0 scales)
        ps = lowbits.packed_spec("float4_e2m1fn")
        d = 8
        stored = d // ps.values_per_group * ps.bytes_per_group
        kq = jnp.zeros((2, 2, 8, stored), jnp.uint8)
        ks = jnp.zeros((2, 2, 8, 1), jnp.uint8)
        flash_decode.flash_decode_quant_bhd(
            qd, kq, ks, kq, ks, sp, pos, fmt="float4_e2m1fn", bk=4)

        # qmatmul, container + packed (BLOCK=32 scale granularity)
        x = jnp.zeros((128, 64), jnp.float32)
        qw = jnp.zeros((128, 64), jnp.float32)
        sc = jnp.zeros((128, 64 // 32), jnp.float32)
        qmatmul.qmatmul_mkn(x, qw, sc, bm=64, bn=64, bk=32)
        pw = jnp.zeros((128, 64 // 2), jnp.uint8)
        qmatmul.qmatmul_packed_mkn(x, pw, sc, "float4_e2m1fn", bm=64, bn=64, bk=32)

        # ssd scan (sequential chunk axis + last-chunk state emission),
        # fresh AND carried-state entry (the chunked-prefill seed adds a
        # (1,1,p,n) broadcast-read input block — check both signatures)
        xs = jnp.zeros((2, 2, 8, 4), jnp.float32)
        da = jnp.zeros((2, 2, 8), jnp.float32)
        bc = jnp.zeros((2, 8, 4), jnp.float32)
        ssd_scan.ssd_scan_bhsp(xs, da, bc, bc, chunk=4)
        h0 = jnp.zeros((2, 2, 4, 4), jnp.float32)
        ssd_scan.ssd_scan_bhsp(xs, da, bc, bc, chunk=4, initial_state=h0)

        # probes
        probe_mma.mma_probe(jnp.zeros((1, 8, 8), jnp.float32),
                            jnp.zeros((8, 8), jnp.float32),
                            bm=8, bn=8, bk=8, ilp=1)
        probe_chase.chase(jnp.zeros((8, 128), jnp.int32), steps=2)
        probe_dep_chain.dep_chain(jnp.zeros((1, 8, 128), jnp.float32),
                                  chain_len=2)
    return sites


def check_kernels(kernels_dir: Optional[str] = None,
                  vmem_budget: Optional[int] = None) -> List[Finding]:
    """Drive every kernel wrapper under capture and check all sites.

    Also cross-checks coverage: each ``pallas_call`` found by AST in
    ``kernels_dir`` must have been exercised (PC200 otherwise).
    """
    import repro.kernels as _k

    kernels_dir = kernels_dir or os.path.dirname(_k.__file__)
    sites = _repo_driver_sites()
    findings = check_sites(sites, vmem_budget=vmem_budget)
    exercised = {(os.path.abspath(s.path), s.line) for s in sites}
    for path, line, scope in pallas_call_sites([kernels_dir]):
        if (os.path.abspath(path), line) not in exercised:
            findings.append(Finding(
                path=path, line=line, col=1, rule="PC200",
                message=(f"pallas_call in {scope} is not exercised by "
                         "any analysis driver — add one to "
                         "repro.analysis.pallas_check._repo_driver_sites"),
                context=scope))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
