"""jaxpr contract checks for the hot entry points (layer 2).

The Tab IV/V/VII/VIII artifacts all rest on one claim: a packed
fp4/fp6/e8m0 buffer is streamed from HBM at its *stored* width and only
expanded on the VMEM tile (or in the jnp twin's bitwise unpack).  One
stray ``convert_element_type`` on the packed payload before that expand
— an `.astype(f32)` slipped into a wrapper, an implicit promotion — and
every bytes/elem number the repo reports is silently measuring dense
traffic.  That is invisible to tests (values stay bit-exact) and
invisible at runtime (nothing crashes); it is only visible in the
jaxpr.  So we check the jaxpr.

``CT301 packed-upcast``    float ``convert_element_type`` applied to a
                           still-packed payload buffer.  Taint starts on
                           the uint8 code leaves (``k_q``/``v_q``/packed
                           weights — *not* the e8m0 scale leaves, whose
                           direct ``astype(f32)`` in ``e8m0_decode`` is
                           the legitimate decode), flows through layout
                           ops and integer converts, and is *consumed*
                           by bitwise ops (the unpack has begun) or by
                           entering a ``pallas_call`` (the in-kernel
                           expand).
``CT302 host-callback``    ``pure_callback``/``io_callback``/
                           ``debug_callback``/``debug_print`` surviving
                           in a hot path: each is a host round trip per
                           dispatch.
``CT303 cache-width``      a quantized-cache entry point whose output
                           cache leaves widen beyond their uint8
                           storage (checked via ``jax.eval_shape``).

:func:`check_entry_points` wires these to the serving hot paths named
in the ROADMAP: ``lm_decode_step``, the fused ``decode_loop`` scan
body (which now carries the fault injector + non-finite sentinel),
``lm_prefill_chunk``, the speculative leg (``lm_verify_chunk`` /
``lm_commit_chunk`` and the fused ``spec_loop`` scan body),
``qmatmul_packed``, ``flash_decode_quant``, and the robustness
state-writes (``cancel_update``/``fault_arm_update``) plus the cache
poisoners from ``repro.serve.faults``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.lint import Finding

# taint flows through these unchanged (layout/reindexing only)
_LAYOUT_PRIMS = {
    "reshape", "transpose", "squeeze", "expand_dims", "broadcast_in_dim",
    "slice", "dynamic_slice", "dynamic_update_slice", "concatenate",
    "rev", "gather", "scatter", "pad", "copy", "select_n", "tile",
    "device_put", "split", "stop_gradient", "squeeze_p",
    "sharding_constraint",       # GSPMD placement hint: bytes unchanged
}
# reaching one of these means the in-register expand has begun: the
# payload is no longer "packed bytes pretending to be dense"
_EXPAND_PRIMS = {
    "and", "or", "xor", "not", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "rem", "div",
}
_CALLBACK_PRIMS = {"infeed", "outfeed"}


def _is_callback(prim_name: str) -> bool:
    return ("callback" in prim_name or prim_name == "debug_print"
            or prim_name in _CALLBACK_PRIMS)


def _sub_jaxprs(eqn) -> List[Any]:
    """Inner jaxprs of a higher-order eqn (scan/while/cond/pjit/...)."""
    subs: List[Any] = []
    for val in eqn.params.values():
        items = val if isinstance(val, (list, tuple)) else [val]
        for item in items:
            inner = getattr(item, "jaxpr", item)
            if hasattr(inner, "eqns") and hasattr(inner, "invars"):
                subs.append(inner)
    return subs


def _align(inner_vars, outer_vars):
    """Best-effort positional pairing.  Exact for pjit/scan/closed_call
    (arity matches); for while/cond the carries sit at the end, so align
    from the tail."""
    n = min(len(inner_vars), len(outer_vars))
    if n == 0:
        return []
    return list(zip(inner_vars[-n:], outer_vars[-n:]))


def _is_float(dtype) -> bool:
    import numpy as np
    return np.issubdtype(np.dtype(dtype), np.floating)


def upcast_findings(closed_jaxpr, tainted_invar_idx: Sequence[int],
                    label: str) -> List[Finding]:
    """CT301: float converts on still-packed payload vars."""
    import jax.core as core
    try:
        Literal = core.Literal
    except AttributeError:                      # newer layouts
        from jax._src.core import Literal

    findings: List[Finding] = []
    jaxpr = closed_jaxpr.jaxpr

    def walk(jx, taint: Set[Any], scope: str):
        for eqn in jx.eqns:
            prim = eqn.primitive.name
            tainted_in = [v for v in eqn.invars
                          if not isinstance(v, Literal) and v in taint]
            if prim == "pallas_call":
                continue                    # the sanctioned expand
            if prim == "convert_element_type":
                if tainted_in:
                    new = eqn.params.get("new_dtype")
                    if _is_float(new):
                        findings.append(Finding(
                            path=f"<jaxpr:{label}>", line=0, col=0,
                            rule="CT301",
                            message=(
                                f"packed payload upcast to {new} before "
                                f"its expand (in {scope}): the buffer "
                                "is now dense — every bytes/elem claim "
                                "downstream of this entry point is "
                                "measuring full-width traffic"),
                            context=scope))
                    else:
                        taint.update(eqn.outvars)
                continue
            if prim in _EXPAND_PRIMS:
                continue                    # unpack has begun: consume
            subs = _sub_jaxprs(eqn)
            if subs:
                for sub in subs:
                    sub_taint = {iv for iv, ov in
                                 _align(sub.invars, eqn.invars)
                                 if not isinstance(ov, Literal)
                                 and ov in taint}
                    walk(sub, sub_taint, f"{scope}/{prim}")
                    for iv, ov in _align(sub.outvars, eqn.outvars):
                        if not isinstance(iv, Literal) and iv in sub_taint:
                            taint.add(ov)
                continue
            if prim in _LAYOUT_PRIMS and tainted_in:
                taint.update(eqn.outvars)

    taint0 = {jaxpr.invars[i] for i in tainted_invar_idx
              if i < len(jaxpr.invars)}
    walk(jaxpr, taint0, label)
    return findings


def callback_findings(closed_jaxpr, label: str) -> List[Finding]:
    """CT302: host callbacks / debug prints anywhere in the jaxpr."""
    findings: List[Finding] = []
    seen: Set[int] = set()

    def walk(jx, scope: str):
        if id(jx) in seen:
            return
        seen.add(id(jx))
        for eqn in jx.eqns:
            prim = eqn.primitive.name
            if _is_callback(prim):
                findings.append(Finding(
                    path=f"<jaxpr:{label}>", line=0, col=0, rule="CT302",
                    message=(f"host callback `{prim}` in hot path "
                             f"({scope}): one host round trip per "
                             "dispatch — remove it or move it out of "
                             "the traced region"),
                    context=scope))
            for sub in _sub_jaxprs(eqn):
                walk(sub, f"{scope}/{prim}")

    walk(closed_jaxpr.jaxpr, label)
    return findings


# ---------------------------------------------------------------------------
# tainted-leaf discovery


_PAYLOAD_KEYS = ("'k_q'", "'v_q'")


def payload_invar_indices(args: Tuple[Any, ...],
                          extra_keys: Sequence[str] = ()) -> List[int]:
    """Flattened-arg indices of packed payload leaves (``k_q``/``v_q``
    code buffers) — the taint seeds for :func:`upcast_findings`.

    Scale leaves (``k_s``/``v_s``) are deliberately *not* seeded:
    ``e8m0_decode`` converts scale codes straight to float and that is
    the decode, not a leak.
    """
    import jax

    flat = jax.tree_util.tree_flatten_with_path(args)[0]
    keys = tuple(_PAYLOAD_KEYS) + tuple(extra_keys)
    out = []
    for i, (path, _leaf) in enumerate(flat):
        s = jax.tree_util.keystr(path)
        if any(k in s for k in keys):
            out.append(i)
    return out


def contract_findings(fn: Callable, args: Tuple[Any, ...], label: str,
                      tainted_idx: Optional[Sequence[int]] = None
                      ) -> List[Finding]:
    """Trace ``fn(*args)`` and run CT301 + CT302 over the jaxpr."""
    import jax

    closed = jax.make_jaxpr(fn)(*args)
    if tainted_idx is None:
        tainted_idx = payload_invar_indices(args)
    out = upcast_findings(closed, tainted_idx, label)
    out += callback_findings(closed, label)
    return out


def cache_width_findings(fn: Callable, args: Tuple[Any, ...], label: str,
                         cache_out_index: int = 1) -> List[Finding]:
    """CT303: quantized cache leaves must come back at storage width."""
    import jax
    import numpy as np

    shapes = jax.eval_shape(fn, *args)
    outs = shapes if isinstance(shapes, tuple) else (shapes,)
    if cache_out_index >= len(outs):
        return []
    cache_out = outs[cache_out_index]
    findings: List[Finding] = []
    flat = jax.tree_util.tree_flatten_with_path(cache_out)[0]
    quant_keys = ("'k_q'", "'v_q'", "'k_s'", "'v_s'")
    for path, leaf in flat:
        s = jax.tree_util.keystr(path)
        if any(k in s for k in quant_keys) and \
                np.dtype(leaf.dtype) != np.dtype(np.uint8):
            findings.append(Finding(
                path=f"<eval_shape:{label}>", line=0, col=0, rule="CT303",
                message=(f"quantized cache leaf {s} leaves {label} as "
                         f"{leaf.dtype}, not uint8 storage — the cache "
                         "has silently widened"),
                context=label))
    return findings


# ---------------------------------------------------------------------------
# the repo's named hot entry points


def check_entry_points(kv_format: str = "float4_e2m1fn",
                       mesh: Any = "auto") -> List[Finding]:
    """Contract-check the serving hot paths on tiny quantized configs.

    Covers: ``lm_decode_step`` (via ``model.decode_step``), the fused
    ``decode_loop`` scan body, ``lm_prefill_chunk``, ``qmatmul_packed``,
    ``flash_decode_quant`` — on an attention arch AND on the hybrid
    (SSM-state) and enc-dec (slot-resident ``enc_out`` + quantized
    cross-KV, via ``lm_encode_slot``) families, which run the same
    slot-state protocol.  Pure tracing — nothing executes.

    ``mesh``: the *sharded* engine's entry points join the check — the
    mesh-native jit wrappers (``out_shardings`` pinned to the serving
    rules, sample-point ``with_sharding_constraint`` in the loop body)
    must not introduce a packed upcast or a host callback either.
    ``"auto"`` (default) builds a (1, 1) ('data', 'model') mesh so the
    sharded trace runs on single-device CI; pass a real ``Mesh`` to
    trace the actual TP partitioning, or ``None`` to skip.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.kernels import ops
    from repro.models import build_model
    from repro.serve.engine import ServeEngine

    findings: List[Finding] = []

    cfg = dataclasses.replace(get_config("gptneox-1b").reduced(),
                              kv_format=kv_format)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch, max_seq = 2, 32
    cache = model.init_cache(batch, max_seq)
    token = jnp.zeros((batch,), jnp.int32)
    pos = jnp.ones((batch,), jnp.int32)
    active = jnp.ones((batch,), bool)

    findings += contract_findings(
        lambda p, c, t, q, a: model.decode_step(p, c, t, q, active=a),
        (params, cache, token, pos, active), "lm_decode_step")
    findings += cache_width_findings(
        lambda p, c, t, q, a: model.decode_step(p, c, t, q, active=a),
        (params, cache, token, pos, active), "lm_decode_step")

    eng = ServeEngine(model, params, batch=batch, max_seq=max_seq,
                      decode_block=4)
    loop = eng._make_decode_loop(4)
    findings += contract_findings(
        loop, (eng.params, eng.cache, eng.state, eng._sample_key),
        "decode_loop[k=4]")

    # Speculative decoding entry points: the verify executable reads the
    # quantized cache (packed codes must reach their dequant expand
    # un-widened), the commit executable re-enters the quantized
    # cache-write path (CT303: leaves come back at storage width), and
    # the fused speculative loop composes both with drafting + chunk
    # sampling in one scan body.
    from repro.serve.spec import SpecConfig

    spec_eng = ServeEngine(model, params, batch=batch, max_seq=max_seq,
                           decode_block=4,
                           spec=SpecConfig(draft_tokens=3,
                                           ngram_table=64))
    s_width = 4
    v_tokens = jnp.zeros((batch, s_width), jnp.int32)
    v_pos = jnp.ones((batch, 1), jnp.int32) + jnp.arange(
        s_width, dtype=jnp.int32)[None, :]
    e_acc = jnp.ones((batch,), jnp.int32)
    findings += contract_findings(
        model.verify_chunk, (params, cache, v_tokens, v_pos),
        "lm_verify_chunk")
    _, v_info = jax.eval_shape(model.verify_chunk, params, cache,
                               v_tokens, v_pos)
    v_info = jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, l.dtype), v_info)
    findings += contract_findings(
        model.commit_chunk, (cache, v_info, v_pos, e_acc),
        "lm_commit_chunk")
    findings += cache_width_findings(
        model.commit_chunk, (cache, v_info, v_pos, e_acc),
        "lm_commit_chunk", cache_out_index=0)
    findings += contract_findings(
        spec_eng._make_spec_loop(2),
        (spec_eng.params, spec_eng.cache, spec_eng.state,
         spec_eng._sample_key), "spec_loop[n=2]")

    chunk = jnp.zeros((4,), jnp.int32)
    findings += contract_findings(
        model.prefill_chunk,
        (params, cache, chunk, jnp.int32(0), jnp.int32(0), jnp.int32(4)),
        "lm_prefill_chunk")
    findings += cache_width_findings(
        model.prefill_chunk,
        (params, cache, chunk, jnp.int32(0), jnp.int32(0), jnp.int32(4)),
        "lm_prefill_chunk")

    # Every arch family runs the SAME fused scan + chunked pooled
    # prefill protocol now — trace the non-attention families through
    # their own entry points (hybrid exercises the SSM conv/state
    # leaves in the fused loop; enc-dec exercises slot-resident
    # enc_out + quantized cross-KV).
    hyb_cfg = dataclasses.replace(get_config("jamba-v0.1-52b").reduced(),
                                  kv_format=kv_format)
    hyb = build_model(hyb_cfg)
    hyb_params = hyb.init(jax.random.PRNGKey(1))
    hyb_cache = hyb.init_cache(batch, max_seq)
    findings += contract_findings(
        lambda p, c, t, q, a: hyb.decode_step(p, c, t, q, active=a),
        (hyb_params, hyb_cache, token, pos, active),
        "lm_decode_step[hybrid]")
    findings += contract_findings(
        hyb.prefill_chunk,
        (hyb_params, hyb_cache, chunk, jnp.int32(0), jnp.int32(0),
         jnp.int32(4)), "lm_prefill_chunk[hybrid]")
    findings += cache_width_findings(
        hyb.prefill_chunk,
        (hyb_params, hyb_cache, chunk, jnp.int32(0), jnp.int32(0),
         jnp.int32(4)), "lm_prefill_chunk[hybrid]")
    hyb_eng = ServeEngine(hyb, hyb_params, batch=batch, max_seq=max_seq,
                          decode_block=4)
    findings += contract_findings(
        hyb_eng._make_decode_loop(4),
        (hyb_eng.params, hyb_eng.cache, hyb_eng.state,
         hyb_eng._sample_key), "decode_loop[hybrid,k=4]")

    enc_len = 16
    ed_cfg = dataclasses.replace(
        get_config("seamless-m4t-medium").reduced(), kv_format=kv_format)
    ed = build_model(ed_cfg)
    ed_params = ed.init(jax.random.PRNGKey(2))
    ed_cache = ed.init_cache(batch, max_seq, enc_len=enc_len)
    frames = jnp.zeros((1, enc_len, ed_cfg.d_model), jnp.float32)
    findings += contract_findings(
        ed.encode_slot,
        (ed_params, ed_cache, frames, jnp.int32(0), jnp.int32(enc_len)),
        "lm_encode_slot[enc-dec]")
    findings += cache_width_findings(
        ed.encode_slot,
        (ed_params, ed_cache, frames, jnp.int32(0), jnp.int32(enc_len)),
        "lm_encode_slot[enc-dec]", cache_out_index=0)
    findings += contract_findings(
        ed.prefill_chunk,
        (ed_params, ed_cache, chunk, jnp.int32(0), jnp.int32(0),
         jnp.int32(4)), "lm_prefill_chunk[enc-dec]")
    findings += contract_findings(
        lambda p, c, t, q, a: ed.decode_step(p, c, t, q, active=a),
        (ed_params, ed_cache, token, pos, active),
        "lm_decode_step[enc-dec]")

    x = jnp.zeros((8, 64), jnp.float32)
    pw = jnp.zeros((128, 64 // 2), jnp.uint8)      # fp4: 2 values/byte
    sc = jnp.zeros((128, 64 // 32), jnp.float32)
    findings += contract_findings(
        lambda a, b, c: ops.qmatmul_packed(a, b, c, "float4_e2m1fn",
                                           bm=8, bn=64, bk=32),
        (x, pw, sc), "qmatmul_packed", tainted_idx=[1])

    d, hq, hkv, s = 16, 4, 2, 8
    q = jnp.zeros((batch, 1, hq, d), jnp.float32)
    kv_cache = {
        "k_q": jnp.zeros((batch, s, hkv, d // 2), jnp.uint8),
        "k_s": jnp.zeros((batch, s, hkv, 1), jnp.uint8),
        "v_q": jnp.zeros((batch, s, hkv, d // 2), jnp.uint8),
        "v_s": jnp.zeros((batch, s, hkv, 1), jnp.uint8),
        "slot_pos": jnp.full((batch, s), -1, jnp.int32),
    }
    findings += contract_findings(
        lambda qq, kv, pp: ops.flash_decode_quant(qq, kv, pp, fmt="float4_e2m1fn",
                                                  bk=8),
        (q, kv_cache, pos), "flash_decode_quant")

    # Robustness entry points (serving-under-fire layer): the cancel and
    # fault-arm slot-state writes dispatched on deadline expiry /
    # cancellation / chaos arming, and the cache poisoners that corrupt
    # a slot's quantized KV in place.  Same contract as every other hot
    # path — no packed payload upcasts, no host callbacks — plus CT303
    # on the poisoners: a fault injector that silently WIDENED the cache
    # it corrupts would invalidate every bytes/elem claim downstream.
    from repro.serve import faults as fault_lib

    slot0 = jnp.int32(0)
    findings += contract_findings(
        eng._cancel_update, (eng.state, slot0), "cancel_update")
    findings += contract_findings(
        eng._fault_arm_update,
        (eng.state, slot0, jnp.int32(5), jnp.int32(1)),
        "fault_arm_update")
    findings += contract_findings(
        fault_lib.overflow_e8m0_scales, (eng.cache, slot0),
        "fault_e8m0_overflow")
    findings += cache_width_findings(
        fault_lib.overflow_e8m0_scales, (eng.cache, slot0),
        "fault_e8m0_overflow", cache_out_index=0)
    findings += contract_findings(
        fault_lib.flip_kv_bytes, (eng.cache, slot0), "fault_kv_bitflip")
    findings += cache_width_findings(
        fault_lib.flip_kv_bytes, (eng.cache, slot0), "fault_kv_bitflip",
        cache_out_index=0)

    # Mesh-native serving entry points: the same fused loop + chunked
    # prefill, but compiled through the sharded wrappers.  The packed
    # k_q/v_q payload now carries NamedShardings and the jaxpr grows
    # sharding_constraint/pjit structure — the contract is unchanged:
    # codes stay uint8 until their expand, no host callbacks appear.
    if mesh == "auto":
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh((1, 1))
    if mesh is not None:
        sh_eng = ServeEngine(model, params, batch=batch, max_seq=max_seq,
                             decode_block=4, mesh=mesh)
        findings += contract_findings(
            sh_eng._make_decode_loop(4),
            (sh_eng.params, sh_eng.cache, sh_eng.state,
             sh_eng._sample_key), "decode_loop[sharded,k=4]")
        findings += contract_findings(
            sh_eng._prefill_chunk_fn,
            (sh_eng.params, sh_eng.cache, chunk, jnp.int32(0),
             jnp.int32(0), jnp.int32(4)), "lm_prefill_chunk[sharded]")
        findings += cache_width_findings(
            sh_eng._prefill_chunk_fn,
            (sh_eng.params, sh_eng.cache, chunk, jnp.int32(0),
             jnp.int32(0), jnp.int32(4)), "lm_prefill_chunk[sharded]")

    return findings
