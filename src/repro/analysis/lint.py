"""AST trace-safety lint (layer 1 of :mod:`repro.analysis`).

Each rule is keyed to a bug class this repo has actually shipped:

``JL101 host-op-on-tracer``
    ``np.*`` / ``float()`` / ``int()`` / ``bool()`` / ``.item()`` /
    ``.tolist()`` applied to a traced value inside jitted scope.  PR 3
    shipped exactly this (``np.asarray``-on-tracer rounding inside
    ``quantize_blockwise``); on the device path it either crashes under
    jit or silently forces a host sync per call.

``JL102 traced-control-flow``
    Python ``if``/``while`` branching on a traced value.  Under jit the
    branch is resolved once at trace time with whatever concrete value
    the tracer happened to abstract — i.e. it measures the first call,
    forever.

``JL103 captured-attr-mutation``
    Assigning ``self.<attr>`` outside ``__init__`` when ``<attr>`` is
    read by a function wrapped in a cached executable (``jax.jit``).
    The executable baked the old value in at trace time, so the
    mutation is silently ignored — the PR-4 ``temperature``/``top_k``
    class.

``JL104 wall-clock-in-trace``
    ``time.*`` / ``random.*`` / ``np.random.*`` / ``datetime.*`` calls
    in traced scope: evaluated once at trace time, constant thereafter.
    Timing *inside* a jitted region also measures nothing (dispatch is
    async) — timed regions belong outside, around ``block_until_ready``.

``JL105 stale-memo-cache``
    ``functools.lru_cache``/``cache`` on a function whose value depends
    on a mutable registry (the PR-3 ``_format_table`` class: memoized
    over ``dtype_registry()`` output, stale after plugin registration).

Suppression: an inline ``# jaxlint: disable=RULE(reason)`` pragma on
the finding line (or the line above, or the enclosing ``def``), or a
committed baseline (``tools/jaxlint_baseline.json``) so the gate starts
green; baseline entries match on (path, rule, scope, source text), so
they age out when the code they waived changes.

The linter is deliberately repo-shaped: ``DEFAULT_TRACED_ROOTS`` names
the hot entry points (``lm_decode_step``, ``quantize_blockwise``, the
Pallas kernels, ...) that are jitted *by callers in other modules*, and
tracedness propagates transitively through the intra-module call graph.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "JL101": "host-op-on-tracer",
    "JL102": "traced-control-flow",
    "JL103": "captured-attr-mutation",
    "JL104": "wall-clock-in-trace",
    "JL105": "stale-memo-cache",
    # layer 2 (repro.analysis.contracts)
    "CT301": "packed-upcast",
    "CT302": "host-callback",
    "CT303": "cache-width",
    # layer 3 (repro.analysis.pallas_check)
    "PC200": "uncovered-site",
    "PC201": "write-race",
    "PC202": "unsound-alias",
    "PC203": "vmem-overflow",
}
_NAME_TO_ID = {v: k for k, v in RULES.items()}

# Attribute reads that are static at trace time (safe to branch on).
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "format",
                "aval", "weak_type", "itemsize", "nbytes"}

# np.* calls that only inspect type/metadata, never force the value.
NP_SAFE_FUNCS = {"isscalar", "dtype", "shape", "ndim", "result_type",
                 "issubdtype", "can_cast", "promote_types", "iinfo",
                 "finfo", "prod", "dtype_of"}

# Builtin predicates whose result is static for tracers.
STATIC_PREDICATES = {"isinstance", "issubclass", "hasattr", "callable",
                     "len", "type", "id", "repr", "str"}

# Parameter names that by repo convention hold static config, not arrays.
STATIC_PARAM_NAMES = {
    "self", "cls", "cfg", "config", "fmt", "kv_format", "weight_format",
    "name", "mode", "axis", "interpret", "dtype", "out_dtype",
    "compute_dtype", "spec", "pattern", "path", "fn", "model", "key_fn",
}

_HOST_CONVERTERS = {"float", "int", "bool", "complex"}
_FORCING_METHODS = {"item", "tolist", "__array__"}

_CLOCK_MODULES = {
    ("time",): "time.* is evaluated once at trace time",
    ("random",): "stdlib random runs at trace time (constant under jit)",
    ("np", "random"): "np.random runs at trace time; use jax.random",
    ("numpy", "random"): "np.random runs at trace time; use jax.random",
    ("datetime",): "datetime.* is evaluated once at trace time",
}

# Entry points jitted by callers outside their own module.  Keys are
# path suffixes, values the function names to treat as traced roots.
DEFAULT_TRACED_ROOTS: Dict[str, Set[str]] = {
    "models/transformer.py": {
        "lm_decode_step", "lm_prefill_chunk", "lm_prefill", "lm_forward",
        "lm_features", "lm_encode_slot", "clear_slot", "kv_cache_stats",
    },
    "models/attention.py": {
        "decode_attention", "cache_attention", "cache_kv", "quantize_kv",
        "dequantize_kv",
    },
    "models/slotstate.py": {
        "mask_rows", "masked_tree", "decode_advance", "take_row",
        "put_row", "clear_slot",
    },
    "models/ssm.py": {"ssm_prefill_chunk"},
    "serve/quant.py": {"quantize_blockwise", "dequantize_blockwise"},
    "serve/sampler.py": {"sample_token", "sample_tokens",
                         "fold_slot_keys"},
    "serve/faults.py": {"overflow_e8m0_scales", "flip_kv_bytes",
                        "poison_recurrent_state"},
    "repro/lowbits.py": {
        "decode", "quantize_values", "encode_codes", "unpack_codes",
        "e8m0_decode", "e8m0_scale_code",
    },
}


@dataclasses.dataclass
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str
    context: str = ""          # qualified name of the enclosing scope
    text: str = ""             # stripped source line

    @property
    def rule_name(self) -> str:
        return RULES.get(self.rule, "?")

    def fingerprint(self) -> Tuple[str, str, str, str]:
        return (self.path, self.rule, self.context, self.text)

    def render(self) -> str:
        ctx = f" [{self.context}]" if self.context else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule}/{self.rule_name}{ctx}: {self.message}")


@dataclasses.dataclass
class LintConfig:
    traced_roots: Dict[str, Set[str]] = dataclasses.field(
        default_factory=lambda: {k: set(v) for k, v in
                                 DEFAULT_TRACED_ROOTS.items()})
    select: Optional[Set[str]] = None     # restrict to these rule ids


# ---------------------------------------------------------------------------
# pragma parsing


_PRAGMA_RE = re.compile(r"#\s*jaxlint:\s*disable=([^#]*)")
_PRAGMA_ITEM_RE = re.compile(r"(JL\d{3}|[a-z][a-z0-9-]+)\s*(?:\(([^)]*)\))?")


def _parse_pragmas(source: str) -> Dict[int, Set[str]]:
    """line number -> set of disabled rule ids (names normalised)."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if not m:
            continue
        rules: Set[str] = set()
        for item in _PRAGMA_ITEM_RE.finditer(m.group(1)):
            rid = item.group(1)
            rules.add(_NAME_TO_ID.get(rid, rid))
        if rules:
            out[i] = rules
    return out


# ---------------------------------------------------------------------------
# small AST helpers


def _attr_chain(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``a.b.c`` -> ("a", "b", "c"); None for non-trivial bases."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _is_jax_jit(node: ast.AST) -> bool:
    chain = _attr_chain(node)
    return chain in (("jax", "jit"), ("jit",), ("jax", "pmap"),
                     ("pjit",), ("jax", "experimental", "pjit", "pjit"))


def _is_partial(node: ast.AST) -> bool:
    return _attr_chain(node) in (("functools", "partial"), ("partial",))


def _is_memoizer(node: ast.AST) -> bool:
    chain = _attr_chain(node)
    if chain is None:
        return False
    return chain in (("functools", "lru_cache"), ("lru_cache",),
                     ("functools", "cache"), ("cache",))


def _const_str_tuple(node: ast.AST) -> Set[str]:
    """Extract constant strings from a str / tuple-of-str node."""
    out: Set[str] = set()
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        out.add(node.value)
    elif isinstance(node, (ast.Tuple, ast.List)):
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.add(elt.value)
    return out


def _jit_static_argnames(call: ast.Call) -> Set[str]:
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            names |= _const_str_tuple(kw.value)
    return names


_MARKER_CALLS = {
    # callee chain suffix -> positional indices holding traced callables
    ("scan",): (0,),
    ("fori_loop",): (2,),
    ("while_loop",): (0, 1),
    ("cond",): (1, 2),
    ("switch",): (1,),
    ("vmap",): (0,),
    ("grad",): (0,),
    ("value_and_grad",): (0,),
    ("checkpoint",): (0,),
    ("remat",): (0,),
    ("pallas_call",): (0,),
    ("custom_vjp",): (0,),
    ("custom_jvp",): (0,),
    ("associative_scan",): (0,),
    ("lax", "map"): (0,),   # jax.lax.map only — NOT jax.tree.map
}


class _FuncRecord:
    __slots__ = ("node", "qualname", "traced", "static_params",
                 "class_name", "calls", "reason")

    def __init__(self, node, qualname, class_name):
        self.node = node
        self.qualname = qualname
        self.class_name = class_name
        self.traced = False
        self.reason = ""
        self.static_params: Set[str] = set()
        self.calls: Set[str] = set()     # simple names called in body


class _ModuleIndex(ast.NodeVisitor):
    """Collect functions, trace markers, and the intra-module call graph."""

    def __init__(self):
        self.funcs: Dict[str, _FuncRecord] = {}   # qualname -> record
        self.by_name: Dict[str, List[_FuncRecord]] = {}
        self._stack: List[str] = []
        self._class: List[str] = []
        # names referenced as callables in traced-marker positions
        self.marked_names: Set[str] = set()
        # (class, method) pairs marked via jax.jit(self.method)
        self.marked_methods: Set[Tuple[str, str]] = set()
        # qualnames of functions that *call* jax.jit / markers, with the
        # jit call node (needed for JL103 capture analysis)
        self.jit_sites: List[Tuple[str, Optional[str], ast.Call]] = []
        self.memoized: List[_FuncRecord] = []
        self._alias: List[Dict[str, str]] = [dict()]

    # -- scope bookkeeping ----------------------------------------------
    def _qual(self, name: str) -> str:
        return ".".join(self._stack + [name]) if self._stack else name

    def visit_ClassDef(self, node: ast.ClassDef):
        self._stack.append(node.name)
        self._class.append(node.name)
        self.generic_visit(node)
        self._class.pop()
        self._stack.pop()

    def _visit_func(self, node):
        qual = self._qual(node.name)
        rec = _FuncRecord(node, qual,
                          self._class[-1] if self._class else None)
        # decorators
        for dec in node.decorator_list:
            if _is_jax_jit(dec) or _attr_chain(dec) in (
                    ("jax", "vmap"), ("jax", "checkpoint"),
                    ("jax", "remat"), ("jax", "custom_vjp"),
                    ("jax", "custom_jvp")):
                rec.traced = True
                rec.reason = "jit-decorated"
            elif isinstance(dec, ast.Call):
                if _is_jax_jit(dec.func):
                    rec.traced = True
                    rec.reason = "jit-decorated"
                    rec.static_params |= _jit_static_argnames(dec)
                elif _is_partial(dec.func) and dec.args and \
                        _is_jax_jit(dec.args[0]):
                    rec.traced = True
                    rec.reason = "jit-decorated"
                    rec.static_params |= _jit_static_argnames(dec)
                elif _is_memoizer(dec.func):
                    self.memoized.append(rec)
            elif _is_memoizer(dec):
                self.memoized.append(rec)
        self.funcs[qual] = rec
        self.by_name.setdefault(node.name, []).append(rec)
        self._stack.append(node.name)
        self._alias.append(dict())
        self.generic_visit(node)
        self._alias.pop()
        self._stack.pop()

    def visit_FunctionDef(self, node):
        self._visit_func(node)

    def visit_AsyncFunctionDef(self, node):
        self._visit_func(node)

    # -- marker / alias / call-graph collection -------------------------
    def _resolve_alias(self, name: str) -> str:
        for frame in reversed(self._alias):
            if name in frame:
                return frame[name]
        return name

    def _mark_callable_arg(self, arg: ast.AST):
        if isinstance(arg, ast.Name):
            self.marked_names.add(self._resolve_alias(arg.id))
        elif isinstance(arg, ast.Attribute):
            chain = _attr_chain(arg)
            if chain and chain[0] == "self" and len(chain) == 2:
                cls = self._class[-1] if self._class else None
                if cls:
                    self.marked_methods.add((cls, chain[1]))
        elif isinstance(arg, ast.Lambda):
            # lambdas in traced positions: handled by the outer scope
            # being traced (their bodies are visited as expressions of
            # the enclosing function), nothing extra to record.
            pass
        elif isinstance(arg, ast.Call) and _is_partial(arg.func) and arg.args:
            self._mark_callable_arg(arg.args[0])

    def visit_Assign(self, node: ast.Assign):
        # track `k = functools.partial(f, ...)` and `g = f` aliases
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            tgt = node.targets[0].id
            val = node.value
            if isinstance(val, ast.Call) and _is_partial(val.func) \
                    and val.args and isinstance(val.args[0], ast.Name):
                self._alias[-1][tgt] = val.args[0].id
            elif isinstance(val, ast.Name):
                self._alias[-1][tgt] = self._resolve_alias(val.id)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        chain = _attr_chain(node.func)
        enclosing = ".".join(self._stack) if self._stack else None
        if chain:
            if chain in (("jax", "jit"), ("jit",)) or \
                    (_is_partial(node.func) and node.args and
                     _is_jax_jit(node.args[0])):
                args = node.args
                if _is_partial(node.func):
                    args = node.args[1:]
                for a in args[:1]:
                    self._mark_callable_arg(a)
                self.jit_sites.append(
                    (enclosing or "<module>",
                     self._class[-1] if self._class else None, node))
            else:
                for suffix, positions in _MARKER_CALLS.items():
                    if chain[-len(suffix):] == suffix:
                        for p in positions:
                            if p < len(node.args):
                                self._mark_callable_arg(node.args[p])
                        break
            if len(chain) == 1 and enclosing is not None:
                cur = self.funcs.get(enclosing)
                if cur is not None:
                    cur.calls.add(self._resolve_alias(chain[0]))
        self.generic_visit(node)


# ---------------------------------------------------------------------------
# taint / rule checking inside a traced function


class _ExprScan(ast.NodeVisitor):
    """Collect Name references in an expression, skipping subtrees that
    are static at trace time (``x.shape``, ``isinstance(x, ...)``,
    ``x is None``)."""

    def __init__(self):
        self.names: Set[str] = set()

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr in STATIC_ATTRS:
            return
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        chain = _attr_chain(node.func)
        if chain is not None:
            if chain[-1] in STATIC_PREDICATES and len(chain) == 1:
                return
            if chain[0] in ("np", "numpy") and chain[-1] in NP_SAFE_FUNCS:
                return
            # is_quantized_cache(...), has_*/supports_* — structure
            # predicates, resolved at trace time by repo convention
            if chain[-1].startswith(("is_", "has_", "supports_")):
                return
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare):
        # `x is None`, `"k_q" in cache`: identity and container
        # membership are static at trace time
        if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
               for op in node.ops):
            return
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name):
        self.names.add(node.id)


def _dynamic_names(expr: ast.AST) -> Set[str]:
    scan = _ExprScan()
    scan.visit(expr)
    return scan.names


def _all_names(expr: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(expr) if isinstance(n, ast.Name)}


def _target_names(target: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(target):
        if isinstance(n, ast.Name):
            out.add(n.id)
    return out


class _TracedChecker(ast.NodeVisitor):
    """Run JL101/JL102/JL104 over one traced function body."""

    def __init__(self, rec: _FuncRecord, path: str, lines: List[str],
                 findings: List[Finding], inherited: Set[str]):
        self.rec = rec
        self.path = path
        self.lines = lines
        self.findings = findings
        self.tainted: Set[str] = set(inherited)
        node = rec.node
        args = node.args
        for a in list(args.posonlyargs) + list(args.args):
            if a.arg in STATIC_PARAM_NAMES or \
                    a.arg in rec.static_params or _static_annotation(a):
                continue
            self.tainted.add(a.arg)
        # keyword-only params are bound via functools.partial in this
        # repo's kernel idiom (block sizes, flags) — treat as static
        # unless they look like arrays.
        for a in args.kwonlyargs:
            if a.arg in ("q", "k", "v", "x", "w", "acc"):
                self.tainted.add(a.arg)
        if args.vararg:
            self.tainted.add(args.vararg.arg)

    # -- helpers --------------------------------------------------------
    def _emit(self, node: ast.AST, rule: str, msg: str):
        line = getattr(node, "lineno", 0)
        text = self.lines[line - 1].strip() if 0 < line <= len(self.lines) \
            else ""
        self.findings.append(Finding(
            path=self.path, line=line,
            col=getattr(node, "col_offset", 0) + 1, rule=rule,
            message=msg, context=self.rec.qualname, text=text))

    def _is_tainted_expr(self, expr: ast.AST) -> bool:
        return bool(_dynamic_names(expr) & self.tainted)

    def _rhs_taints(self, value: ast.AST) -> bool:
        if self._is_tainted_expr(value):
            return True
        for call in ast.walk(value):
            if isinstance(call, ast.Call):
                chain = _attr_chain(call.func)
                if chain and chain[0] in ("jnp", "jax", "lax", "pl",
                                          "plgpu", "pltpu"):
                    return True
        return False

    # -- taint propagation ---------------------------------------------
    def visit_Assign(self, node: ast.Assign):
        self.generic_visit(node)
        if self._rhs_taints(node.value):
            for t in node.targets:
                self.tainted |= _target_names(t)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        self.generic_visit(node)
        if node.value is not None and self._rhs_taints(node.value):
            self.tainted |= _target_names(node.target)

    def visit_AugAssign(self, node: ast.AugAssign):
        self.generic_visit(node)
        if self._rhs_taints(node.value):
            self.tainted |= _target_names(node.target)

    def visit_FunctionDef(self, node):
        # nested defs are checked separately with inherited taint
        pass

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):
        pass

    # -- JL102 ----------------------------------------------------------
    def visit_If(self, node: ast.If):
        if self._is_tainted_expr(node.test):
            names = sorted(_dynamic_names(node.test) & self.tainted)
            self._emit(node, "JL102",
                       f"Python `if` on traced value(s) {names}: the "
                       "branch is resolved once at trace time; use "
                       "jnp.where / lax.cond")
        self.generic_visit(node)

    def visit_While(self, node: ast.While):
        if self._is_tainted_expr(node.test):
            names = sorted(_dynamic_names(node.test) & self.tainted)
            self._emit(node, "JL102",
                       f"Python `while` on traced value(s) {names}: "
                       "use lax.while_loop")
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert):
        if self._is_tainted_expr(node.test):
            names = sorted(_dynamic_names(node.test) & self.tainted)
            self._emit(node, "JL102",
                       f"`assert` on traced value(s) {names}: resolved "
                       "at trace time (checks nothing at runtime)")
        self.generic_visit(node)

    # -- JL101 / JL104 ---------------------------------------------------
    def visit_Call(self, node: ast.Call):
        chain = _attr_chain(node.func)
        arg_tainted = any(self._is_tainted_expr(a) for a in node.args) or \
            any(kw.value is not None and self._is_tainted_expr(kw.value)
                for kw in node.keywords)
        if chain is not None:
            root, leaf = chain[0], chain[-1]
            if root in ("np", "numpy") and len(chain) > 1 \
                    and leaf not in NP_SAFE_FUNCS and arg_tainted:
                self._emit(node, "JL101",
                           f"`{'.'.join(chain)}` on a traced value: "
                           "forces a host sync / breaks under jit; use "
                           "the jnp equivalent")
            elif chain in (("float",), ("int",), ("bool",), ("complex",)) \
                    and arg_tainted:
                self._emit(node, "JL101",
                           f"`{leaf}()` on a traced value forces a "
                           "device sync; keep it as a device scalar")
            else:
                for prefix, why in _CLOCK_MODULES.items():
                    if chain[:len(prefix)] == prefix and \
                            len(chain) > len(prefix):
                        self._emit(node, "JL104",
                                   f"`{'.'.join(chain)}` in traced "
                                   f"scope: {why}")
                        break
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in _FORCING_METHODS and \
                self._is_tainted_expr(node.func.value):
            self._emit(node, "JL101",
                       f"`.{node.func.attr}()` on a traced value "
                       "forces a device sync")
        self.generic_visit(node)


def _static_annotation(arg: ast.arg) -> bool:
    ann = arg.annotation
    if ann is None:
        return False
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        name = ann.value
    else:
        chain = _attr_chain(ann)
        name = chain[-1] if chain else ""
    # Python-scalar annotations are static by repo convention: traced
    # values are annotated `jax.Array`; `int`/`float` params are shapes,
    # block sizes, and sampling knobs baked in at trace time.
    return name in {"str", "bool", "int", "float", "Config",
                    "ArchConfig", "ModelConfig", "BlockSpec",
                    "PackedSpec", "Callable", "Model"}


# ---------------------------------------------------------------------------
# JL103: mutation of jit-captured attributes


def _self_attr_reads(node: ast.AST) -> Set[str]:
    return {sub.attr for sub in ast.walk(node)
            if isinstance(sub, ast.Attribute)
            and isinstance(sub.ctx, ast.Load)
            and isinstance(sub.value, ast.Name)
            and sub.value.id == "self"
            and sub.attr not in STATIC_ATTRS}


def _local_attr_flow(method: ast.AST) -> Dict[str, Set[str]]:
    """local name -> self attrs whose values flowed into it, e.g.
    ``temp, top_k = self.temperature, self.top_k`` (the PR-4 shape)."""
    flow: Dict[str, Set[str]] = {}
    for stmt in ast.walk(method):
        if not isinstance(stmt, ast.Assign):
            continue
        attrs = _self_attr_reads(stmt.value)
        if not attrs:
            continue
        # pairwise-map tuple assignments when arities line up
        if len(stmt.targets) == 1 and \
                isinstance(stmt.targets[0], ast.Tuple) and \
                isinstance(stmt.value, ast.Tuple) and \
                len(stmt.targets[0].elts) == len(stmt.value.elts):
            for tgt, val in zip(stmt.targets[0].elts, stmt.value.elts):
                if isinstance(tgt, ast.Name):
                    a = _self_attr_reads(val)
                    if a:
                        flow.setdefault(tgt.id, set()).update(a)
            continue
        for t in stmt.targets:
            for name in _target_names(t):
                flow.setdefault(name, set()).update(attrs)
    return flow


def _check_captured_mutation(tree: ast.Module, path: str,
                             lines: List[str], findings: List[Finding]):
    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        methods = {n.name: n for n in cls.body
                   if isinstance(n, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))}
        captured: Dict[str, str] = {}   # attr -> where it was captured

        def note(attrs: Set[str], where: str):
            for a in attrs:
                captured.setdefault(a, where)

        for name, m in methods.items():
            where = f"{cls.name}.{name}"
            flow = _local_attr_flow(m)
            local_defs = {n.name: n for n in ast.walk(m)
                          if isinstance(n, ast.FunctionDef) and n is not m}
            for call in ast.walk(m):
                if not isinstance(call, ast.Call):
                    continue
                if not (_is_jax_jit(call.func) or
                        (_is_partial(call.func) and call.args and
                         _is_jax_jit(call.args[0]))):
                    continue
                args = call.args[1:] if _is_partial(call.func) \
                    else call.args
                for a in args[:1]:
                    body: Optional[ast.AST] = None
                    site = where
                    chain = _attr_chain(a)
                    if isinstance(a, ast.Lambda):
                        body = a
                    elif isinstance(a, ast.Name) and a.id in local_defs:
                        body = local_defs[a.id]
                    elif chain and chain[0] == "self" and \
                            len(chain) == 2 and chain[1] in methods:
                        body = methods[chain[1]]
                        site = f"{cls.name}.{chain[1]}"
                    if body is None:
                        continue
                    # direct self.* reads in the jitted callable, plus
                    # self attrs that flowed into locals it closes over
                    attrs = set(_self_attr_reads(body))
                    free = _all_names(body)
                    for local, srcs in flow.items():
                        if local in free:
                            attrs |= srcs
                    note(attrs, site)
        if not captured:
            continue
        # private backing fields of read-only properties are fine: the
        # property pattern is the sanctioned fix for this rule.
        props = {n.name for n in cls.body
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                 and any(_attr_chain(d) == ("property",)
                         for d in n.decorator_list)}
        for name, m in methods.items():
            if name == "__init__":
                continue
            is_setter = any(
                (c := _attr_chain(d)) and len(c) == 2 and c[1] == "setter"
                for d in m.decorator_list)
            for sub in ast.walk(m):
                if isinstance(sub, (ast.Assign, ast.AugAssign)):
                    targets = sub.targets if isinstance(sub, ast.Assign) \
                        else [sub.target]
                    for t in targets:
                        if isinstance(t, ast.Attribute) and \
                                isinstance(t.value, ast.Name) and \
                                t.value.id == "self" and \
                                t.attr in captured and not is_setter and \
                                t.attr.lstrip("_") not in props:
                            line = sub.lineno
                            text = lines[line - 1].strip() \
                                if line <= len(lines) else ""
                            findings.append(Finding(
                                path=path, line=line,
                                col=sub.col_offset + 1, rule="JL103",
                                message=(
                                    f"`self.{t.attr}` is captured by a "
                                    f"jitted executable (traced in "
                                    f"{captured[t.attr]}); mutating it "
                                    "here is silently ignored — rebuild "
                                    "the executable or make it a "
                                    "read-only property"),
                                context=f"{cls.name}.{name}", text=text))


# ---------------------------------------------------------------------------
# JL105: memo caches over mutable registry state


def _check_stale_memo(index: _ModuleIndex, path: str, lines: List[str],
                      findings: List[Finding]):
    for rec in index.memoized:
        own = rec.node.name
        for call in ast.walk(rec.node):
            if not isinstance(call, ast.Call):
                continue
            chain = _attr_chain(call.func)
            if chain is None:
                continue
            leaf = chain[-1]
            if leaf == own:
                continue
            if "registry" in leaf or leaf in ("get_registry",
                                              "registered_formats"):
                line = call.lineno
                text = lines[line - 1].strip() if line <= len(lines) else ""
                findings.append(Finding(
                    path=path, line=line, col=call.col_offset + 1,
                    rule="JL105",
                    message=(f"memoized `{own}` reads mutable registry "
                             f"state via `{'.'.join(chain)}`: the cache "
                             "goes stale after registration — key the "
                             "memo on the registry contents or drop it"),
                    context=rec.qualname, text=text))


# ---------------------------------------------------------------------------
# driver


def _mark_traced(index: _ModuleIndex, path: str, config: LintConfig):
    # roots from config (path-suffix match)
    for suffix, names in config.traced_roots.items():
        if path.endswith(suffix):
            for rec in index.funcs.values():
                if rec.node.name in names and not rec.traced:
                    rec.traced = True
                    rec.reason = "configured root"
    # names marked via jit()/scan()/pallas_call() call sites
    for rec in index.funcs.values():
        if rec.node.name in index.marked_names and not rec.traced:
            rec.traced = True
            rec.reason = "passed to a tracing transform"
        if rec.class_name and (rec.class_name, rec.node.name) in \
                index.marked_methods and not rec.traced:
            rec.traced = True
            rec.reason = "method passed to jax.jit"
    # nested defs inside traced functions are traced
    changed = True
    while changed:
        changed = False
        for qual, rec in index.funcs.items():
            if rec.traced:
                continue
            parent = qual.rsplit(".", 1)[0] if "." in qual else None
            if parent and parent in index.funcs and \
                    index.funcs[parent].traced and \
                    isinstance(index.funcs[parent].node,
                               (ast.FunctionDef, ast.AsyncFunctionDef)):
                rec.traced = True
                rec.reason = "nested in traced scope"
                changed = True
        # transitive: traced fn calls module-level fn by simple name
        for rec in index.funcs.values():
            if not rec.traced:
                continue
            for callee in rec.calls:
                for cand in index.by_name.get(callee, ()):  # same module
                    if not cand.traced and "." not in cand.qualname:
                        cand.traced = True
                        cand.reason = f"called from traced {rec.qualname}"
                        changed = True


def lint_source(source: str, path: str,
                config: Optional[LintConfig] = None) -> List[Finding]:
    config = config or LintConfig()
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Finding(path=path, line=exc.lineno or 0, col=0,
                        rule="JL100", message=f"syntax error: {exc.msg}")]
    lines = source.splitlines()
    index = _ModuleIndex()
    index.visit(tree)
    _mark_traced(index, path, config)

    findings: List[Finding] = []
    # inherited taint: names tainted in an enclosing traced function
    inherited: Dict[str, Set[str]] = {}
    for qual in sorted(index.funcs):   # parents sort before children
        rec = index.funcs[qual]
        if not rec.traced:
            continue
        parent = qual.rsplit(".", 1)[0] if "." in qual else None
        seed = inherited.get(parent, set()) if parent else set()
        checker = _TracedChecker(rec, path, lines, findings, seed)
        for stmt in rec.node.body:
            checker.visit(stmt)
        inherited[qual] = set(checker.tainted)

    _check_captured_mutation(tree, path, lines, findings)
    _check_stale_memo(index, path, lines, findings)

    # pragma suppression
    pragmas = _parse_pragmas(source)
    def_lines: Dict[str, int] = {q: r.node.lineno
                                 for q, r in index.funcs.items()}
    kept: List[Finding] = []
    for f in findings:
        if config.select and f.rule not in config.select:
            continue
        spots = [f.line, f.line - 1]
        if f.context in def_lines:
            spots.append(def_lines[f.context])
        if any(f.rule in pragmas.get(s, ()) for s in spots):
            continue
        kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept


def lint_paths(paths: Sequence[str],
               config: Optional[LintConfig] = None,
               baseline: Optional[Iterable[dict]] = None,
               root: Optional[str] = None) -> List[Finding]:
    """Lint files/directories; drop findings matching the baseline."""
    import os

    config = config or LintConfig()
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames)
                             if f.endswith(".py"))
        elif p.endswith(".py"):
            files.append(p)
    findings: List[Finding] = []
    for fp in sorted(set(files)):
        with open(fp, "r", encoding="utf-8") as fh:
            source = fh.read()
        rel = os.path.relpath(fp, root) if root else fp
        findings.extend(lint_source(source, rel, config))
    if baseline:
        budget: Dict[Tuple[str, str, str, str], int] = {}
        for entry in baseline:
            key = (entry["path"], entry["rule"],
                   entry.get("context", ""), entry.get("text", ""))
            budget[key] = budget.get(key, 0) + 1
        kept = []
        for f in findings:
            key = f.fingerprint()
            if budget.get(key, 0) > 0:
                budget[key] -= 1
                continue
            kept.append(f)
        findings = kept
    return findings


def load_baseline(path: str) -> List[dict]:
    import os
    if not os.path.exists(path):
        return []
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    return list(data.get("findings", []))


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    data = {
        "comment": ("jaxlint baseline: pre-existing findings waived at "
                    "gate introduction. Entries match on (path, rule, "
                    "scope, source text) and age out when the waived "
                    "line changes. Do not add new entries without a "
                    "review; prefer inline pragmas with reasons."),
        "findings": [
            {"path": f.path, "rule": f.rule, "context": f.context,
             "text": f.text, "message": f.message}
            for f in findings
        ],
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(data, fh, indent=2, sort_keys=False)
        fh.write("\n")
