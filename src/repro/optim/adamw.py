"""AdamW with distributed-memory knobs.

Two beyond-paper (but paper-motivated — §V precision/energy study) state
compressions that make the 1T-param cell fit 16 GiB/chip HBM:

* ``m_dtype="bfloat16"``  — first moment stored bf16 (update maths fp32)
* ``factored_v=True``     — Adafactor-style rank-1 second moment for
  matrices (row/col means), exact Adam ``v`` for vectors

Optimizer state mirrors parameter sharding; :func:`opt_state_specs`
derives the state PartitionSpecs from the parameter specs (factored
leaves drop the corresponding axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Schedule:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_ratio: float = 0.1

    def __call__(self, step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = self.peak_lr * step / max(self.warmup_steps, 1)
        progress = jnp.clip((step - self.warmup_steps)
                            / max(self.decay_steps - self.warmup_steps, 1),
                            0.0, 1.0)
        cos = self.peak_lr * (self.min_ratio + (1 - self.min_ratio)
                              * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
        return jnp.where(step < self.warmup_steps, warm, cos)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    schedule: Schedule = Schedule()
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    m_dtype: str = "float32"
    factored_v: bool = False
    factored_min_dim: int = 128    # factor only matrices at least this big


def _is_factored(cfg: AdamWConfig, shape: Tuple[int, ...]) -> bool:
    return (cfg.factored_v and len(shape) >= 2
            and shape[-1] >= cfg.factored_min_dim
            and shape[-2] >= cfg.factored_min_dim)


def adamw_init(cfg: AdamWConfig, params: Any) -> dict:
    def init_m(p):
        return jnp.zeros(p.shape, jnp.dtype(cfg.m_dtype))

    def init_v(p):
        if _is_factored(cfg, p.shape):
            return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                     jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree.map(init_m, params),
        "v": jax.tree.map(init_v, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _vhat_factored(v: dict, g2: jax.Array, b2: float) -> Tuple[dict, jax.Array]:
    row = b2 * v["row"] + (1 - b2) * jnp.mean(g2, axis=-1)
    col = b2 * v["col"] + (1 - b2) * jnp.mean(g2, axis=-2)
    denom = jnp.maximum(jnp.mean(row, axis=-1, keepdims=True), 1e-30)
    vhat = row[..., None] * col[..., None, :] / denom[..., None]
    return {"row": row, "col": col}, vhat


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: dict
                 ) -> Tuple[Any, dict]:
    step = state["step"] + 1
    lr = cfg.schedule(step)
    # global-norm clip (fp32)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        g2 = jnp.square(g)
        if isinstance(v, dict):
            v_new, vhat = _vhat_factored(v, g2, cfg.b2)
        else:
            v_new = cfg.b2 * v + (1 - cfg.b2) * g2
            vhat = v_new
        update = (m32 / bc1) / (jnp.sqrt(vhat / bc2) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:        # no decay on norms/bias
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * update).astype(p.dtype))
        new_m.append(m32.astype(m.dtype))
        new_v.append(v_new)

    return (jax.tree.unflatten(treedef, new_p),
            {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v),
             "step": step})


def opt_state_specs(cfg: AdamWConfig, params_shapes: Any,
                    params_specs: Any) -> dict:
    """State PartitionSpecs mirroring the parameter specs."""
    def v_spec(shape_leaf, spec: P):
        full = tuple(spec) + (None,) * (len(shape_leaf.shape) - len(tuple(spec)))
        if _is_factored(cfg, shape_leaf.shape):
            return {"row": P(*full[:-1]),
                    "col": P(*(full[:-2] + full[-1:]))}
        return P(*full)

    return {
        "m": params_specs,
        "v": jax.tree.map(v_spec, params_shapes, params_specs),
        "step": P(),
    }
