"""Optimizers (no external deps): AdamW with precision/memory knobs."""

from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    Schedule,
    adamw_init,
    adamw_update,
    global_norm,
    opt_state_specs,
)
