"""Capability detection + compatibility layer (the portability tentpole).

The paper's methodology is *portable* characterization: drop the probe
suite on a device and report what that device actually supports — which
mma formats are native vs. emulated, which pipeline a dot really lowers
to, and so on.  This module applies the same philosophy to the software
stack the reproduction runs on:

* **JAX version probing** — the repo targets current Pallas/TPU APIs but
  must degrade gracefully on older/newer installs (``pltpu.CompilerParams``
  vs ``pltpu.TPUCompilerParams``; ``check_vma`` vs ``check_rep``).
* **Low-precision dtype registry** — fp8/fp6/fp4 availability differs per
  JAX version.  Every format resolves to a *container* dtype JAX can hold
  plus an optional ``ml_dtypes`` host-rounding dtype, so fp4 degrades to
  fp4-rounded values in an fp8 container instead of an import crash
  (numerically exact fp4 in a byte-aligned box).  Sub-byte formats
  additionally carry a :class:`repro.lowbits.PackedSpec` — true
  bit-packed storage (fp4 2 values/byte, fp6 4 values in 3 bytes, the
  paper's Tab V tile packing) that ``serve.quant``/``kernels.qmatmul``
  use for HBM-resident weights and that storage accounting reports as
  measured bytes/element.
* **shard_map resolution** — ``jax.shard_map`` (new) vs
  ``jax.experimental.shard_map.shard_map`` (older), with kwarg
  translation between ``check_vma`` and ``check_rep``.
* **pallas_call wrapper** — transparently selects native Mosaic
  compilation on TPU vs ``interpret=True`` everywhere else, and builds
  ``compiler_params`` through whichever class this JAX exposes.
* **``report()``** — a machine-readable capability report printed at the
  top of every benchmark artifact so each measurement records which paths
  ran native vs. emulated.

Everything here probes *lazily* and caches: importing this module never
touches a device or raises on a missing feature.
"""

from __future__ import annotations

import dataclasses
import functools
import importlib.util
import inspect
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

from repro.lowbits import PackedSpec, is_packable
from repro.lowbits import packed_spec as _lowbits_packed_spec

__all__ = [
    "jax_version",
    "backend_platform",
    "is_tpu",
    "DTypeSpec",
    "dtype_spec",
    "dtype_registry",
    "available_formats",
    "format_bits",
    "PackedSpec",
    "packed_spec",
    "storage_bytes_per_element",
    "shard_map",
    "resolve_shard_map",
    "pallas_interpret_default",
    "tpu_compiler_params",
    "pallas_call",
    "vmem_budget_bytes",
    "has_hypothesis",
    "CompatReport",
    "report",
]


# --------------------------------------------------------------------- #
# Version / backend probing
# --------------------------------------------------------------------- #

@functools.lru_cache(maxsize=None)
def jax_version() -> Tuple[int, ...]:
    """Installed JAX version as a comparable int tuple, e.g. (0, 4, 37)."""
    parts: List[int] = []
    for tok in jax.__version__.split("."):
        digits = "".join(c for c in tok if c.isdigit())
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts) or (0,)


@functools.lru_cache(maxsize=None)
def backend_platform() -> str:
    """Default-backend platform string: 'tpu' | 'gpu' | 'cpu'."""
    try:
        return jax.devices()[0].platform
    except Exception:
        return "cpu"


def is_tpu() -> bool:
    return backend_platform() == "tpu"


# --------------------------------------------------------------------- #
# Low-precision dtype registry
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class DTypeSpec:
    """How one paper format (Tab IV/V) is actually stored on this stack.

    ``container`` is a dtype JAX arrays can hold; ``round_dtype`` (an
    ``ml_dtypes`` dtype, host-side) is set when values must be rounded to
    the true format before entering the container — i.e. the format is
    *emulated*: numerically exact in a wider, byte-aligned box.
    ``native`` means the container IS the format (no emulation).
    """

    name: str                # canonical name, e.g. "float4_e2m1fn"
    bits: int                # true format width (storage accounting)
    max_finite: float        # format's largest finite magnitude
    container: Any           # jnp-compatible dtype holding the values
    round_dtype: Optional[Any]   # ml_dtypes dtype for host rounding
    native: bool             # container == format in this JAX
    packed: Optional[PackedSpec] = None   # sub-byte bit-packed layout

    @property
    def emulated(self) -> bool:
        return not self.native

    @property
    def packable(self) -> bool:
        return self.packed is not None

    def describe(self) -> str:
        suffix = (f"; packed {self.packed.bytes_per_element:g} B/elem"
                  if self.packed is not None else "")
        if self.native:
            return f"native{suffix}"
        return (f"emulated ({np.dtype(self.container).name} container, "
                f"{'host-rounded' if self.round_dtype is not None else 'exact'}"
                f"{suffix})")


def _jnp_dtype(name: str):
    """jnp.<name> if this JAX registers it as a real array dtype."""
    import jax.numpy as jnp

    dt = getattr(jnp, name, None)
    if dt is None:
        return None
    try:                      # probe: can JAX actually hold an array of it?
        np.zeros(1, dtype=np.dtype(dt))
        jnp.zeros((1,), dtype=dt)
    except Exception:
        return None
    return dt


@functools.lru_cache(maxsize=None)
def dtype_registry() -> Dict[str, DTypeSpec]:
    """name -> DTypeSpec for every paper format, probed once per process.

    Fallback ladder per format: native jnp dtype -> fp8 e4m3 container
    with ml_dtypes host rounding (every fp6/fp4 value is exactly
    representable in e4m3: narrower mantissa AND exponent range).
    """
    import jax.numpy as jnp

    e4m3 = _jnp_dtype("float8_e4m3fn") or jnp.bfloat16

    # name, bits, max_finite, ml_dtypes rounding dtype used when the
    # format has no native jnp dtype and must round on the host
    table = [
        ("float8_e4m3fn", 8, 448.0, ml_dtypes.float8_e4m3fn),
        ("float8_e5m2", 8, 57344.0, ml_dtypes.float8_e5m2),
        ("float6_e2m3fn", 6, 7.5, ml_dtypes.float6_e2m3fn),
        ("float6_e3m2fn", 6, 28.0, ml_dtypes.float6_e3m2fn),
        ("float4_e2m1fn", 4, 6.0, ml_dtypes.float4_e2m1fn),
    ]
    reg: Dict[str, DTypeSpec] = {}
    for name, bits, fmax, round_dt in table:
        packed = _lowbits_packed_spec(name) if is_packable(name) else None
        native = _jnp_dtype(name)
        if native is not None:
            reg[name] = DTypeSpec(name=name, bits=bits, max_finite=fmax,
                                  container=native, round_dtype=None,
                                  native=True, packed=packed)
        else:
            reg[name] = DTypeSpec(name=name, bits=bits, max_finite=fmax,
                                  container=e4m3, round_dtype=round_dt,
                                  native=False, packed=packed)
    return reg


def dtype_spec(name: str) -> DTypeSpec:
    try:
        return dtype_registry()[name]
    except KeyError:
        raise KeyError(
            f"unknown low-precision format {name!r}; known: "
            f"{sorted(dtype_registry())}") from None


def available_formats() -> Tuple[str, ...]:
    return tuple(dtype_registry())


def format_bits(name: str) -> int:
    return dtype_spec(name).bits


def packed_spec(name: str) -> Optional[PackedSpec]:
    """The sub-byte packed layout for ``name``, or None (byte formats)."""
    return dtype_spec(name).packed


def storage_bytes_per_element(name: str, packed: bool = True) -> float:
    """True storage B/elem: packed layout when available, else container."""
    spec = dtype_spec(name)
    if packed and spec.packed is not None:
        return spec.packed.bytes_per_element
    return float(np.dtype(spec.container).itemsize)


# --------------------------------------------------------------------- #
# shard_map resolution
# --------------------------------------------------------------------- #

@functools.lru_cache(maxsize=None)
def resolve_shard_map() -> Tuple[Callable, str]:
    """(shard_map callable, where it came from)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn, "jax.shard_map"
    from jax.experimental.shard_map import shard_map as fn  # noqa: F811
    return fn, "jax.experimental.shard_map"


@functools.lru_cache(maxsize=None)
def _shard_map_params() -> frozenset:
    fn, _ = resolve_shard_map()
    try:
        return frozenset(inspect.signature(fn).parameters)
    except (TypeError, ValueError):
        return frozenset()


def shard_map(f: Optional[Callable] = None, **kwargs):
    """Version-portable ``shard_map``.

    Accepts either kwarg spelling of the replication check
    (``check_vma`` — new JAX — or ``check_rep`` — old) and translates to
    whatever the installed ``shard_map`` understands; unsupported kwargs
    are dropped rather than raised.  Usable directly or as a decorator
    factory (``shard_map(mesh=..., ...)(f)``), mirroring upstream.
    """
    if f is None:
        return functools.partial(shard_map, **kwargs)
    fn, _ = resolve_shard_map()
    params = _shard_map_params()
    check = kwargs.pop("check_vma", kwargs.pop("check_rep", None))
    if check is not None:
        if "check_vma" in params:
            kwargs["check_vma"] = check
        elif "check_rep" in params:
            kwargs["check_rep"] = check
    if params:
        kwargs = {k: v for k, v in kwargs.items() if k in params}
    return fn(f, **kwargs)


# --------------------------------------------------------------------- #
# Pallas: interpret-mode fallback + compiler-params portability
# --------------------------------------------------------------------- #

def pallas_interpret_default() -> bool:
    """True off-TPU: run kernels through the Pallas interpreter so the
    whole suite executes (and is testable) on any backend; Mosaic-compile
    natively when real hardware is present."""
    return not is_tpu()


@functools.lru_cache(maxsize=None)
def _compiler_params_cls() -> Tuple[Optional[type], str]:
    from jax.experimental.pallas import tpu as pltpu

    for attr in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, attr, None)
        if cls is not None:
            return cls, f"pltpu.{attr}"
    return None, "dict"


def tpu_compiler_params(**kwargs):
    """Build TPU compiler params via whichever API this JAX exposes.

    ``pltpu.CompilerParams`` (new) -> ``pltpu.TPUCompilerParams`` (older)
    -> plain ``dict(mosaic=...)`` (oldest).  Kwargs the installed class
    doesn't know are dropped so callers can always pass the full set.
    """
    cls, _ = _compiler_params_cls()
    if cls is None:
        return dict(mosaic=dict(kwargs))
    try:
        fields = {f.name for f in dataclasses.fields(cls)}
        kwargs = {k: v for k, v in kwargs.items() if k in fields}
    except TypeError:
        pass
    return cls(**kwargs)


def vmem_budget_bytes() -> int:
    """Per-core VMEM available to a single Pallas grid step, in bytes.

    TPU cores carry ~16 MiB of VMEM (see the Pallas TPU docs); Mosaic
    needs headroom for double-buffered pipelining, so the usable budget
    for one grid step's blocks + scratch is roughly half.  Off-TPU the
    interpreter has no such limit, but the static checker
    (:mod:`repro.analysis.pallas_check`) still enforces the TPU budget so
    kernels developed under interpret mode don't blow up on hardware.
    Override with ``REPRO_VMEM_BUDGET_BYTES`` when targeting parts with
    different VMEM (e.g. v4's 32 MiB variants).
    """
    env = os.environ.get("REPRO_VMEM_BUDGET_BYTES")
    if env:
        return int(env)
    return 8 * 1024 * 1024


def pallas_call(kernel: Callable, *, interpret: Optional[bool] = None,
                dimension_semantics: Optional[Tuple[str, ...]] = None,
                compiler_params: Any = None, **kwargs):
    """``pl.pallas_call`` with capability-aware defaults.

    * ``interpret=None`` resolves via :func:`pallas_interpret_default` —
      native Mosaic on TPU, interpreter elsewhere.
    * ``dimension_semantics`` builds ``compiler_params`` through
      :func:`tpu_compiler_params`, insulating kernels from the
      CompilerParams/TPUCompilerParams rename.
    """
    from jax.experimental import pallas as pl

    if interpret is None:
        interpret = pallas_interpret_default()
    if compiler_params is None and dimension_semantics is not None:
        compiler_params = tpu_compiler_params(
            dimension_semantics=tuple(dimension_semantics))
    if compiler_params is not None:
        kwargs["compiler_params"] = compiler_params
    return pl.pallas_call(kernel, interpret=interpret, **kwargs)


# --------------------------------------------------------------------- #
# Optional test/tooling deps
# --------------------------------------------------------------------- #

@functools.lru_cache(maxsize=None)
def has_hypothesis() -> bool:
    return importlib.util.find_spec("hypothesis") is not None


# --------------------------------------------------------------------- #
# Capability report
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class CompatReport:
    jax_version: str
    platform: str
    device_count: int
    pallas_mode: str             # "native-mosaic" | "interpret"
    compiler_params_api: str
    shard_map_source: str
    formats: Dict[str, str]      # name -> "native" | "emulated (...)"
    hypothesis: bool

    def lines(self) -> List[str]:
        out = [
            f"compat,jax={self.jax_version},platform={self.platform},"
            f"devices={self.device_count}",
            f"compat,pallas={self.pallas_mode},"
            f"compiler_params={self.compiler_params_api},"
            f"shard_map={self.shard_map_source},"
            f"hypothesis={'yes' if self.hypothesis else 'no'}",
        ]
        out += [f"compat,format={name},{how}"
                for name, how in self.formats.items()]
        return out

    def __str__(self) -> str:
        return "\n".join(self.lines())


def report() -> CompatReport:
    """Probe everything once and return the capability report that the
    benchmark runner and examples print at startup, so every artifact
    records which paths ran native vs. emulated."""
    _, cp_api = _compiler_params_cls()
    _, sm_src = resolve_shard_map()
    try:
        n_dev = jax.device_count()
    except Exception:
        n_dev = 0
    return CompatReport(
        jax_version=jax.__version__,
        platform=backend_platform(),
        device_count=n_dev,
        pallas_mode="interpret" if pallas_interpret_default()
        else "native-mosaic",
        compiler_params_api=cp_api,
        shard_map_source=sm_src,
        formats={name: spec.describe()
                 for name, spec in dtype_registry().items()},
        hypothesis=has_hypothesis(),
    )
