"""Data pipeline: deterministic synthetic LM streams + packing."""

from repro.data.synthetic import (  # noqa: F401
    SyntheticConfig,
    SyntheticStream,
    make_stream,
)
from repro.data.packing import pack_documents  # noqa: F401
