"""Deterministic synthetic LM data.

Every batch is a pure function of (seed, step, process topology), so:
  * restarts resume mid-epoch with no state to checkpoint beyond ``step``
    (the fault-tolerance property the train loop relies on),
  * elastic re-mesh replays the identical token stream on a different
    process count (host-sharded slicing by ``process_index``).

Task kinds:
  * ``affine``  — t_{i+1} = (a * t_i + b) mod v on a reduced vocab; a 1-layer
    model can learn it, so loss-decreases tests converge in tens of steps.
  * ``uniform`` — i.i.d. tokens (worst case; loss floor = log v).
  * ``zipf``    — Zipf-distributed unigrams (realistic embedding traffic).

Modality archs get deterministic frame/patch embeddings keyed the same way.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.model import batch_fields


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    kind: str = "affine"          # affine | uniform | zipf
    seed: int = 0
    affine_a: int = 5
    affine_b: int = 17
    affine_vocab: int = 97        # prime => full cycle
    zipf_alpha: float = 1.2


class SyntheticStream:
    """Stateless stream: ``batch(step)`` is deterministic."""

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig,
                 data_cfg: SyntheticConfig = SyntheticConfig(),
                 process_index: int = 0, process_count: int = 1):
        self.cfg, self.shape, self.data_cfg = cfg, shape, data_cfg
        assert shape.global_batch % process_count == 0
        self.local_batch = shape.global_batch // process_count
        self.process_index = process_index
        self.fields = batch_fields(cfg, shape)

    def _tokens(self, key: jax.Array, shape: tuple) -> jax.Array:
        d = self.data_cfg
        v = min(d.affine_vocab, self.cfg.vocab_size)
        if d.kind == "uniform":
            return jax.random.randint(key, shape, 0, self.cfg.vocab_size,
                                      jnp.int32)
        if d.kind == "zipf":
            ranks = jnp.arange(1, self.cfg.vocab_size + 1, dtype=jnp.float32)
            logp = -d.zipf_alpha * jnp.log(ranks)
            return jax.random.categorical(
                key, jnp.broadcast_to(logp, shape + (self.cfg.vocab_size,)))
        # affine chain
        t0 = jax.random.randint(key, shape[:-1] + (1,), 0, v, jnp.int32)
        def step(t, _):
            nxt = (d.affine_a * t + d.affine_b) % v
            return nxt, nxt
        _, seq = jax.lax.scan(step, t0[..., 0], None, length=shape[-1] - 1)
        seq = jnp.moveaxis(seq, 0, -1)
        return jnp.concatenate([t0, seq], axis=-1)

    def batch(self, step: int) -> Dict[str, jax.Array]:
        base = jax.random.PRNGKey(self.data_cfg.seed)
        key = jax.random.fold_in(jax.random.fold_in(base, step),
                                 self.process_index)
        out = {}
        for name, (shp, dtype) in self.fields.items():
            key, sub = jax.random.split(key)
            local = (self.local_batch,) + tuple(shp[1:])
            if dtype == "int32":
                out[name] = self._tokens(sub, local)
            else:
                out[name] = (jax.random.normal(sub, local, jnp.float32)
                             * 0.02).astype(jnp.dtype(dtype))
        return out

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_stream(cfg: ArchConfig, shape: ShapeConfig,
                data_cfg: Optional[SyntheticConfig] = None,
                ) -> SyntheticStream:
    return SyntheticStream(cfg, shape, data_cfg or SyntheticConfig())


def host_prompt(length: int, seed: int, vocab_size: int,
                kind: str = "affine",
                data_cfg: SyntheticConfig = SyntheticConfig()) -> list:
    """One deterministic prompt as a host-side Python list.

    Same task kinds as :class:`SyntheticStream` but generated with seeded
    NumPy on the host — serving-side arrival traces must never touch
    device RNG or wall-clock inside traced scope (lint rule JL104), and
    a list of ints is exactly what ``ServeEngine.submit`` takes.
    """
    if length < 1:
        raise ValueError("prompt length must be >= 1")
    rng = np.random.default_rng(seed)
    d = data_cfg
    if kind == "uniform":
        return rng.integers(0, vocab_size, size=length).tolist()
    if kind == "zipf":
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = ranks ** -d.zipf_alpha
        p /= p.sum()
        return rng.choice(vocab_size, size=length, p=p).tolist()
    if kind != "affine":
        raise ValueError(f"unknown prompt kind {kind!r}")
    v = min(d.affine_vocab, vocab_size)
    t = int(rng.integers(0, v))
    out = [t]
    for _ in range(length - 1):
        t = (d.affine_a * t + d.affine_b) % v
        out.append(t)
    return out
