"""Sequence packing: concatenate variable-length documents into fixed
training rows with loss masks that zero the first token after each
boundary (no cross-document next-token supervision).

Greedy first-fit packing; numpy-level (host side, pre-device)."""

from __future__ import annotations

from typing import Iterable, List, Tuple

import numpy as np


def pack_documents(docs: Iterable[np.ndarray], seq_len: int,
                   pad_id: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack ``docs`` (1-D int arrays) into rows of ``seq_len``.

    Returns (tokens (n, s), loss_mask (n, s) float32, segment_ids (n, s)).
    loss_mask is 0 on padding and on the first token of every document
    (its "previous token" belongs to another document).
    """
    rows: List[List[np.ndarray]] = []
    space: List[int] = []
    for doc in docs:
        doc = np.asarray(doc, np.int32)
        if doc.size == 0:
            continue
        while doc.size > 0:
            placed = False
            for i, s in enumerate(space):
                if doc.size <= s:
                    rows[i].append(doc)
                    space[i] -= doc.size
                    placed = True
                    break
            if placed:
                break
            if doc.size >= seq_len:
                rows.append([doc[:seq_len]])
                space.append(0)
                doc = doc[seq_len:]
            else:
                rows.append([doc])
                space.append(seq_len - doc.size)
                break

    n = len(rows)
    tokens = np.full((n, seq_len), pad_id, np.int32)
    mask = np.zeros((n, seq_len), np.float32)
    seg = np.zeros((n, seq_len), np.int32)
    for i, docs_i in enumerate(rows):
        off = 0
        for j, d in enumerate(docs_i):
            tokens[i, off:off + d.size] = d
            mask[i, off:off + d.size] = 1.0
            mask[i, off] = 0.0                 # no cross-doc supervision
            seg[i, off:off + d.size] = j + 1
            off += d.size
    return tokens, mask, seg
