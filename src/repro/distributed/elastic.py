"""Elasticity + straggler mitigation.

A 1000+-node job WILL lose nodes mid-run; the framework's posture:

* **Elastic re-mesh** — checkpoints store logical PartitionSpecs (not
  device layouts).  :func:`remesh` re-shards any pytree onto a *different*
  mesh shape deterministically, so a job that lost a pod restarts on the
  surviving 16x16 slice from the same checkpoint (exercised by
  tests/test_checkpoint.py on 1->N fake devices).

* **Straggler watchdog** — :class:`StepWatchdog` tracks a rolling median
  of step times; a step exceeding ``deadline_factor`` x median raises a
  straggler event.  On real pods the registered callback triggers
  checkpoint-and-reschedule (here: log + count, and the train loop's
  snapshot path is the tested part).

* **Heartbeat** — :class:`Heartbeat` is the per-process liveness file
  (mtime-updated every step); an external supervisor restarts ranks whose
  heartbeat goes stale.  File-based so it works on any cluster manager.
"""

from __future__ import annotations

import dataclasses
import os
import statistics
import time
from typing import Any, Callable, List, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def remesh(tree: Any, specs: Any, new_mesh: Mesh) -> Any:
    """Re-shard ``tree`` onto ``new_mesh`` using its logical ``specs``.

    Divisibility degradation is re-evaluated for the new mesh: a spec axis
    that no longer divides is dropped to replication (the same fallback
    rule the original sharding used).
    """
    def place(x, spec):
        axes = []
        for dim, ax in zip(x.shape, tuple(spec) + (None,) * 99):
            if ax is None:
                axes.append(None)
                continue
            names = (ax,) if isinstance(ax, str) else tuple(ax)
            names = tuple(a for a in names if a in new_mesh.axis_names)
            size = 1
            for a in names:
                size *= new_mesh.shape[a]
            axes.append(names if names and dim % size == 0 else None)
        spec2 = PartitionSpec(*[a if not isinstance(a, tuple) or len(a) > 1
                                else a[0] for a in axes])
        return jax.device_put(x, NamedSharding(new_mesh, spec2))

    return jax.tree.map(place, tree, specs,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float


class StepWatchdog:
    """Rolling-median step-time monitor with a deadline callback."""

    def __init__(self, deadline_factor: float = 3.0, window: int = 32,
                 on_straggler: Optional[Callable[[StragglerEvent], None]]
                 = None):
        self.deadline_factor = deadline_factor
        self.window = window
        self.on_straggler = on_straggler
        self.durations: List[float] = []
        self.events: List[StragglerEvent] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self, step: int) -> None:
        self._step = step
        self._t0 = time.perf_counter()

    def end_step(self) -> Optional[StragglerEvent]:
        assert self._t0 is not None, "end_step without start_step"
        dur = time.perf_counter() - self._t0
        self._t0 = None
        event = None
        if len(self.durations) >= 4:
            med = statistics.median(self.durations[-self.window:])
            if dur > self.deadline_factor * med:
                event = StragglerEvent(self._step, dur, med)
                self.events.append(event)
                if self.on_straggler:
                    self.on_straggler(event)
        self.durations.append(dur)
        return event

    @property
    def median_s(self) -> float:
        return statistics.median(self.durations) if self.durations else 0.0


class Heartbeat:
    """Liveness file touched every step; supervisors watch its mtime."""

    def __init__(self, path: str, process_index: Optional[int] = None):
        pid = (jax.process_index() if process_index is None
               else process_index)
        self.path = os.path.join(path, f"heartbeat.{pid}")
        os.makedirs(path, exist_ok=True)

    def beat(self, step: int) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            f.write(f"{step} {time.time()}\n")
        os.replace(tmp, self.path)

    def last(self) -> Optional[tuple]:
        try:
            with open(self.path) as f:
                step, ts = f.read().split()
            return int(step), float(ts)
        except (FileNotFoundError, ValueError):
            return None

    def stale(self, timeout_s: float) -> bool:
        last = self.last()
        return last is None or (time.time() - last[1]) > timeout_s
