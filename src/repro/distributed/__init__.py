"""Distribution layer: sharding rules, compressed collectives, elasticity."""

from repro.distributed.sharding import (  # noqa: F401
    axis_size,
    batch_specs,
    cache_shardings,
    cache_specs,
    device_put_store,
    dp_axes,
    logits_spec,
    named,
    param_shardings,
    param_specs,
    serving_shardings,
    spec_local_bytes,
    state_shardings,
    state_specs,
    weight_store_shardings,
    weight_store_specs,
)
from repro.distributed.compression import (  # noqa: F401
    compressed_psum,
    compressed_psum_tree,
    quantize,
    stochastic_round,
)
from repro.distributed.elastic import (  # noqa: F401
    Heartbeat,
    StepWatchdog,
    StragglerEvent,
    remesh,
)
