"""Distribution layer: sharding rules, compressed collectives, elasticity."""

from repro.distributed.sharding import (  # noqa: F401
    axis_size,
    batch_specs,
    cache_specs,
    dp_axes,
    named,
    param_shardings,
    param_specs,
    spec_local_bytes,
)
from repro.distributed.compression import (  # noqa: F401
    compressed_psum,
    compressed_psum_tree,
    quantize,
    stochastic_round,
)
from repro.distributed.elastic import (  # noqa: F401
    Heartbeat,
    StepWatchdog,
    StragglerEvent,
    remesh,
)
