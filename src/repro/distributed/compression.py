"""Gradient compression — int8 stochastic-rounding all-reduce.

Beyond-paper distributed-optimization trick, directly motivated by the
paper's §V.C finding (energy and bandwidth scale down with precision:
FP4 16.8 W < FP6 ~39 W < FP8 ~47 W at iso-work): the DP gradient
all-reduce is the dominant *collective* term for small-model/large-mesh
cells, and its payload tolerates 8-bit quantization when rounding is
unbiased.

Scheme (used by the shard_map DP trainer, ``repro.train.local_dp``):
  1. global scale  = pmax(|g|_inf) / qmax          (tiny scalar collective)
  2. q = stochastic_round(g / scale)  in int8 range
  3. psum(q) accumulated in int16/int32 (qmax chosen so the sum of
     ``world`` shards cannot overflow)
  4. g_hat = q_sum * scale / world

Wire bytes: 2 B/element (int16) vs 4 B fp32 — 2x reduction; unbiased:
E[q] = g/scale exactly (property-tested in tests/test_compression.py).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def stochastic_round(x: jax.Array, key: jax.Array) -> jax.Array:
    """Unbiased randomized rounding to the nearest integers."""
    floor = jnp.floor(x)
    frac = x - floor
    return floor + (jax.random.uniform(key, x.shape) < frac)


def quantize(g: jax.Array, key: jax.Array, qmax: int
             ) -> Tuple[jax.Array, jax.Array]:
    """(int8 payload, fp32 scale); stochastic rounding keeps E[deq] = g."""
    scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / qmax
    scale = jnp.maximum(scale, 1e-30)
    q = stochastic_round(g.astype(jnp.float32) / scale, key)
    return jnp.clip(q, -qmax, qmax).astype(jnp.int8), scale


def compressed_psum(g: jax.Array, key: jax.Array, axis_name: str,
                    world: int) -> jax.Array:
    """Mean of ``g`` over ``axis_name`` with an int8-quantized payload.

    Must run inside shard_map/pmap with ``axis_name`` bound.  ``qmax`` is
    chosen so ``world * qmax`` fits the int16 accumulator.  Scales are
    per-row (leading dim) for matrices — a per-tensor scale lets one
    outlier (embedding rows) flush every other gradient to zero, which
    measurably stalls training (tests/test_compression.py).
    """
    qmax = min(127, max(1, 32767 // max(world, 1)))
    gf = g.astype(jnp.float32)
    if g.ndim >= 2:
        axes = tuple(range(1, g.ndim))
        local_scale = jnp.max(jnp.abs(gf), axis=axes, keepdims=True) / qmax
    else:
        local_scale = jnp.max(jnp.abs(gf)) / qmax
    scale = jax.lax.pmax(jnp.maximum(local_scale, 1e-30), axis_name)
    q = stochastic_round(gf / scale, key)
    q = jnp.clip(q, -qmax, qmax).astype(jnp.int16)
    q_sum = jax.lax.psum(q, axis_name)
    return (q_sum.astype(jnp.float32) * scale / world).astype(g.dtype)


def compressed_psum_tree(grads: Any, key: jax.Array, axis_name: str,
                         world: int) -> Any:
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = [compressed_psum(g, k, axis_name, world)
           for g, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)
