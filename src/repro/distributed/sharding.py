"""Sharding rules: parameter / batch / cache PartitionSpecs for the
production meshes.

Parallelism map (DESIGN.md §6):
  * DP   — batch on ('pod', 'data')
  * TP   — heads / d_ff / d_inner / vocab on 'model'
  * EP   — MoE expert dim on 'model'
  * FSDP — for cfg.fsdp archs (>=52B), parameter d_model dims additionally
           sharded over ('pod', 'data'); XLA all-gathers just-in-time
  * SP   — long-context decode (batch < dp size): KV-cache sequence dim
           sharded on 'data' (flash-decoding-style partial softmax; XLA
           inserts the combine)

Every rule degrades gracefully: a dim that is not divisible by its mesh
axis is replicated instead (e.g. kv_heads=8 on model=16 — the standard
Megatron MQA/GQA fallback).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig


# --------------------------------------------------------------------- #
# Mesh helpers
# --------------------------------------------------------------------- #

def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _maybe(mesh: Mesh, dim: int, axes) -> Optional[Any]:
    """axes if dim divides evenly over them, else None (replicate).

    Normalized: a single axis comes back as its bare name (``"data"``,
    never the 1-tuple ``("data",)``) so spec entries compare uniformly;
    only genuinely multi-axis placements stay tuples."""
    if axes is None or dim <= 0:
        return None
    size = axis_size(mesh, axes)
    if size > 1 and dim % size == 0:
        if isinstance(axes, str):
            return axes
        axes = tuple(axes)
        return axes[0] if len(axes) == 1 else axes
    return None


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# --------------------------------------------------------------------- #
# Parameter specs
# --------------------------------------------------------------------- #

def _param_rule(names: Sequence[str], shape: Tuple[int, ...],
                cfg: ArchConfig, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path."""
    fsdp = dp_axes(mesh) if cfg.fsdp else None
    name = names[-1]
    stacked = "layers" in names[:2]         # scanned: leading period dim

    def f(dim):                             # fsdp placement for this dim
        return _maybe(mesh, dim, fsdp)

    def m(dim):
        return _maybe(mesh, dim, "model")

    def f_dp(dim, heads):
        """d_model placement for attention weights: FSDP axes when
        enabled; otherwise fall back to the data axes WHEN the head dim
        cannot shard on 'model' (24 q-heads / 8 kv-heads on a 16-way
        model axis) — leaving those weights fully replicated costs
        n_layers fp32 gradient copies per device (measured +20 GiB on
        llama3.2 train; EXPERIMENTS.md §Perf)."""
        if fsdp:
            return _maybe(mesh, dim, fsdp)
        if m(heads) is None:
            return _maybe(mesh, dim, dp_axes(mesh))
        return None

    base: Tuple = ()
    if name == "embed":
        base = (m(shape[0]), f(shape[1]))
    elif name == "unembed":
        base = (f(shape[0]), m(shape[1]))
    elif name in ("final_norm", "gate_norm") or name.startswith("ln_"):
        core = shape[1:] if stacked else shape
        base = tuple(None for _ in core)
    elif name == "wq":
        base = (f_dp(shape[-3], shape[-2]), m(shape[-2]), None)
    elif name in ("wk", "wv"):
        base = (f_dp(shape[-3], shape[-2]), m(shape[-2]), None)
    elif name == "wo":
        base = (m(shape[-3]), None, f_dp(shape[-1], shape[-3]))
    elif name in ("bq", "bk", "bv"):
        base = (m(shape[-2]), None)
    elif name in ("w1", "w3"):
        if len(shape) - (1 if stacked else 0) == 3:   # MoE (E, D, F)
            # EP on 'model' + FSDP on the *d_ff* dim: sharding d_model
            # would force a full weight all-gather per microbatch
            # (measured ~2 TB/device/step on kimi train); d_ff sharding
            # replaces it with an activation psum (§Perf iteration)
            base = (m(shape[-3]), None, f(shape[-1]))
        else:                                          # dense (D, F)
            base = (f(shape[-2]), m(shape[-1]))
    elif name == "w2":
        if len(shape) - (1 if stacked else 0) == 3:   # MoE (E, F, D)
            base = (m(shape[-3]), f(shape[-2]), None)
        else:                                          # dense (F, D)
            base = (m(shape[-2]), f(shape[-1]))
    elif name == "router":
        base = (None, None)
    elif name in ("wz", "wx"):
        base = (f(shape[-2]), m(shape[-1]))
    elif name in ("wb", "wc"):
        base = (f(shape[-2]), None)
    elif name == "wdt":
        base = (f(shape[-2]), m(shape[-1]))
    elif name in ("conv_x_w",):
        base = (m(shape[-2]), None)
    elif name in ("conv_x_b",):
        base = (m(shape[-1]),)
    elif name in ("conv_b_w", "conv_c_w"):
        base = (None, None)
    elif name in ("conv_b_b", "conv_c_b"):
        base = (None,)
    elif name in ("A_log", "dt_bias", "D"):
        base = (m(shape[-1]),)
    elif name == "out_proj":
        base = (m(shape[-2]), f(shape[-1]))
    elif name == "in_proj":
        base = (f(shape[-2]), None)
    else:
        base = tuple(None for _ in (shape[1:] if stacked else shape))
    if stacked:
        base = (None,) + tuple(base)
    assert len(base) == len(shape), (names, shape, base)
    return P(*base)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_specs(cfg: ArchConfig, mesh: Mesh, params_shapes) -> Any:
    """PartitionSpec tree matching ``params_shapes`` (eval_shape output)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_rule(_path_names(path), leaf.shape,
                                       cfg, mesh),
        params_shapes)


def param_shardings(cfg: ArchConfig, mesh: Mesh, params_shapes) -> Any:
    return jax.tree.map(lambda s: named(mesh, s),
                        param_specs(cfg, mesh, params_shapes))


# --------------------------------------------------------------------- #
# Batch / cache specs
# --------------------------------------------------------------------- #

def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                fields: Dict[str, Tuple[tuple, str]]) -> Dict[str, P]:
    dp = dp_axes(mesh)
    out = {}
    for fname, (shp, _) in fields.items():
        b_axis = _maybe(mesh, shp[0], dp)
        out[fname] = P(b_axis, *(None for _ in shp[1:]))
    return out


def _kv_seq_axes(mesh: Mesh, batch: int, seq: int, heads: int):
    """(batch_axes, seq_axes, head_axes) for a KV-cache leaf.

    Axes the batch/head dims cannot absorb fall through to the sequence
    dim (flash-decoding-style sequence-parallel KV): GQA kv_heads=8 on a
    16-way 'model' axis would otherwise replicate the cache 16x — the
    dominant decode_32k memory blowup found in the first dry-run sweep
    (EXPERIMENTS.md §Perf)."""
    dp = dp_axes(mesh)
    b = _maybe(mesh, batch, dp)
    h = _maybe(mesh, heads, "model")
    spill = []
    if b is None:
        spill.extend(dp)
    if h is None:
        spill.append("model")
    s = _maybe(mesh, seq, tuple(spill)) if spill else None
    return b, s, h


def cache_rule(names: Sequence[str], shape: Tuple[int, ...],
               cfg: ArchConfig, mesh: Mesh) -> P:
    """Spec for a decode-cache leaf (leading dim = period stack except
    enc_out)."""
    dp = dp_axes(mesh)
    name = names[-1]
    if name == "enc_out":
        b = _maybe(mesh, shape[0], dp)
        return P(b, None, None)
    # all other leaves are period-stacked: shape[0] = n_periods
    batch = shape[1]
    if name in ("k", "v"):
        b, s, h = _kv_seq_axes(mesh, batch, shape[2], shape[3])
        return P(None, b, s, h, None)
    if name == "slot_pos":
        b, s, _ = _kv_seq_axes(mesh, batch, shape[2], cfg.n_kv_heads)
        return P(None, b, s)
    b = _maybe(mesh, batch, dp)
    if name == "conv":
        return P(None, b, None, None)
    if name == "state":
        heads = _maybe(mesh, shape[2], "model")
        return P(None, b, heads, None, None)
    return P(*(None for _ in shape))


def cache_specs(cfg: ArchConfig, mesh: Mesh, cache_shapes) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_rule(_path_names(path), leaf.shape,
                                      cfg, mesh),
        cache_shapes)


# --------------------------------------------------------------------- #
# Sizing report (used by the dry-run and tests)
# --------------------------------------------------------------------- #

def spec_local_bytes(shapes_tree, specs_tree, mesh: Mesh) -> int:
    """Per-device bytes of a sharded pytree (exact, from specs)."""
    total = 0
    for leaf, spec in zip(jax.tree.leaves(shapes_tree),
                          jax.tree.leaves(specs_tree,
                                          is_leaf=lambda x: isinstance(x, P))):
        n = leaf.dtype.itemsize
        for dim, axes in zip(leaf.shape, tuple(spec) + (None,) * 99):
            div = axis_size(mesh, axes) if axes else 1
            n *= math.ceil(dim / div)
        total += n
    return total
