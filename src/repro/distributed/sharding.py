"""Sharding rules: parameter / batch / cache PartitionSpecs for the
production meshes.

Parallelism map (DESIGN.md §6):
  * DP   — batch on ('pod', 'data')
  * TP   — heads / d_ff / d_inner / vocab on 'model'
  * EP   — MoE expert dim on 'model'
  * FSDP — for cfg.fsdp archs (>=52B), parameter d_model dims additionally
           sharded over ('pod', 'data'); XLA all-gathers just-in-time
  * SP   — long-context decode (batch < dp size): KV-cache sequence dim
           sharded on 'data' (flash-decoding-style partial softmax; XLA
           inserts the combine)

Every rule degrades gracefully: a dim that is not divisible by its mesh
axis is replicated instead (e.g. kv_heads=8 on model=16 — the standard
Megatron MQA/GQA fallback).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.attention import QUANT_KV_LEAVES
from repro.models.slotstate import SLOT_STATE_FIELDS


# --------------------------------------------------------------------- #
# Mesh helpers
# --------------------------------------------------------------------- #

def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _maybe(mesh: Mesh, dim: int, axes) -> Optional[Any]:
    """axes if dim divides evenly over them, else None (replicate).

    Normalized: a single axis comes back as its bare name (``"data"``,
    never the 1-tuple ``("data",)``) so spec entries compare uniformly;
    only genuinely multi-axis placements stay tuples."""
    if axes is None or dim <= 0:
        return None
    size = axis_size(mesh, axes)
    if size > 1 and dim % size == 0:
        if isinstance(axes, str):
            return axes
        axes = tuple(axes)
        return axes[0] if len(axes) == 1 else axes
    return None


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# --------------------------------------------------------------------- #
# Parameter specs
# --------------------------------------------------------------------- #

def _param_rule(names: Sequence[str], shape: Tuple[int, ...],
                cfg: ArchConfig, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path."""
    fsdp = dp_axes(mesh) if cfg.fsdp else None
    name = names[-1]
    stacked = "layers" in names[:2]         # scanned: leading period dim

    def f(dim):                             # fsdp placement for this dim
        return _maybe(mesh, dim, fsdp)

    def m(dim):
        return _maybe(mesh, dim, "model")

    def f_dp(dim, heads):
        """d_model placement for attention weights: FSDP axes when
        enabled; otherwise fall back to the data axes WHEN the head dim
        cannot shard on 'model' (24 q-heads / 8 kv-heads on a 16-way
        model axis) — leaving those weights fully replicated costs
        n_layers fp32 gradient copies per device (measured +20 GiB on
        llama3.2 train; EXPERIMENTS.md §Perf)."""
        if fsdp:
            return _maybe(mesh, dim, fsdp)
        if m(heads) is None:
            return _maybe(mesh, dim, dp_axes(mesh))
        return None

    base: Tuple = ()
    if name == "embed":
        base = (m(shape[0]), f(shape[1]))
    elif name == "unembed":
        base = (f(shape[0]), m(shape[1]))
    elif name in ("final_norm", "gate_norm") or name.startswith("ln_"):
        core = shape[1:] if stacked else shape
        base = tuple(None for _ in core)
    elif name == "wq":
        base = (f_dp(shape[-3], shape[-2]), m(shape[-2]), None)
    elif name in ("wk", "wv"):
        base = (f_dp(shape[-3], shape[-2]), m(shape[-2]), None)
    elif name == "wo":
        base = (m(shape[-3]), None, f_dp(shape[-1], shape[-3]))
    elif name in ("bq", "bk", "bv"):
        base = (m(shape[-2]), None)
    elif name in ("w1", "w3"):
        if len(shape) - (1 if stacked else 0) == 3:   # MoE (E, D, F)
            # EP on 'model' + FSDP on the *d_ff* dim: sharding d_model
            # would force a full weight all-gather per microbatch
            # (measured ~2 TB/device/step on kimi train); d_ff sharding
            # replaces it with an activation psum (§Perf iteration)
            base = (m(shape[-3]), None, f(shape[-1]))
        else:                                          # dense (D, F)
            base = (f(shape[-2]), m(shape[-1]))
    elif name == "w2":
        if len(shape) - (1 if stacked else 0) == 3:   # MoE (E, F, D)
            base = (m(shape[-3]), f(shape[-2]), None)
        else:                                          # dense (F, D)
            base = (m(shape[-2]), f(shape[-1]))
    elif name == "router":
        base = (None, None)
    elif name in ("wz", "wx"):
        base = (f(shape[-2]), m(shape[-1]))
    elif name in ("wb", "wc"):
        base = (f(shape[-2]), None)
    elif name == "wdt":
        base = (f(shape[-2]), m(shape[-1]))
    elif name in ("conv_x_w",):
        base = (m(shape[-2]), None)
    elif name in ("conv_x_b",):
        base = (m(shape[-1]),)
    elif name in ("conv_b_w", "conv_c_w"):
        base = (None, None)
    elif name in ("conv_b_b", "conv_c_b"):
        base = (None,)
    elif name in ("A_log", "dt_bias", "D"):
        base = (m(shape[-1]),)
    elif name == "out_proj":
        base = (m(shape[-2]), f(shape[-1]))
    elif name == "in_proj":
        base = (f(shape[-2]), None)
    else:
        base = tuple(None for _ in (shape[1:] if stacked else shape))
    if stacked:
        base = (None,) + tuple(base)
    assert len(base) == len(shape), (names, shape, base)
    return P(*base)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_specs(cfg: ArchConfig, mesh: Mesh, params_shapes) -> Any:
    """PartitionSpec tree matching ``params_shapes`` (eval_shape output)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_rule(_path_names(path), leaf.shape,
                                       cfg, mesh),
        params_shapes)


def param_shardings(cfg: ArchConfig, mesh: Mesh, params_shapes) -> Any:
    return jax.tree.map(lambda s: named(mesh, s),
                        param_specs(cfg, mesh, params_shapes))


# --------------------------------------------------------------------- #
# Batch / cache specs
# --------------------------------------------------------------------- #

def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                fields: Dict[str, Tuple[tuple, str]]) -> Dict[str, P]:
    dp = dp_axes(mesh)
    out = {}
    for fname, (shp, _) in fields.items():
        b_axis = _maybe(mesh, shp[0], dp)
        out[fname] = P(b_axis, *(None for _ in shp[1:]))
    return out


def _kv_seq_axes(mesh: Mesh, batch: int, seq: int, heads: int):
    """(batch_axes, seq_axes, head_axes) for a KV-cache leaf.

    Axes the batch/head dims cannot absorb fall through to the sequence
    dim (flash-decoding-style sequence-parallel KV): GQA kv_heads=8 on a
    16-way 'model' axis would otherwise replicate the cache 16x — the
    dominant decode_32k memory blowup found in the first dry-run sweep
    (EXPERIMENTS.md §Perf)."""
    dp = dp_axes(mesh)
    b = _maybe(mesh, batch, dp)
    h = _maybe(mesh, heads, "model")
    spill = []
    if b is None:
        spill.extend(dp)
    if h is None:
        spill.append("model")
    s = _maybe(mesh, seq, tuple(spill)) if spill else None
    return b, s, h


def cache_rule(names: Sequence[str], shape: Tuple[int, ...],
               cfg: ArchConfig, mesh: Mesh) -> P:
    """Spec for a decode-cache leaf (leading dim = period stack except
    enc_out)."""
    dp = dp_axes(mesh)
    name = names[-1]
    if name == "enc_out":
        b = _maybe(mesh, shape[0], dp)
        return P(b, None, None)
    # all other leaves are period-stacked: shape[0] = n_periods
    batch = shape[1]
    if name in ("k", "v") or name in QUANT_KV_LEAVES:
        # dense K/V *and* the quantized-store leaves (packed codes k_q/v_q
        # + e8m0 scale bytes k_s/v_s): pool slots on the data axes, heads
        # on 'model', GQA spill onto the sequence dim.  The packed last
        # dim (stored bytes / scale blocks, not head_dim) is never
        # sharded — sub-byte groups must stay device-local.  Self- and
        # cross-attention KV (``cross_kv``) share this rule: their leaf
        # names and layouts are identical (cross capacity = enc_len).
        b, s, h = _kv_seq_axes(mesh, batch, shape[2], shape[3])
        return P(None, b, s, h, None)
    if name == "slot_pos":
        b, s, _ = _kv_seq_axes(mesh, batch, shape[2], cfg.n_kv_heads)
        return P(None, b, s)
    b = _maybe(mesh, batch, dp)
    if name == "conv_x":
        # SSM carried conv window, x section (n_p, b, k-1, d_inner):
        # channels are the TP dim — shards with wx / conv_x_w on 'model'
        # so the decode window concat and depthwise conv stay local.
        return P(None, b, None, _maybe(mesh, shape[3], "model"))
    if name in ("conv_b", "conv_c"):
        # B/C conv sections (n_p, b, k-1, ssm_state): the state dim n is
        # head-shared (ngroups=1) and stays replicated, like wb/wc.
        return P(None, b, None, None)
    if name == "state":
        # SSM state (n_p, b, heads, head_dim, ssm_state): heads are the
        # TP dim (matches the wdt/A_log/D parameter placement on 'model').
        heads = _maybe(mesh, shape[2], "model")
        return P(None, b, heads, None, None)
    return P(*(None for _ in shape))


def cache_specs(cfg: ArchConfig, mesh: Mesh, cache_shapes) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_rule(_path_names(path), leaf.shape,
                                      cfg, mesh),
        cache_shapes)


def cache_shardings(cfg: ArchConfig, mesh: Mesh, cache_shapes) -> Any:
    return jax.tree.map(lambda s: named(mesh, s),
                        cache_specs(cfg, mesh, cache_shapes))


# --------------------------------------------------------------------- #
# Serving: slot state / quantized weight store / sample-point specs
# --------------------------------------------------------------------- #

def state_rule(name: str, mesh: Mesh) -> P:
    """Spec for one engine slot-state leaf (a (batch,) bookkeeping
    array — ``repro.models.slotstate.SLOT_STATE_FIELDS``).

    Replicated by design: the fused loop's bookkeeping arithmetic runs on
    logits that were just all-gathered at the sample point anyway, the
    leaves are a few bytes per slot, and the host reads ``active`` back
    once per K-step block — a dp-sharded slot state would turn that one
    designed readback into a cross-device gather per dispatch."""
    assert name in SLOT_STATE_FIELDS, name
    return P()


def state_specs(mesh: Mesh, state: Any) -> Any:
    return {name: state_rule(name, mesh) for name in state}


def state_shardings(mesh: Mesh, state: Any) -> Any:
    return jax.tree.map(lambda s: named(mesh, s),
                        state_specs(mesh, state),
                        is_leaf=lambda x: isinstance(x, P))


def logits_spec(mesh: Mesh) -> P:
    """Sample-point spec: fully replicated (b, vocab) logits.

    The unembedding leaves decode logits vocab-sharded over 'model' (the
    embed/unembed placement); sampling — argmax or the per-slot folded
    categorical — must see every vocab column AND feed the replicated
    slot state, so the one all-gather of the serving hot loop happens
    here, on the (batch, vocab) logits, and nowhere else."""
    return P()


def _fit_spec(spec: P, logical_shape: Tuple[int, ...],
              stored_shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Re-fit a logical-layout spec onto a *stored* (packed) layout: keep
    an axis assignment only where the stored dim still matches the
    logical dim and divides; packed/reblocked dims replicate."""
    base = tuple(spec) + (None,) * (len(stored_shape) - len(tuple(spec)))
    out = []
    for dim_l, dim_s, axes in zip(
            logical_shape + (0,) * 9, stored_shape, base):
        out.append(_maybe(mesh, dim_s, axes)
                   if dim_s == dim_l else None)
    return P(*out)


def weight_store_specs(cfg: ArchConfig, mesh: Mesh, store: Any) -> Any:
    """PartitionSpec tree for a ``serve.quant.quantize_tree`` store.

    Each quantized leaf is ``{"q": codes, "scales": e8m0 bytes, "shape":
    ..., ...}`` (``serve.quant._is_qleaf``): the spec is DERIVED from
    the dense :func:`_param_rule` placement of the same path, re-fitted
    onto the stored layout (bit-packing shrinks the last dim; the scale
    store reblocks it) — a dim whose size changed replicates, everything
    else shards exactly like the dense parameter it stores.  Metadata
    entries (``fmt``/``shape``/``packed``) map to None; passthrough
    (unquantized) leaves keep their dense rule."""
    from repro.serve.quant import _is_qleaf

    def visit(path, leaf):
        names = _path_names(path)
        if _is_qleaf(leaf):
            logical = tuple(leaf["shape"])
            base = _param_rule(names, logical, cfg, mesh)
            out = {k: None for k in leaf}
            out["q"] = _fit_spec(base, logical, leaf["q"].shape, mesh)
            out["scales"] = _fit_spec(base, logical,
                                      leaf["scales"].shape, mesh)
            return out
        return _param_rule(names, leaf.shape, cfg, mesh)

    return jax.tree_util.tree_map_with_path(visit, store,
                                            is_leaf=_is_qleaf)


def weight_store_shardings(cfg: ArchConfig, mesh: Mesh, store: Any) -> Any:
    return jax.tree.map(lambda s: named(mesh, s),
                        weight_store_specs(cfg, mesh, store),
                        is_leaf=lambda x: isinstance(x, P))


def device_put_store(store: Any, shardings: Any) -> Any:
    """``jax.device_put`` a quantize_tree store onto its shardings,
    leaving the non-array metadata entries (format strings, logical
    shape tuples, packed flags) untouched — a whole-tree device_put
    would try to place those as leaves."""
    from repro.serve.quant import _is_qleaf

    def put(x, sh):
        if _is_qleaf(x):
            return dict(x, q=jax.device_put(x["q"], sh["q"]),
                        scales=jax.device_put(x["scales"], sh["scales"]))
        return jax.device_put(x, sh)

    return jax.tree.map(put, store, shardings, is_leaf=_is_qleaf)


def serving_shardings(cfg: ArchConfig, mesh: Mesh, params, cache, state,
                      weight_store=None) -> Dict[str, Any]:
    """Every array the serving engine owns, mapped to an explicit
    NamedSharding: dense params, the (possibly quantized) cache pool, the
    slot-state leaves, the packed weight store, plus the sample-point
    logits sharding and the fully-replicated sharding host-read outputs
    use."""
    out = {
        "params": param_shardings(cfg, mesh, params),
        "cache": cache_shardings(cfg, mesh, cache),
        "state": state_shardings(mesh, state),
        "logits": named(mesh, logits_spec(mesh)),
        "replicated": named(mesh, P()),
    }
    if weight_store is not None:
        out["weights"] = weight_store_shardings(cfg, mesh, weight_store)
    return out


# --------------------------------------------------------------------- #
# Sizing report (used by the dry-run and tests)
# --------------------------------------------------------------------- #

def _leaf_bytes_per_element(leaf, fmt: Optional[str]) -> float:
    """Storage B/elem for one leaf: the compat registry's *packed*
    bytes/element when the leaf is a sub-byte store (fp4 0.5, fp6 0.75),
    else ``dtype.itemsize``.  Using itemsize for a uint8 code leaf that
    stands in for fp4/fp6 values over- or under-counts per-device
    memory: a LOGICAL-shape fp4 leaf at itemsize 1 reports 2x its real
    store, and a fp6 3-bytes-per-4 group has no itemsize at all."""
    if fmt:
        from repro import compat
        return compat.storage_bytes_per_element(fmt, packed=True)
    return float(leaf.dtype.itemsize)


def spec_local_bytes(shapes_tree, specs_tree, mesh: Mesh,
                     formats=None) -> int:
    """Per-device bytes of a sharded pytree (exact, from specs).

    ``formats``: optional — a format name (uniform: every leaf is stored
    in that sub-byte format at its LOGICAL shape) or a tree matching
    ``shapes_tree`` whose leaves are format names or None.  Sub-byte
    leaves are accounted via the compat registry's
    ``storage_bytes_per_element`` instead of ``dtype.itemsize``."""
    is_p = lambda x: isinstance(x, P)
    leaves = jax.tree.leaves(shapes_tree)
    specs = jax.tree.leaves(specs_tree, is_leaf=is_p)
    if formats is None or isinstance(formats, str):
        fmts = [formats] * len(leaves)
    else:
        fmts = jax.tree.leaves(
            formats, is_leaf=lambda x: x is None or isinstance(x, str))
    total = 0.0
    for leaf, spec, fmt in zip(leaves, specs, fmts):
        n = _leaf_bytes_per_element(leaf, fmt)
        for dim, axes in zip(leaf.shape, tuple(spec) + (None,) * 99):
            div = axis_size(mesh, axes) if axes else 1
            n *= math.ceil(dim / div)
        total += math.ceil(n)
    return int(total)
