#!/usr/bin/env python
"""jaxlint — the repo's trace-safety gate (tier-1 CI).

Usage:
    python -m tools.jaxlint src benchmarks            # lint (gate mode)
    python -m tools.jaxlint --write-baseline src ...  # (re)freeze baseline
    python -m tools.jaxlint --no-baseline src ...     # show everything
    python -m tools.jaxlint --contracts               # jaxpr contracts
    python -m tools.jaxlint --pallas                  # Pallas checker
    python -m tools.jaxlint --all src benchmarks      # lint + both

Exit code 0 iff no finding survives pragmas + baseline.  Rules, pragma
(`# jaxlint: disable=RULE(reason)`) and baseline semantics are
documented in ``repro.analysis.lint`` and README "Static analysis &
sanitizers".
"""

from __future__ import annotations

import argparse
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(_REPO, "src") not in sys.path:
    sys.path.insert(0, os.path.join(_REPO, "src"))

DEFAULT_BASELINE = os.path.join(_REPO, "tools", "jaxlint_baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="jaxlint", description=__doc__)
    ap.add_argument("paths", nargs="*", default=[],
                    help="files/directories to lint (default: src "
                         "benchmarks when run from the repo root)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the committed baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="freeze current findings as the new baseline")
    ap.add_argument("--contracts", action="store_true",
                    help="run the jaxpr contract checks (traces the "
                         "tiny quantized model; needs jax)")
    ap.add_argument("--pallas", action="store_true",
                    help="run the Pallas write-race/alias/VMEM checker")
    ap.add_argument("--all", action="store_true",
                    help="lint + --contracts + --pallas")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids (e.g. JL101,JL103)")
    args = ap.parse_args(argv)

    from repro.analysis import lint as L

    findings = []
    paths = args.paths
    if not paths and not (args.contracts or args.pallas):
        paths = [os.path.join(_REPO, "src"),
                 os.path.join(_REPO, "benchmarks")]

    if paths:
        cfg = L.LintConfig()
        if args.select:
            cfg.select = set(args.select.split(","))
        base = None if (args.no_baseline or args.write_baseline) \
            else L.load_baseline(args.baseline)
        lint_findings = L.lint_paths(paths, config=cfg, baseline=base,
                                     root=_REPO)
        if args.write_baseline:
            L.write_baseline(args.baseline, lint_findings)
            print(f"wrote {len(lint_findings)} finding(s) to "
                  f"{os.path.relpath(args.baseline, _REPO)}")
            return 0
        findings += lint_findings

    if args.contracts or args.all:
        from repro.analysis import contracts
        findings += contracts.check_entry_points()
    if args.pallas or args.all:
        from repro.analysis import pallas_check
        findings += pallas_check.check_kernels()

    for f in findings:
        print(f.render())
    n = len(findings)
    parts = []
    if paths:
        parts.append(",".join(os.path.relpath(p, _REPO) for p in paths))
    if args.contracts or args.all:
        parts.append("contracts")
    if args.pallas or args.all:
        parts.append("pallas")
    print(f"jaxlint: {n} finding(s) [{' + '.join(parts)}]")
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())
