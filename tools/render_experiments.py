"""Render EXPERIMENTS.md tables from the dry-run JSON directories.

    PYTHONPATH=src python tools/render_experiments.py
"""

import glob
import json
import os
import sys

HBM = 16 * 2**30


def load(dirname):
    out = {}
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        d = json.load(open(f))
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def fits(d):
    m = d["memory"]
    live = m["argument_bytes"] + m["temp_bytes"]
    return live <= HBM, live / 2**30


def roofline_table(cells, title):
    lines = [f"### {title}", "",
             "| cell | FLOPs/dev | bytes/dev | coll B/dev | compute ms | "
             "memory ms | coll ms | dominant | useful | MFU@bound | "
             "live GiB (fits 16?) |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), d in sorted(cells.items()):
        r = d["roofline"]
        ok, gib = fits(d)
        lines.append(
            f"| {a}/{s}/{m} | {d['flops_per_device']:.2e} | "
            f"{d['bytes_per_device']:.2e} | {d['collective_bytes']:.2e} | "
            f"{r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} | "
            f"{r['collective_s']*1e3:.1f} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['mfu']:.3f} | "
            f"{gib:.1f} ({'yes' if ok else 'NO'}) |")
    return "\n".join(lines) + "\n"


def dryrun_summary(cells):
    n = len(cells)
    n_fit = sum(1 for d in cells.values() if fits(d)[0])
    doms = {}
    for d in cells.values():
        doms[d["roofline"]["dominant"]] = \
            doms.get(d["roofline"]["dominant"], 0) + 1
    return n, n_fit, doms


def compare_table(base, opt):
    lines = ["| cell | memory ms (base -> opt) | coll ms (base -> opt) | "
             "MFU (base -> opt) |", "|---|---|---|---|"]
    for key in sorted(opt):
        if key not in base:
            continue
        b, o = base[key]["roofline"], opt[key]["roofline"]
        dm = (o["memory_s"] / b["memory_s"] - 1) * 100 if b["memory_s"] \
            else 0
        lines.append(
            f"| {'/'.join(key)} | {b['memory_s']*1e3:.1f} -> "
            f"{o['memory_s']*1e3:.1f} ({dm:+.0f}%) | "
            f"{b['collective_s']*1e3:.1f} -> {o['collective_s']*1e3:.1f} | "
            f"{b['mfu']:.3f} -> {o['mfu']:.3f} |")
    return "\n".join(lines) + "\n"


if __name__ == "__main__":
    base = load("results/dryrun_baseline")
    opt = load("results/dryrun")
    nb, fb, db = dryrun_summary(base)
    no, fo, do = dryrun_summary(opt)
    print(f"baseline: {nb} cells, {fb} fit 16GiB, dominant={db}")
    print(f"optimized: {no} cells, {fo} fit 16GiB, dominant={do}")
    with open("results/roofline_baseline.md", "w") as f:
        f.write(roofline_table(base, "Baseline (paper-faithful defaults)"))
    with open("results/roofline_optimized.md", "w") as f:
        f.write(roofline_table(opt, "Optimized (beyond-paper, §Perf)"))
    with open("results/roofline_compare.md", "w") as f:
        f.write(compare_table(base, opt))
    print("wrote results/roofline_{baseline,optimized,compare}.md")
