"""Paper Table III: true/completion latency per execution-unit workload
(pure INT32, pure FP32, mixed, FP64) — measured on this backend via the
dependency-chain probes, with the paper's GB203/GH100 columns alongside."""

from __future__ import annotations

from benchmarks.common import BenchResult, csv, table
from repro.core import detect_backend_model
from repro.core.probes import compute

# Paper Tab III (cycles, true/completion)
PAPER = {
    "int32": {"GB203": (4, 16.97), "GH100": (4, 16.69)},
    "fp32": {"GB203": (4, 7.97), "GH100": (4, 7.86)},
    "mixed1": {"GB203": (15.96, 14), "GH100": (31.62, 16)},
    "mixed2": {"GB203": (26.28, 18), "GH100": (43.54, 20)},
    "fp64": {"GB203": (63.57, 11), "GH100": (8.04, 13)},
}


def run(quick: bool = False) -> BenchResult:
    dev = detect_backend_model()
    iters = 5 if quick else 20
    results = compute.latency_table(iters=iters)
    rows, csv_rows = [], []
    for r in results:
        paper = PAPER.get(r.workload, {})
        rows.append([
            r.workload, r.support,
            r.true_cycles, r.completion_cycles,
            f"{paper.get('GB203', ('-', '-'))[0]}/{paper.get('GB203', ('-', '-'))[1]}",
            f"{paper.get('GH100', ('-', '-'))[0]}/{paper.get('GH100', ('-', '-'))[1]}",
        ])
        csv_rows.append(csv(
            "tab3_latency", workload=r.workload,
            true_ns=r.true_ns, completion_ns=r.completion_ns,
            true_cycles=r.true_cycles,
            completion_cycles=r.completion_cycles))
    emu = compute.fp64_emulation_factor(iters=iters)
    csv_rows.append(csv("tab3_latency", workload="fp64_emulation_factor",
                        factor=emu))
    md = table(
        ["workload", "support", f"{dev.name} true (cyc)",
         "completion (cyc)", "GB203 paper (t/c)", "GH100 paper (t/c)"],
        rows)
    md += (f"\nfp64/fp32 completion factor on {dev.name}: **{emu:.2f}x** "
           f"(paper GB203: ~16x true-latency penalty from 2 FP64 units/SM; "
           f"TPU has 0 FP64 units — the paper's 'FP64 is for type support, "
           f"compute is meant to be emulated' is the design point here).\n")
    return BenchResult("tab3_latency", "Table III", md, csv_rows)
