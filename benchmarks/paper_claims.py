"""Paper-claims validation: each of the paper's checkable qualitative
claims, tested against OUR measurements/models.  This is the faithfulness
gate for EXPERIMENTS.md §Paper-claims."""

from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from benchmarks.common import BenchResult, csv, table


def _claims(quick: bool) -> List[Tuple[str, str, Callable[[], bool]]]:
    iters = 3 if quick else 10

    def c1():
        # §IV.B: pure chains have LOWER true latency than mixed workloads
        from repro.core.probes import compute
        t = {w: compute.measure_latency(w, chain=256, iters=iters)
             for w in ("int32", "fp32", "mixed2")}
        return (t["mixed2"].true_ns >=
                0.9 * max(t["int32"].true_ns, t["fp32"].true_ns))

    def c2():
        # §IV.C: FP64 is de-prioritized — scarce units (GB203: 2/SM),
        # emulation (TPU), or silent downcast (x64-disabled JAX).  The
        # structural claim is "not a first-class pipeline"; the timing
        # factor only applies when fp64 actually executes natively.
        from repro.core.probes import compute
        r = compute.measure_latency("fp64", iters=iters)
        if r.support != "native":
            return True
        return compute.fp64_emulation_factor(iters=iters) >= 1.0

    def c3():
        # §IV.D: throughput grows with chain length then plateaus
        from repro.core.probes import compute
        pts = compute.ilp_ramp("fp32", lengths=(1, 8, 64, 256),
                               iters=iters)
        return pts[-1].ops_per_cycle > pts[0].ops_per_cycle

    def c4():
        # §V.A/B: sub-bf16 formats lower via convert onto the wide
        # pipeline (the QMMA-fallback analogue)
        from repro.core.probes import precision
        sup = {s.fmt: s for s in precision.support_matrix()}
        e4m3 = sup.get("e4m3")
        return e4m3 is not None and (not e4m3.native_dot)

    def c5():
        # §V.C: energy ordering fp4 < fp6 < fp8 < bf16 at iso work
        from repro.core import GB203
        from repro.core.energy import estimate
        j = [estimate(GB203, flops=1e12, dtype=f, seconds=1.0).joules
             for f in ("float4_e2m1fn", "float6_e2m3fn",
                       "float8_e4m3fn", "bfloat16")]
        return j[0] < j[1] < j[2] < j[3]

    def c6():
        # §V.C quantization-error staircase: fp8 < fp6 < fp4 fidelity
        from repro.core.probes import precision
        errs = [precision.cast_error(f).rel_err_mean
                for f in ("e4m3", "e2m3", "e2m1")]
        return errs[0] < errs[1] < errs[2]

    def c7():
        # §VI.A: latency steps up across hierarchy boundaries
        from repro.core.probes import memory
        curve = memory.chase_curve(
            sizes=(1 << 14, 1 << 24), steps=1 << 12, iters=iters)
        return curve[-1].ns_per_load > 1.2 * curve[0].ns_per_load

    def c8():
        # §VI.D: streaming read bandwidth >= write bandwidth
        from repro.core.probes import memory
        bw = {r.mode: r.gbps for r in memory.stream_bandwidth(iters=iters)}
        return bw.get("read", 0) >= 0.8 * bw.get("write", 1e30)

    def c9():
        # §V.B tile alignment: misaligned tiles lose throughput
        from repro.core.probes import matmul
        pts = matmul.tile_sweep(iters=iters, shapes=[
            (512, 512, 512), (509, 509, 509)])
        return pts[1].tflops <= pts[0].tflops * 1.05

    def c10():
        # Tab VIII trend: lower serving precision => lower modeled power
        from repro.core import TPU_V5E
        from repro.core.energy import estimate
        w = [estimate(TPU_V5E, flops=2e9, dtype=f,
                      bytes_by_level={"hbm": b}, seconds=1e-3).total_watts
             for f, b in (("float32", 4e9), ("bfloat16", 2e9),
                          ("float8_e4m3fn", 1e9))]
        return w[0] >= w[1] >= w[2]

    return [
        ("IV.B mixed-vs-pure latency", "mixed chains slower than pure", c1),
        ("IV.C fp64 penalty", "fp64 emulated/penalized vs fp32", c2),
        ("IV.D ILP ramp", "throughput grows then plateaus", c3),
        ("V.B QMMA fallback", "low-precision dot lowers via convert", c4),
        ("V.C energy ordering", "fp4 < fp6 < fp8 < bf16 energy", c5),
        ("V.C precision staircase", "error grows as bits shrink", c6),
        ("VI.A hierarchy steps", "latency steps at capacity boundaries",
         c7),
        ("VI.D read-heavy design", "read bw >= write bw", c8),
        ("V.B tile alignment", "misaligned tiles not faster", c9),
        ("VII.B precision-power", "serving power drops with precision",
         c10),
    ]


def run(quick: bool = False) -> BenchResult:
    rows, csv_rows = [], []
    n_pass = 0
    for ref, desc, fn in _claims(quick):
        try:
            ok = bool(fn())
        except Exception as e:                      # pragma: no cover
            ok = False
            desc += f" (ERROR: {e})"
        n_pass += ok
        rows.append([ref, desc, "PASS" if ok else "FAIL"])
        csv_rows.append(csv("paper_claims", ref=ref.split()[0],
                            ok=int(ok)))
    md = table(["paper §", "claim (as it transfers to this backend)",
                "status"], rows)
    md += f"\n**{n_pass}/{len(rows)} claims reproduced.**\n"
    return BenchResult("paper_claims", "qualitative-claims validation",
                       md, csv_rows)
