"""Paper §VII.B (Tab VIII): transformer-inference power across precisions.

The paper serves GPT-NeoX via TensorRT at FP32/FP16/FP8/best and reads
wall power.  Here: the gptneox-1b config runs through OUR serving stack
(weight-only block-quantized at each precision, sub-byte formats stored
truly bit-packed — engine ``weight_format=...``/``packed=True`` — and
the KV cache quantized to the same format: ``kv_format=...``, packed
codes + 1-byte e8m0 scales), served through the fused device-resident
decode loop (one dispatch per ``decode_block`` tokens — tok/s reflects
the step body, not per-token dispatch latency; see
``benchmarks/serve_throughput.py`` for the fused-vs-per-step split),
wall-time measured on this backend;
per-step energy on v5e comes from the model (2*N_active flops +
*measured* HBM reads: the quantized weight store at 0.5 B/elem fp4 /
0.75 B/elem fp6 plus the measured KV-cache bytes — at long context the
KV read is the dominant term, the §VI.D story)."""

from __future__ import annotations

import time

import jax

from benchmarks.common import BenchResult, csv, table
from repro.analysis.sanitize import CompileCounter
from repro.configs import get_config
from repro.core import TPU_V5E
from repro.core.energy import estimate
from repro.models import build_model
from repro.serve import ServeEngine, quantize_params

PAPER_WATTS = {"float32": (60.24, 58.82), "float16": (57.64, 47.78),
               "float8_e4m3fn": (57.69, 45.14)}

PRECISIONS = ("float32", "bfloat16", "float8_e4m3fn", "float4_e2m1fn")


def run(quick: bool = False) -> BenchResult:
    cfg = get_config("gptneox-1b").reduced()
    model = build_model(cfg)
    base_params = model.init(jax.random.PRNGKey(0))
    n_req, new_toks = (4, 4) if quick else (8, 8)
    rows, csv_rows = [], []
    for fmt in PRECISIONS:
        quantized = fmt not in ("float32", "bfloat16", "float16")
        if quantized:
            # engine holds TRUE quantized storage (bit-packed sub-byte
            # weights AND a packed-code + byte-scale KV cache); the
            # compute params are re-derived from it inside the engine
            eng = ServeEngine(model, base_params, batch=4, max_seq=64,
                              weight_format=fmt, packed=True,
                              kv_format=fmt, decode_block=8)
            qstats = eng.weight_stats
            stored_bytes = qstats["quantized_bytes"]
        else:
            params, qstats = quantize_params(base_params, fmt)
            eng = ServeEngine(model, params, batch=4, max_seq=64,
                              decode_block=8)
            stored_bytes = qstats["quantized_bytes"]
        bpe = qstats["bytes_per_element"]
        kv = eng.kv_stats          # *measured* over the live cache pytree
        # §IV.B warm-up discipline: absorb compilation of the fused
        # loop/prefill executables before the timed region (reset()
        # keeps the compiled functions)
        eng.submit([1, 2, 3, 4, 5, 6, 7, 8], max_new_tokens=new_toks)
        eng.run()
        eng.reset()
        for i in range(n_req):
            eng.submit([1 + i, 2, 3, 4, 5, 6, 7, 8],
                       max_new_tokens=new_toks)
        # settle async device work from the warm-up drive, then hold the
        # timed region to zero recompiles (the warm-up compiled every
        # executable; a compile here would be timed as tok/s)
        jax.block_until_ready((eng.cache, eng.state))
        with CompileCounter() as compiles:
            t0 = time.perf_counter()
            results = eng.run()
            dt = time.perf_counter() - t0
        if compiles.count:
            raise AssertionError(
                f"{fmt}: {compiles.count} recompile(s) inside the timed "
                "region — tok/s invalid")
        toks = sum(len(r.tokens) for r in results)
        # v5e per-token energy: 2*N flops + measured HBM reads — the
        # quantized weight store (sum(arr.nbytes) over the actual packed
        # arrays, not a nominal width) plus the measured KV-cache bytes
        # a full-cache decode step streams
        full = get_config("gptneox-1b")
        n_active = full.active_param_count()
        weight_frac = stored_bytes / max(
            sum(x.nbytes for x in jax.tree.leaves(base_params)), 1)
        hbm_bytes = n_active * 2 * weight_frac     # bf16 baseline scaled
        hbm_bytes += kv["kv_bytes"]                # KV read per step
        est = estimate(TPU_V5E, flops=2.0 * n_active, dtype=fmt,
                       bytes_by_level={"hbm": hbm_bytes},
                       seconds=max(hbm_bytes / TPU_V5E.hbm.bandwidth_Bps,
                                   1e-9))
        paper = PAPER_WATTS.get(fmt)
        rows.append([fmt, toks / dt, qstats["mse"], f"{bpe:g}",
                     f"{kv['bytes_per_elem']:g}",
                     f"{kv['bytes_per_token']:.0f}",
                     est.total_watts,
                     f"{paper[0]}/{paper[1]}" if paper else "-"])
        csv_rows.append(csv("tab8_inference", precision=fmt,
                            tok_per_s_cpu=toks / dt,
                            quant_rel_mse=qstats["mse"],
                            weight_bytes_per_elem=bpe,
                            weight_store_bytes=stored_bytes,
                            kv_bytes_per_elem=kv["bytes_per_elem"],
                            kv_bytes_per_token=kv["bytes_per_token"],
                            kv_store_bytes=kv["kv_bytes"],
                            model_watts_v5e=est.total_watts))
    # mixed per-layer KV precision (cfg.kv_formats): fp4 on the
    # sliding-window locals (short-lived, re-read within the window),
    # fp8 on globals (read at full context every step).  gemma2's
    # local/global period makes the split real; the per-layer B/elem
    # below is measured over the live cache arrays of each layer.
    mix_cfg = get_config("gemma2-2b").reduced()
    mix_fmts = tuple(
        "float4_e2m1fn" if blk.window else "float8_e4m3fn"
        for blk in mix_cfg.block_pattern())
    mix_eng = ServeEngine(build_model(mix_cfg),
                          build_model(mix_cfg).init(jax.random.PRNGKey(0)),
                          batch=4, max_seq=64, kv_format=mix_fmts,
                          decode_block=8)
    mkv = mix_eng.kv_stats
    per_layer = {name: f"{d['format']}:{d['bytes_per_elem']:.3g}"
                 for name, d in mkv["per_layer"].items()}
    rows.append(["mixed fp8/fp4 (gemma2)", "-", "-", "-",
                 f"{mkv['bytes_per_elem']:g}", f"{mkv['bytes_per_token']:.0f}",
                 "-", "-"])
    csv_rows.append(csv(
        "tab8_inference", precision="mixed_fp8_fp4_gemma2",
        kv_bytes_per_elem=mkv["bytes_per_elem"],
        kv_bytes_per_token=mkv["bytes_per_token"],
        kv_store_bytes=mkv["kv_bytes"],
        **{f"kv_bpe_{name.replace('.', '_')}": d["bytes_per_elem"]
           for name, d in mkv["per_layer"].items()}))

    md = table(["precision", "tok/s (cpu, reduced)", "quant rel-MSE",
                "weight B/elem", "KV B/elem", "KV B/token",
                "v5e model W/step", "paper H100/5080 W"], rows)
    md += ("\nMixed per-layer KV (gemma2 local/global period): "
           + ", ".join(f"{k}={v}" for k, v in sorted(per_layer.items()))
           + " — sub-byte fp4 on the windowed half, fp8 where the full "
             "context is streamed.\n")
    watts = [r[6] for r in rows[:len(PRECISIONS)]]
    md += (f"\nModeled decode power decreases with precision "
           f"({watts[0]:.0f} -> {watts[-1]:.0f} W) — the paper's Tab VIII "
           f"trend (Blackwell 58.8 -> 45.1 W from FP32 to FP8), here "
           f"driven purely by HBM traffic since v5e computes in bf16 "
           f"either way.  Decode is memory-bound, so quantized *storage* "
           f"is the whole win: bit-packed fp4 weights measure 0.5 B/elem "
           f"and the fp4 KV cache (packed codes + 1-byte e8m0 scales) "
           f"measures ~0.53-0.56 B/elem vs 2 B/elem bf16 — both numbers "
           f"are sum(arr.nbytes) over live arrays, not docstring "
           f"claims.  At long context the KV term dominates the read "
           f"(§VI.D), which is why the cache lever matters more than "
           f"the weight one.\n")
    ok = watts[0] >= watts[-2] >= watts[-1] - 1e-9
    csv_rows.append(csv("tab8_inference", precision="trend_ok", ok=int(ok)))
    return BenchResult("tab8_inference", "Table VIII", md, csv_rows)
