"""Paper Table VI: power per mma data format.

No power telemetry exists on CPU/TPU-Pallas, so the energy model
(repro.core.energy, constants documented there) reproduces the paper's
*ordering* — FP4 16.75 W < FP6 39.4/46.7 W < FP8 46.7/46.8 W on GB203 —
as model output for an iso-work sustained-mma loop, for GB203 (sanity
check against the paper's absolute watts) and TPU v5e (the target)."""

from __future__ import annotations

from benchmarks.common import BenchResult, csv, table
from repro.core import GB203, TPU_V5E
from repro.core.energy import estimate

PAPER_WATTS = {"float4_e2m1fn": 16.753, "float6_e2m3fn": 39.383,
               "float6_e3m2fn": 46.723, "float8_e4m3fn": 46.661,
               "float8_e5m2": 46.806}

FORMATS = ("float4_e2m1fn", "float6_e2m3fn", "float6_e3m2fn",
           "float8_e4m3fn", "float8_e5m2", "bfloat16")


def run(quick: bool = False) -> BenchResult:
    rows, csv_rows = [], []
    # iso-work loop: sustained mma at each format's native rate
    for fmt in FORMATS:
        est_gb = estimate(
            GB203, flops=GB203.peak_flops_for(fmt) * 0.35, dtype=fmt,
            bytes_by_level={"l1": 2e12}, seconds=1.0)
        est_tpu = estimate(
            TPU_V5E, flops=TPU_V5E.peak_flops_for(fmt) * 0.35, dtype=fmt,
            bytes_by_level={"vmem": 2e12}, seconds=1.0)
        rows.append([fmt, est_gb.total_watts, PAPER_WATTS.get(fmt, "-"),
                     est_tpu.total_watts,
                     est_tpu.perf_per_watt / 1e9])
        csv_rows.append(csv("tab6_energy", fmt=fmt,
                            model_watts_gb203=est_gb.total_watts,
                            paper_watts=PAPER_WATTS.get(fmt, 0.0),
                            model_watts_v5e=est_tpu.total_watts,
                            gflops_per_watt_v5e=est_tpu.perf_per_watt / 1e9))
    md = table(["format", "GB203 model (W)", "GB203 paper (W)",
                "v5e model (W)", "v5e GFLOP/s/W"], rows)
    # the reproducible claim is the ORDERING
    watts = [r[1] for r in rows[:5]]
    ordered = all(watts[i] <= watts[i + 1] + 1e-9
                  for i in range(len(watts) - 1))
    md += (f"\nOrdering FP4 < FP6 <= FP8 reproduced: **{ordered}** "
           f"(paper Tab VI; v5e runs every format on the bf16 MXU, so its "
           f"energy differences come only from storage traffic — the "
           f"quantified cost of missing low-precision pipelines).\n")
    csv_rows.append(csv("tab6_energy", fmt="ordering_ok", ok=int(ordered)))
    return BenchResult("tab6_energy", "Table VI", md, csv_rows)
