"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only tab3_latency]

Prints ``name,key=value,...`` CSV lines per measurement and writes the
markdown report to results/characterization.md.
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import write_report
from repro import compat

MODULES = [
    "tab3_latency",
    "fig2_3_ilp",
    "tab4_5_precision",
    "tab6_energy",
    "fig4_5_matmul",
    "fig6_10_memory",
    "tab7_gemm",
    "tab8_inference",
    "serve_throughput",
    "collectives_bench",
    "roofline_table",
    "paper_claims",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small iteration counts (CI mode)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--report", default="results/characterization.md")
    args = ap.parse_args()

    # capability header: every artifact records native vs. emulated paths
    compat_header = str(compat.report())
    print(compat_header)

    results = []
    failures = []
    for name in MODULES:
        if args.only and name != args.only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            res = mod.run(quick=args.quick)
        except Exception as e:                     # pragma: no cover
            failures.append((name, repr(e)))
            print(f"bench,{name},status=FAIL,error={e!r}",
                  file=sys.stderr)
            continue
        dt = time.time() - t0
        print(f"bench,{name},paper_ref={res.paper_ref!r},"
              f"wall_s={dt:.1f}")
        for row in res.csv_rows:
            print(row)
        results.append(res)

    if results:
        write_report(results, args.report, preamble=compat_header)
        print(f"bench,report,path={args.report}")
    if failures:
        print(f"bench,failures,n={len(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
