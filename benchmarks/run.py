"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only tab3_latency]

Prints ``name,key=value,...`` CSV lines per measurement and writes the
markdown report to results/characterization.md.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time

from benchmarks.common import append_history, parse_csv_row, write_report
from repro import compat

MODULES = [
    "tab3_latency",
    "fig2_3_ilp",
    "tab4_5_precision",
    "tab6_energy",
    "fig4_5_matmul",
    "fig6_10_memory",
    "tab7_gemm",
    "tab8_inference",
    "serve_throughput",
    "serve_scenarios",
    "collectives_bench",
    "roofline_table",
    "paper_claims",
]


def _headline(results) -> dict:
    """serve + tab8 headline numbers for the rolling trajectory file.

    Pulls from the CSV rows each module already emits (so the history
    line can never drift from the printed artifact): fused serving
    tok/s + per-device bandwidth per arch family, and tab8 tok/s +
    stored bytes/elem per precision."""
    head: dict = {}
    for res in results:
        if res.name == "serve_throughput":
            head["serve"] = [
                {k: a[k] for k in ("family", "arch", "kv_format", "mesh",
                                   "speedup", "bandwidth")}
                | {"tok_per_s_fused": a["fused"]["tok_per_s"]}
                for a in getattr(res, "artifacts", [])]
        elif res.name == "tab8_inference":
            rows = []
            for row in res.csv_rows:
                _, fields = parse_csv_row(row)
                if "tok_per_s_cpu" in fields:
                    rows.append({k: fields[k] for k in
                                 ("precision", "tok_per_s_cpu",
                                  "weight_bytes_per_elem",
                                  "kv_bytes_per_elem",
                                  "model_watts_v5e") if k in fields})
            head["tab8"] = rows
    return head


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small iteration counts (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    ap.add_argument("--report", default="results/characterization.md")
    ap.add_argument("--history", default="results/BENCH_history.jsonl",
                    help="rolling per-PR trajectory JSONL ('' disables)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    # capability header: every artifact records native vs. emulated paths
    rep = compat.report()
    compat_header = str(rep)
    print(compat_header)

    results = []
    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            res = mod.run(quick=args.quick)
        except Exception as e:                     # pragma: no cover
            failures.append((name, repr(e)))
            print(f"bench,{name},status=FAIL,error={e!r}",
                  file=sys.stderr)
            continue
        dt = time.time() - t0
        print(f"bench,{name},paper_ref={res.paper_ref!r},"
              f"wall_s={dt:.1f}")
        for row in res.csv_rows:
            print(row)
        results.append(res)

    if results:
        write_report(results, args.report, preamble=compat_header)
        print(f"bench,report,path={args.report}")
        head = _headline(results)
        if head and args.history:
            append_history({"bench": "run", "quick": args.quick,
                            "compat": dataclasses.asdict(rep), **head},
                           path=args.history)
            print(f"bench,history,path={args.history}")
    if failures:
        print(f"bench,failures,n={len(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
