"""Measured speculative-decoding throughput vs the fused K=16 baseline.

The speculative loop replaces K sequential decode steps (one GEMV-sized
forward per token, even inside the fused scan) with draft -> ONE
batched verify forward over ``draft_tokens + 1`` positions -> commit.
When drafts verify, each accepted token amortizes the weight stream
over the verify width — the classic speculative-decoding bandwidth
argument (arXiv:2211.17192 applied to the §VI.D roofline: decode
throughput = how fast the resident state streams per *emitted* token).
When drafts miss, every verify row past the first is wasted compute —
so the measured number is workload-dependent by design, and the
acceptance length is reported next to tokens/s.

The workload is acceptance-friendly on purpose (cyclic prompts whose
continuations the per-slot n-gram table learns): it measures the
speculation machinery at its design point, not draft quality.  Greedy
streams are asserted bit-identical between the legs before any number
is reported — speculation may only change the dispatch count, never
the tokens — and the timed region is held to zero recompiles.

    PYTHONPATH=src python benchmarks/serve_spec.py --quick \
        --out BENCH_serve_spec.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Dict, Optional

import jax

if __package__ in (None, ""):      # `python benchmarks/serve_spec.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import BenchResult, append_history, csv, table
from repro import compat
from repro.analysis.sanitize import CompileCounter
from repro.configs import get_config
from repro.core.timing import time_fn
from repro.models import build_model
from repro.serve import ServeEngine, SpecConfig


def _drive(eng: ServeEngine, n_req: int, prompt_len: int,
           new_tokens: int) -> int:
    """Reset, enqueue the cyclic-prompt workload, serve to drain.

    Period-3 cyclic prompts with a per-request phase: the reduced
    attention model's greedy continuation settles into short cycles the
    per-slot n-gram table learns online, so acceptance climbs as the
    stream lengthens — repetitive enough to hit, distinct enough per
    slot that streams do not collapse together."""
    eng.reset()
    for i in range(n_req):
        eng.submit([1 + (i + j) % 3 for j in range(prompt_len)],
                   max_new_tokens=new_tokens)
    results = eng.run(max_steps=100_000)
    return sum(len(r.tokens) for r in results)


def measure(quick: bool = False, kv_format: Optional[str] = None,
            decode_block: int = 16, draft_tokens: int = 3,
            arch: str = "gptneox-1b") -> Dict:
    """Fused K=16 baseline vs the speculative engine on one model."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # quick mode trims ITERATIONS, not the stream: acceptance needs the
    # ~96-token stream for the online n-gram table to warm past the
    # break-even length (short streams spend their life in the miss
    # phase and would gate on table warm-up, not on the machinery)
    n_req, prompt_len, new_tokens = 4, 16, 96
    iters, warmup = (4, 1) if quick else (6, 2)

    legs: Dict[str, Dict] = {}
    streams = {}
    spec_rep: Dict = {}
    spec_cfg = SpecConfig(draft_tokens=draft_tokens, ngram_context=2,
                          ngram_table=1024)
    for name, spec in (("fused", None), ("spec", spec_cfg)):
        eng = ServeEngine(model, params, batch=4, max_seq=256,
                          kv_format=kv_format, decode_block=decode_block,
                          prefill_chunk=16, spec=spec)
        n_tok = _drive(eng, n_req, prompt_len, new_tokens)
        streams[name] = [r.tokens for r in
                         sorted(eng.results, key=lambda r: r.request_id)]
        # the warm-up drive above built every executable; a compile
        # inside the timed region would mean a shape leak is being
        # timed as throughput
        jax.block_until_ready((eng.cache, eng.state))
        with CompileCounter() as compiles:
            t = time_fn(_drive, eng, n_req, prompt_len, new_tokens,
                        iters=iters, warmup=warmup)
        if compiles.count:
            raise AssertionError(
                f"{name} leg recompiled {compiles.count}x inside the "
                "timed region — measurement invalid (see README "
                "'Static analysis & sanitizers')")
        legs[name] = {"decode_block": decode_block, "tokens": n_tok,
                      "median_s": t.median_s, "mean_s": t.mean_s,
                      "std_s": t.std_s,
                      "tok_per_s": n_tok / t.median_s}
        if spec is not None:
            spec_rep = eng.spec_report()

    identical = streams["fused"] == streams["spec"]
    if not identical:
        raise AssertionError(
            "speculative decode diverged from the fused loop (greedy "
            "streams must be bit-identical): "
            f"{streams['fused']} vs {streams['spec']}")
    return {
        "arch": cfg.name,
        "kv_format": kv_format or "none",
        "draft_tokens": draft_tokens,
        "requests": n_req, "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "fused": legs["fused"], "spec": legs["spec"],
        "speedup": legs["spec"]["tok_per_s"]
        / legs["fused"]["tok_per_s"],
        "mean_accepted_len": spec_rep["mean_accepted_len"],
        "blocks": spec_rep["blocks"],
        "greedy_identical": identical,
    }


def run(quick: bool = False) -> BenchResult:
    # the attention family carries the headline and the regression gate
    # (gated=True: speculation must beat the fused baseline it rides
    # on).  The other two rows are correctness-certified DIAGNOSTICS of
    # known costs, reported but not gated: the fp4 row pays emulated
    # quantize-on-commit for all draft_tokens+1 verify rows while only
    # ~accepted-len of them commit, and the reduced hybrid model's
    # greedy stream is aperiodic (chaotic), so its acceptance sits at
    # the ~1.0 floor and the row measures the pure miss penalty.
    scenarios = [
        ("attn", "gptneox-1b", None, True),
        ("attn", "gptneox-1b", "float4_e2m1fn", False),
        ("hybrid", "jamba-v0.1-52b", None, False),
    ]
    rows, csv_rows, artifacts = [], [], []
    for family, arch, kv_format, gated in scenarios:
        art = measure(quick=quick, kv_format=kv_format, arch=arch)
        art["family"] = family
        art["gated"] = gated
        artifacts.append(art)
        rows.append([family, art["arch"], art["kv_format"],
                     f"{art['fused']['tok_per_s']:.1f}",
                     f"{art['spec']['tok_per_s']:.1f}",
                     f"{art['speedup']:.2f}x",
                     f"{art['mean_accepted_len']:.2f}",
                     "yes" if art["greedy_identical"] else "NO"])
        csv_rows.append(csv(
            "serve_spec", family=family, arch=art["arch"],
            kv_format=art["kv_format"],
            draft_tokens=art["draft_tokens"],
            tok_per_s_fused=art["fused"]["tok_per_s"],
            tok_per_s_spec=art["spec"]["tok_per_s"],
            speedup=art["speedup"],
            mean_accepted_len=art["mean_accepted_len"],
            gated=int(gated),
            greedy_identical=int(art["greedy_identical"])))
    md = table(["family", "arch", "kv_format", "tok/s fused (K=16)",
                "tok/s speculative", "speedup", "accepted len",
                "greedy identical"], rows)
    md += ("\nSpeculative decode vs the fused K=16 loop it is built "
           "into: drafts come from the per-slot n-gram table, verify is "
           "one batched forward over draft_tokens+1 positions, accepted "
           "prefixes commit through the (quantized) cache-write path, "
           "rejected tails roll back by pointer.  'accepted len' is "
           "committed tokens per verify block (1.0 = speculation never "
           "helps, draft_tokens+1 = every block fully accepted); the "
           "speedup column is meaningful only next to it — this is the "
           "design-point (repetitive) workload, not an average over "
           "workloads.  The attention-dense row carries the regression "
           "gate; the fp4 and hybrid rows are ungated diagnostics of "
           "the emulated quantize-on-commit cost and the acceptance "
           "floor (aperiodic stream -> pure miss penalty).\n")
    res = BenchResult("serve_spec", "§VI.D (speculative serving)", md,
                      csv_rows)
    res.artifacts = artifacts          # for the __main__ JSON writer
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_serve_spec.json")
    ap.add_argument("--history", default=None,
                    help="also append headline numbers to this JSONL "
                         "trajectory file (see benchmarks/run.py, which "
                         "appends to results/BENCH_history.jsonl)")
    args = ap.parse_args()

    rep = compat.report()
    print(rep)
    res = run(quick=args.quick)
    print(res.markdown)
    for row in res.csv_rows:
        print(row)
    payload = {
        "bench": "serve_spec",
        "quick": args.quick,
        "compat": dataclasses.asdict(rep),
        "runs": res.artifacts,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"bench,serve_spec,artifact={args.out}")
    if args.history:
        append_history({
            "bench": "serve_spec", "quick": args.quick,
            "compat": dataclasses.asdict(rep),
            "spec": [{k: a[k] for k in
                      ("family", "arch", "kv_format", "speedup",
                       "mean_accepted_len")}
                     | {"tok_per_s_spec": a["spec"]["tok_per_s"]}
                     for a in res.artifacts],
        }, path=args.history)
        print(f"bench,serve_spec,history={args.history}")
    # regression gate: on the acceptance-friendly workload, the gated
    # (headline attention-dense) row must beat the fused baseline it
    # rides on.  The quick leg runs few short iterations on shared CI
    # hosts, so it gets a noise margin; the full leg is held to a
    # strict >1x.  The ungated diagnostic rows only have to stay
    # bit-identical (asserted inside measure()).
    floor = 0.9 if args.quick else 1.0
    slow = [a for a in payload["runs"]
            if a["gated"] and a["speedup"] <= floor]
    if slow:
        raise SystemExit(
            f"speculative decode failed to beat the fused K=16 "
            f"baseline (gate {floor}x): {slow}")


if __name__ == "__main__":
    main()
