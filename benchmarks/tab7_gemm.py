"""Paper §VII.A (Fig 11/12, Tab VII): the dense-GEMM case study.

The paper drives cuBLASLt FP8 GEMM over M,N,K in {1024..8192} and reports
runtime, TFLOP/s and power.  Here: our block-scaled qmatmul (fp8 storage,
bf16 MXU) is the engine; small sizes are wall-time measured on this
backend, large sizes are roofline-modeled for v5e (flagged); energy comes
from the model (Fig 12 analogue)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import BenchResult, csv, table
from repro.core import TPU_V5E, detect_backend_model, time_fn
from repro.core.energy import matmul_energy
from repro.kernels import pack_for_qmatmul, qmatmul, quantize_for_qmatmul
from repro.kernels.ref import qmatmul_ref

PAPER_TFLOPS = {  # Tab VII (effective TFLOP/s, FP8 GEMM)
    (8192, 8192, 8192): (0.887, 0.233),
    (2048, 2048, 2048): (0.554, 0.191),
    (2048, 2048, 4096): (0.674, 0.192),
    (2048, 4096, 8192): (0.759, 0.217),
    (1024, 1024, 1024): (0.239, 0.134),
}

SIZES = [(512, 512, 512), (1024, 1024, 1024), (2048, 2048, 2048),
         (2048, 2048, 4096), (2048, 4096, 8192), (4096, 4096, 4096),
         (8192, 8192, 8192)]


def _v5e_model_seconds(m, n, k) -> float:
    flops = 2.0 * m * n * k
    hbm = 1.0 * (m * k * 2 + k * n) + 2.0 * m * n   # bf16 x + fp8 w + bf16 out
    return max(flops / TPU_V5E.peak_flops_for("bfloat16"),
               hbm / TPU_V5E.hbm.bandwidth_Bps)


def run(quick: bool = False) -> BenchResult:
    measure_limit = 1024 if quick else 2048
    key = jax.random.PRNGKey(0)
    rows, csv_rows = [], []
    for (m, n, k) in (SIZES[:3] if quick else SIZES):
        measured = max(m, n, k) <= measure_limit
        if measured:
            ka, kb = jax.random.split(key)
            x = jax.random.normal(ka, (m, k), jnp.bfloat16)
            w = jax.random.normal(kb, (k, n), jnp.float32)
            qw, sc = quantize_for_qmatmul(w, "float8_e4m3fn")
            # interpret-mode Pallas wall time is emulation overhead, not
            # perf: time the XLA-path oracle, validate the kernel output
            t = time_fn(qmatmul_ref, x, qw, sc, iters=3, warmup=1)
            sec = t.median_s
            src = "measured(cpu)"
        else:
            sec = _v5e_model_seconds(m, n, k)
            src = "modeled(v5e)"
        tflops = 2.0 * m * n * k / sec / 1e12
        e = matmul_energy(TPU_V5E, m, n, k, "float8_e4m3fn", seconds=sec)
        paper = PAPER_TFLOPS.get((m, n, k))
        rows.append([f"{m}x{n}x{k}", src, sec * 1e3, tflops,
                     e.total_watts,
                     f"{paper[0]}/{paper[1]}" if paper else "-"])
        csv_rows.append(csv("tab7_gemm", shape=f"{m}x{n}x{k}", source=src,
                            runtime_ms=sec * 1e3, tflops=tflops,
                            model_watts=e.total_watts))
    md = table(["M x N x K", "source", "ms", "TFLOP/s",
                "model W (v5e)", "paper H100/5080 TFLOP/s"], rows)
    md += ("\nFig 12 analogue: modeled power grows with size until the "
           "TDP clamp — the plateau the paper measures.  The paper's "
           "own numbers (0.1-0.9 TFLOP/s) show cuBLASLt FP8 far from "
           "peak on both GPUs; our v5e-modeled numbers are the roofline "
           "bound for the dequant-to-bf16 qmatmul path.\n")

    # Measured weight-storage traffic (Tab V packing): actual nbytes of
    # the arrays each kernel variant reads from HBM, not nominal widths.
    k_t, n_t = (512, 512) if quick else (2048, 2048)
    w = jax.random.normal(jax.random.PRNGKey(1), (k_t, n_t), jnp.float32)
    bf16_bytes = k_t * n_t * 2
    traffic_rows = []
    for fmt, packed in (("float8_e4m3fn", False), ("float6_e2m3fn", True),
                        ("float6_e3m2fn", True), ("float4_e2m1fn", True)):
        if packed:
            qw, sc = pack_for_qmatmul(w, fmt)
        else:
            qw, sc = quantize_for_qmatmul(w, fmt)
        wb = qw.nbytes + sc.nbytes
        traffic_rows.append([fmt, "packed" if packed else "container",
                             qw.nbytes / (k_t * n_t), wb,
                             bf16_bytes / wb])
        csv_rows.append(csv("tab7_gemm_traffic", fmt=fmt,
                            layout="packed" if packed else "container",
                            bytes_per_elem=qw.nbytes / (k_t * n_t),
                            weight_bytes=wb, scale_bytes=sc.nbytes,
                            ratio_vs_bf16=bf16_bytes / wb))
    md += (f"\n**Measured weight HBM traffic ({k_t}x{n_t} weight, "
           f"scales included)**\n\n"
           + table(["format", "layout", "B/elem", "bytes",
                    "traffic drop vs bf16"], traffic_rows))
    md += ("\nThe fp4 weight array itself is a true 4x below bf16 "
           "(0.5 B/elem); with the fp32-held e8m0 scales included the "
           "measured drop is 3.2x (1-byte e8m0 scale storage would give "
           "~3.8x).  qmatmul_packed reads exactly these bytes per "
           "k-block and expands nibbles in VMEM, bit-exact with the "
           "container path.\n")
    return BenchResult("tab7_gemm", "Table VII, Figures 11/12", md,
                       csv_rows)
