"""Paper §VI (Fig 6-10): memory-hierarchy walk, stride sensitivity,
concurrency scaling, streaming bandwidth — on this backend the probes walk
the host cache hierarchy (methodology validation); the v5e column is the
published HBM/VMEM model the roofline uses."""

from __future__ import annotations

from benchmarks.common import BenchResult, csv, table
from repro.core import TPU_V5E, detect_backend_model
from repro.core.probes import memory


def run(quick: bool = False) -> BenchResult:
    iters = 3 if quick else 5
    csv_rows = []

    # Fig 6: pointer-chase hierarchy walk
    sizes = tuple(1 << p for p in (14, 17, 20, 23, 26)) if quick else \
        tuple(1 << p for p in range(13, 28))
    curve = memory.chase_curve(sizes=sizes, steps=1 << 12 if quick
                               else 1 << 14, iters=iters)
    rows = [[f"{p.working_set_bytes/1024:.0f} KiB", p.ns_per_load,
             p.cycles_per_load] for p in curve]
    for p in curve:
        csv_rows.append(csv("fig6_chase", size_bytes=p.working_set_bytes,
                            ns_per_load=p.ns_per_load))
    md = "**Fig 6 — pointer-chase latency**\n\n" + table(
        ["working set", "ns/load", "cycles/load"], rows)
    bounds = memory.find_boundaries(curve)
    md += (f"\nDetected hierarchy boundaries at {bounds} bytes "
           f"(host caches; the paper finds L1 end ~128/256 KB, L2 end "
           f"~30/60 MB).  On v5e the analogous boundary is "
           f"VMEM={TPU_V5E.level('vmem').capacity_bytes >> 20} MiB -> "
           f"HBM.\n")
    for b in bounds:
        csv_rows.append(csv("fig6_chase", boundary_bytes=b))

    # Fig 7/8: stride sweep
    spts = memory.stride_sweep(iters=iters)
    srows = [[p.stride, p.concurrency, p.ns_per_access] for p in spts]
    for p in spts:
        csv_rows.append(csv("fig7_8_stride", stride=p.stride,
                            lanes=p.concurrency,
                            ns_per_access=p.ns_per_access))
    md += "\n**Fig 7/8 — stride x concurrency**\n\n" + table(
        ["stride", "lanes (warp analogue)", "ns/access"], srows)

    # Fig 9: concurrency scaling
    cpts = memory.concurrency_scaling(iters=iters)
    peak1 = cpts[0].aggregate_gbps
    crows = [[p.streams, p.aggregate_gbps, p.aggregate_gbps / peak1]
             for p in cpts]
    for p in cpts:
        csv_rows.append(csv("fig9_concurrency", streams=p.streams,
                            gbps=p.aggregate_gbps))
    md += "\n**Fig 9 — concurrency scaling**\n\n" + table(
        ["streams", "GB/s", "scaling vs 1 stream"], crows)

    # Fig 10: streaming bandwidth
    bw = memory.stream_bandwidth(iters=iters)
    brows = [[r.mode, r.gbps] for r in bw]
    for r in bw:
        csv_rows.append(csv("fig10_bandwidth", kind=r.mode, gbps=r.gbps))
    reads = {r.mode: r.gbps for r in bw}
    md += "\n**Fig 10 — streaming bandwidth**\n\n" + table(
        ["kind", "GB/s"], brows)
    if "read" in reads and "write" in reads:
        md += (f"\nread/write ratio {reads['read']/reads['write']:.2f} "
               f"(paper: GH100 7.2x, GB203 5.1x — read-optimized memory "
               f"paths; v5e HBM {TPU_V5E.hbm.bandwidth_Bps/1e9:.0f} GB/s "
               f"is symmetric).\n")
    return BenchResult("fig6_10_memory", "Figures 6-10", md, csv_rows)
