"""Shared benchmark plumbing: result container + CSV/markdown emit."""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, List, Mapping, Sequence


@dataclasses.dataclass
class BenchResult:
    name: str                      # e.g. "tab3_latency"
    paper_ref: str                 # e.g. "Table III"
    markdown: str
    csv_rows: List[str] = dataclasses.field(default_factory=list)
    notes: str = ""


def csv(name: str, **fields: Any) -> str:
    cells = ",".join(f"{k}={_fmt(v)}" for k, v in fields.items())
    return f"{name},{cells}"


def _fmt(x: Any) -> str:
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) >= 1e5 or abs(x) < 1e-3:
            return f"{x:.4e}"
        return f"{x:.4f}"
    return str(x)


def table(headers: Sequence[str], rows: Sequence[Sequence[Any]]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(_fmt(c) for c in r) + " |")
    return "\n".join(out) + "\n"


def write_report(results: Sequence[BenchResult],
                 path: str = "results/characterization.md",
                 preamble: str = "") -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("# Characterization report (paper-table analogues)\n\n"
                "Backend: CPU container (methodology validation); "
                "TPU v5e numbers are model-derived where flagged.\n\n")
        if preamble:
            f.write("## Capability report (repro.compat)\n\n```\n"
                    + preamble.strip() + "\n```\n\n")
        for r in results:
            f.write(f"## {r.name} — {r.paper_ref}\n\n")
            if r.notes:
                f.write(r.notes.strip() + "\n\n")
            f.write(r.markdown.strip() + "\n\n")
