"""Shared benchmark plumbing: result container + CSV/markdown emit,
plus the rolling per-PR trajectory file (``append_history``)."""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import time
from typing import Any, Dict, List, Mapping, Sequence


@dataclasses.dataclass
class BenchResult:
    name: str                      # e.g. "tab3_latency"
    paper_ref: str                 # e.g. "Table III"
    markdown: str
    csv_rows: List[str] = dataclasses.field(default_factory=list)
    notes: str = ""


def csv(name: str, **fields: Any) -> str:
    cells = ",".join(f"{k}={_fmt(v)}" for k, v in fields.items())
    return f"{name},{cells}"


def _fmt(x: Any) -> str:
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) >= 1e5 or abs(x) < 1e-3:
            return f"{x:.4e}"
        return f"{x:.4f}"
    return str(x)


def table(headers: Sequence[str], rows: Sequence[Sequence[Any]]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(_fmt(c) for c in r) + " |")
    return "\n".join(out) + "\n"


def parse_csv_row(row: str) -> tuple:
    """Invert ``csv()``: ``"name,k=v,..."`` -> ``(name, {k: v})``.

    Values stay strings; callers that want numbers convert themselves
    (the history record keeps them as emitted so the JSONL line matches
    the printed CSV byte-for-byte)."""
    name, _, rest = row.partition(",")
    fields: Dict[str, str] = {}
    for cell in rest.split(","):
        k, _, v = cell.partition("=")
        fields[k] = v
    return name, fields


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def append_history(record: Mapping[str, Any],
                   path: str = "results/BENCH_history.jsonl") -> Dict:
    """Append one benchmark-trajectory record to the rolling JSONL file.

    One line per benchmark run (in practice: one per PR's CI run), so
    ``results/BENCH_history.jsonl`` is the repo's perf trajectory —
    regressions show up as a diff in review, not as a lost artifact.
    Stamps schema version, UTC time, and git revision; the caller
    supplies the headline numbers (and the compat header, so a line is
    interpretable even after the emulated/native split changes)."""
    stamped = {
        "schema": 1,
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "git": _git_rev(),
    }
    stamped.update(record)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(stamped, sort_keys=False) + "\n")
    return stamped


def write_report(results: Sequence[BenchResult],
                 path: str = "results/characterization.md",
                 preamble: str = "") -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("# Characterization report (paper-table analogues)\n\n"
                "Backend: CPU container (methodology validation); "
                "TPU v5e numbers are model-derived where flagged.\n\n")
        if preamble:
            f.write("## Capability report (repro.compat)\n\n```\n"
                    + preamble.strip() + "\n```\n\n")
        for r in results:
            f.write(f"## {r.name} — {r.paper_ref}\n\n")
            if r.notes:
                f.write(r.notes.strip() + "\n\n")
            f.write(r.markdown.strip() + "\n\n")
