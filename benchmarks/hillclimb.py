"""§Perf hillclimb driver: re-lower one cell under a config variant and
diff the roofline terms against the recorded baseline.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch qwen2.5-3b \
        --shape train_4k --set remat=full --set ssm_chunk=512

Each run prints before/after terms; the narrative log (hypothesis ->
confirmed/refuted) lives in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("true", "false"):
        return k, v == "true"
    return k, v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable)")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--tag", default="variant")
    ap.add_argument("--baseline-dir", default="results/dryrun")
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    base_file = os.path.join(
        args.baseline_dir, f"{args.arch}__{args.shape}__{mesh_name}.json")
    base = json.load(open(base_file)) if os.path.exists(base_file) else None

    # run the variant in a fresh subprocess (device-count isolation)
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch import dryrun
overrides = dict({[parse_override(s) for s in args.set]!r})
if {args.accum!r} is not None:
    dryrun.TRAIN_ACCUM_STEPS = {args.accum!r}
import time
t0 = time.time()
mesh, jitted, cell_args, meta = dryrun.build_cell(
    {args.arch!r}, {args.shape!r}, {args.multi_pod!r}, extra=overrides)
from repro.core import TPU_V5E, analyze_compiled, build_report
with mesh:
    compiled = jitted.lower(*cell_args).compile()
    stats = analyze_compiled(compiled)
chips = meta["chips"]
mf = (6.0 if meta["step_kind"] == "train_step" else 2.0) \\
    * meta["active_params"] * meta["tokens"]
r = build_report("variant", stats, TPU_V5E, chips, model_flops=mf)
out = dict(
    compute_s=r.compute_s, memory_s=r.memory_s,
    collective_s=r.collective_s, dominant=r.dominant, mfu=r.mfu,
    useful=r.useful_ratio,
    temp_gib=stats.temp_bytes / 2**30,
    args_gib=stats.argument_bytes / 2**30,
    collective_by_kind=dict(stats.collectives.bytes_by_kind),
    compile_s=round(time.time() - t0, 1))
print("HILLCLIMB_RESULT " + json.dumps(out))
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True)
    if r.returncode != 0:
        print(r.stderr[-3000:])
        sys.exit(1)
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("HILLCLIMB_RESULT ")][-1]
    variant = json.loads(line.split(" ", 1)[1])

    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(
            args.out,
            f"{args.arch}__{args.shape}__{mesh_name}__{args.tag}.json"),
            "w") as f:
        json.dump({"overrides": args.set, "accum": args.accum,
                   **variant}, f, indent=1)

    def fmt(d, key, scale=1e3):
        return f"{d[key]*scale:9.3f}" if d else "       -"

    print(f"cell {args.arch}/{args.shape}/{mesh_name}  "
          f"variant: {args.set or args.accum}")
    print(f"{'term':12s} {'baseline':>9s} {'variant':>9s}")
    for term in ("compute_s", "memory_s", "collective_s"):
        b = base["roofline"][term] * 1e3 if base else None
        v = variant[term] * 1e3
        delta = f"  ({(v/b-1)*100:+.1f}%)" if b else ""
        print(f"{term:12s} {b if b else 0:9.3f} {v:9.3f}{delta}")
    print(f"dominant: {base['roofline']['dominant'] if base else '-'} -> "
          f"{variant['dominant']};  mfu {base['roofline']['mfu'] if base else 0:.3f} "
          f"-> {variant['mfu']:.3f};  temp {variant['temp_gib']:.1f} GiB")


if __name__ == "__main__":
    main()
