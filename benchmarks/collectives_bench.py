"""Beyond-paper: interconnect alpha-beta characterization (roofline term 3
input) + the int8-compressed all-reduce payload measurement."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import BenchResult, csv, table
from repro.core.probes import collectives


def run(quick: bool = False) -> BenchResult:
    abs_ = collectives.characterize(
        sizes=(1 << 16, 1 << 20) if quick else
        (1 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 24))
    rows, csv_rows = [], []
    for ab in abs_:
        rows.append([ab.collective, ab.devices,
                     "measured" if ab.measured else "model",
                     ab.alpha_s * 1e6, ab.beta_Bps / 1e9])
        csv_rows.append(csv("collectives", collective=ab.collective,
                            alpha_us=ab.alpha_s * 1e6,
                            beta_gbps=ab.beta_Bps / 1e9,
                            measured=int(ab.measured)))
    md = table(["collective", "devices", "source", "alpha (us)",
                "beta (GB/s)"], rows)

    # compressed all-reduce: HLO-level payload bytes, fp32 vs int8-in-int16
    from repro.core.hlo_cost import analyze_hlo_text
    from repro.distributed.compression import compressed_psum_tree
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    g = jnp.zeros((1 << 18,), jnp.float32)

    def plain(g):
        return jax.lax.psum(g, "data")

    def comp(g, k):
        return compressed_psum_tree({"g": g}, k, "data", 1)["g"]

    t_plain = jax.jit(shard_map(plain, mesh=mesh, in_specs=P(),
                                out_specs=P())).lower(g).compile()
    t_comp = jax.jit(shard_map(
        lambda g, k: comp(g, k), mesh=mesh, in_specs=(P(), P()),
        out_specs=P())).lower(g, jax.random.PRNGKey(0)).compile()
    b_plain = analyze_hlo_text(t_plain.as_text()).collectives.total_bytes
    b_comp = analyze_hlo_text(t_comp.as_text()).collectives.total_bytes
    md += (f"\n**Compressed all-reduce payload** (HLO-counted): fp32 "
           f"{b_plain/2**20:.2f} MiB -> int8/int16 {b_comp/2**20:.2f} MiB "
           f"per reduce = **{b_plain/max(b_comp,1):.1f}x** wire reduction "
           f"(paper §V.C motivation: precision scales power AND "
           f"bandwidth).\n")
    csv_rows.append(csv("collectives", collective="compressed_allreduce",
                        fp32_bytes=b_plain, int8_bytes=b_comp))
    return BenchResult("collectives", "beyond-paper (roofline term 3)",
                       md, csv_rows)
