"""Traffic scenarios through the serving engine: tail latency and
goodput per (scenario, policy, K) under admission control.

``serve_throughput`` measures the fused loop on pre-enqueued request
sets; this module measures it under *arrivals* — seeded Poisson,
bursty, and overload-ramp traces from ``repro.serve.traffic`` replayed
against :class:`~repro.serve.ServeEngine` with a bounded admission
queue.  Overload is the interesting regime: the admission policy, not
raw throughput, decides what the tail looks like, and the accounting
identity (submitted = ok + truncated + shed + deadline_exceeded +
faulted) is asserted on every row so a lost request is a failed
benchmark, not a quietly wrong goodput number.

Scenario-row schema (``BENCH_serve.json`` / ``BENCH_serve_scenarios
.json``, one dict per (scenario, policy, K) cell — the flat form of
``repro.serve.traffic.ScenarioReport.row()``):

    scenario       str    trace name, e.g. "poisson_r200" / "ramp_r5-400"
    k              int    fused decode block (tokens per dispatch)
    policy         str    admission policy: reject | shed_oldest | block
    scheduler      str    queue order: fifo | spf (shortest-prompt-first)
    submitted      int    requests that entered the engine (block-policy
                          arrivals refused at the queue never count)
    by_status      dict   terminal status -> count; keys from
                          repro.serve.STATUSES, sums to ``submitted``
    elapsed_s      float  replay wall time (measured clock)
    tokens_ok      int    tokens delivered by status="ok" results
    tokens_total   int    all delivered tokens, incl. partials from
                          truncated/deadline_exceeded results
    goodput_tok_s  float  tokens_ok / elapsed_s — sheds and dead
                          partials earn nothing, by construction
    ttft_p50/p99   float|null  submit -> first token, s (admitted reqs)
    tpt_p50/p99    float|null  per-token decode seconds over "ok"
                          results with >= 2 tokens
    accounting_ok  bool   exact-accounting identity held AND nothing
                          left in flight or queued

Every cell reuses ONE engine: admission policy, scheduler, and deadline
are host-side state, so the whole sweep runs on the executables the
warm-up pass built — a ``CompileCounter`` holds the measured sweep to
zero recompiles (a compile mid-sweep means a shape leak is being timed
as queueing behaviour).

    PYTHONPATH=src python benchmarks/serve_scenarios.py --quick \
        --out BENCH_serve_scenarios.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Dict, List, Optional

import jax

if __package__ in (None, ""):      # `python benchmarks/serve_scenarios.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import BenchResult, append_history, csv, table
from repro import compat
from repro.analysis.sanitize import CompileCounter
from repro.configs import get_config
from repro.models import build_model
from repro.serve import (AdmissionConfig, ServeEngine, poisson_trace,
                         replay)
from repro.serve.traffic import bursty_trace, overload_ramp_trace

POLICIES = ("reject", "shed_oldest", "block")


def _scenarios(vocab: int, quick: bool) -> List:
    """Seeded traces; the Poisson one is deliberately overloaded (rate
    far above what batch=4 can drain) so admission policy matters."""
    if quick:
        return [poisson_trace(n=16, rate=5000.0, vocab_size=vocab,
                              seed=7, deadline_ms=400.0)]
    return [
        poisson_trace(n=24, rate=200.0, vocab_size=vocab, seed=7,
                      deadline_ms=500.0),
        bursty_trace(n_bursts=3, burst_size=8, gap_s=0.25,
                     vocab_size=vocab, seed=11),
        overload_ramp_trace(n=24, rate0=5.0, rate1=400.0,
                            vocab_size=vocab, seed=13),
    ]


def measure(quick: bool = False, arch: str = "gptneox-1b",
            kv_format: Optional[str] = None) -> Dict:
    """Sweep (scenario, policy, K) on one engine; returns the artifact
    dict with one ``ScenarioReport.row()`` per cell."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch=4, max_seq=128,
                      kv_format=kv_format, decode_block=16,
                      prefill_chunk=16)
    ks = (4,) if quick else (4, 16)
    vocab = cfg.vocab_size
    traces = _scenarios(vocab, quick)

    # warm pass: build every executable the sweep will touch — the
    # per-K fused loops, chunked prefill over both prompt-chunk counts,
    # and the deadline-cancel path — on a throwaway trace
    warm = poisson_trace(n=6, rate=500.0, vocab_size=vocab, seed=3,
                         prompt_lens=(4, 24), deadline_ms=1.0)
    for k in ks:
        replay(eng, warm, k=k,
               admission=AdmissionConfig(queue_limit=2, policy="reject"))
    jax.block_until_ready((eng.cache, eng.state))

    rows: List[Dict] = []
    with CompileCounter() as compiles:
        for sc in traces:
            for policy in POLICIES:
                for k in ks:
                    rep = replay(
                        eng, sc, k=k,
                        admission=AdmissionConfig(
                            queue_limit=4, policy=policy))
                    rows.append(rep.row())
    if compiles.count:
        raise AssertionError(
            f"scenario sweep recompiled {compiles.count}x — admission "
            "policy and K must reuse the warmed executables (see "
            "README 'Serving robustness')")
    bad = [r for r in rows if not r["accounting_ok"]]
    if bad:
        raise AssertionError(
            "shed-accounting mismatch: submitted != sum(by_status) or "
            f"requests left behind in {len(bad)} row(s): "
            f"{[(r['scenario'], r['policy'], r['k']) for r in bad]}")
    return {
        "arch": cfg.name,
        "kv_format": kv_format or "none",
        "batch": 4, "queue_limit": 4,
        "rows": rows,
        "recompiles_measured": compiles.count,
    }


def run(quick: bool = False, mesh=None) -> BenchResult:
    art = measure(quick=quick)
    md_rows, csv_rows = [], []
    for r in art["rows"]:
        bs = r["by_status"]
        md_rows.append([
            r["scenario"], r["k"], r["policy"], r["submitted"],
            bs.get("ok", 0), bs.get("shed", 0),
            bs.get("deadline_exceeded", 0), bs.get("truncated", 0),
            f"{r['goodput_tok_s']:.1f}",
            _ms(r["ttft_p50"]), _ms(r["ttft_p99"]),
            _ms(r["tpt_p50"]), _ms(r["tpt_p99"]),
            "yes" if r["accounting_ok"] else "NO"])
        csv_rows.append(csv(
            "serve_scenarios", scenario=r["scenario"], k=r["k"],
            policy=r["policy"], scheduler=r["scheduler"],
            submitted=r["submitted"], ok=bs.get("ok", 0),
            shed=bs.get("shed", 0),
            deadline_exceeded=bs.get("deadline_exceeded", 0),
            truncated=bs.get("truncated", 0),
            goodput_tok_s=r["goodput_tok_s"],
            ttft_p50_s=r["ttft_p50"], ttft_p99_s=r["ttft_p99"],
            tpt_p50_s=r["tpt_p50"], tpt_p99_s=r["tpt_p99"],
            accounting_ok=int(r["accounting_ok"])))
    md = table(["scenario", "K", "policy", "subm", "ok", "shed",
                "dl_exc", "trunc", "goodput tok/s", "ttft p50",
                "ttft p99", "tpt p50", "tpt p99", "acct"], md_rows)
    md += ("\nSeeded arrival traces replayed through one engine with a "
           "bounded admission queue (limit 4, batch 4).  Under overload "
           "the policy decides the tail: `reject` sheds at submit and "
           "keeps TTFT flat, `shed_oldest` trades queued work for fresh "
           "arrivals, `block` backpressures the client (zero shed, "
           "longest TTFT tail).  Goodput counts only completed-`ok` "
           "tokens; the `acct` column is the exact-accounting identity "
           "submitted = ok+truncated+shed+deadline_exceeded+faulted, "
           "asserted per cell.  The whole sweep runs with zero "
           "recompiles on warmed executables (CompileCounter-gated).\n")
    res = BenchResult("serve_scenarios", "§IV.A (serving under load)",
                      md, csv_rows)
    res.artifacts = [art]
    return res


def _ms(x: Optional[float]) -> str:
    return "-" if x is None else f"{1e3 * x:.1f}ms"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_serve_scenarios.json")
    ap.add_argument("--history", default=None,
                    help="also append headline numbers to this JSONL "
                         "trajectory file (CI uses "
                         "results/BENCH_history.jsonl)")
    args = ap.parse_args()

    rep = compat.report()
    print(rep)
    res = run(quick=args.quick)
    print(res.markdown)
    for row in res.csv_rows:
        print(row)
    art = res.artifacts[0]
    payload = {
        "bench": "serve_scenarios",
        "quick": args.quick,
        "compat": dataclasses.asdict(rep),
        "runs": res.artifacts,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"bench,serve_scenarios,artifact={args.out}")
    if args.history:
        append_history({
            "bench": "serve_scenarios", "quick": args.quick,
            "compat": dataclasses.asdict(rep),
            "scenarios": [{k: r[k] for k in
                           ("scenario", "k", "policy", "submitted",
                            "by_status", "goodput_tok_s", "ttft_p50",
                            "ttft_p99", "accounting_ok")}
                          for r in art["rows"]],
        }, path=args.history)
        print(f"bench,serve_scenarios,history={args.history}")
    # the gates (zero recompiles, exact accounting) already raised
    # inside measure() if violated; surface the summary for CI logs
    n_ok = sum(r["accounting_ok"] for r in art["rows"])
    print(f"bench,serve_scenarios,cells={len(art['rows'])},"
          f"accounting_ok={n_ok},recompiles={art['recompiles_measured']}")


if __name__ == "__main__":
    main()
