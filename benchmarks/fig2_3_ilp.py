"""Paper Fig 2/3: total cycles & throughput vs dependent-chain length
(1..1024) for INT32/FP32/FP64 — the warp-scheduler/issue-model probe."""

from __future__ import annotations

from benchmarks.common import BenchResult, csv, table
from repro.core.probes import compute


def run(quick: bool = False) -> BenchResult:
    lengths = (1, 4, 16, 64, 256) if quick \
        else (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)
    iters = 5 if quick else 15
    rows, csv_rows = [], []
    curves = {}
    for workload in ("int32", "fp32", "fp64"):
        pts = compute.ilp_ramp(workload, lengths=lengths, iters=iters)
        curves[workload] = pts
        for p in pts:
            csv_rows.append(csv("fig2_3_ilp", workload=workload,
                                chain=p.chain_len,
                                total_cycles=p.total_cycles,
                                ops_per_cycle=p.ops_per_cycle))
    for i, n in enumerate(lengths):
        rows.append([n] + [f"{curves[w][i].total_cycles:.0f} / "
                           f"{curves[w][i].ops_per_cycle:.2f}"
                           for w in ("int32", "fp32", "fp64")])
    md = table(["chain len", "int32 cyc/thr", "fp32 cyc/thr",
                "fp64 cyc/thr"], rows)
    # plateau check (paper: throughput plateaus past ~64)
    fp32 = curves["fp32"]
    peak = max(p.ops_per_cycle for p in fp32)
    sat = next((p.chain_len for p in fp32
                if p.ops_per_cycle >= 0.8 * peak), lengths[-1])
    md += (f"\nThroughput reaches 80% of peak at chain length **{sat}** "
           f"(paper: ramps over 1-9 then plateaus ~64+; same shape "
           f"expected on any pipelined backend).\n")
    csv_rows.append(csv("fig2_3_ilp", workload="fp32_saturation_chain",
                        chain=sat))
    return BenchResult("fig2_3_ilp", "Figures 2 and 3", md, csv_rows)
