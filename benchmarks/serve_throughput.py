"""Measured serving throughput: fused K-token decode vs per-token
dispatch.

The paper's §IV.A/§IV.B discipline — characterize the measurement and
dispatch overhead before trusting a number — applied to our own serving
loop: the per-token leg pays one dispatch + one host sync per generated
token (what the old engine always did), the fused leg pays one per K
tokens (`ServeEngine(decode_block=K)`, the device-resident `lax.scan`
hot loop).  Both legs run the *same* jitted step body, so the measured
ratio isolates dispatch/sync amortization — on a CPU/interpret backend
this is exactly the per-launch overhead that arXiv:2402.13499 and
arXiv:2605.04178 report dominating short memory-bound decode kernels.

Timed via ``core.timing.time_fn`` (warm-up exclusion absorbs
compilation, timer overhead subtracted, medians reported).  Greedy
token streams are asserted bit-identical between the legs before any
number is reported.  Writes a ``BENCH_serve.json`` artifact when run as
a script so CI records the perf trajectory per PR:

    PYTHONPATH=src python benchmarks/serve_throughput.py --quick \
        --out BENCH_serve.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import sys
from typing import Dict, Optional

import jax

if __package__ in (None, ""):      # `python benchmarks/serve_throughput.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import BenchResult, append_history, csv, table
from repro import compat
from repro.analysis.sanitize import CompileCounter
from repro.configs import get_config
from repro.core.device_model import detect_backend_model
from repro.core.timing import time_fn
from repro.models import build_model
from repro.serve import ServeEngine


def _drive(eng: ServeEngine, n_req: int, prompt_len: int,
           new_tokens: int) -> int:
    """Reset, enqueue, serve; returns generated-token count."""
    import numpy as np

    eng.reset()
    enc_dec = eng.model.cfg.is_encoder_decoder
    d = eng.model.cfg.d_model
    for i in range(n_req):
        frames = None
        if enc_dec:
            # deterministic per-request source frames (both legs must
            # see bit-identical inputs for the greedy-identity gate)
            frames = 0.02 * np.sin(
                np.arange(6 * d, dtype=np.float32) + i).reshape(6, d)
        eng.submit([1 + (i + j) % 97 for j in range(prompt_len)],
                   max_new_tokens=new_tokens, frames=frames)
    results = eng.run(max_steps=100_000)
    return sum(len(r.tokens) for r in results)


def _bandwidth(eng: ServeEngine, batch: int, n_dev: int) -> Dict:
    """maxtext-style per-step byte accounting, per device.

    A memory-bound decode step streams the weight store once (the
    *stored* bytes: bit-packed fp4/fp6 count at 0.5/0.75 B/elem, not a
    nominal width) plus the resident KV pool (measured codes + scales
    over the live cache pytree).  Sharding divides the stream: each
    device reads only its parameter/KV shard, so bytes/step/device is
    the total over ``n_dev`` — that is the whole per-device bandwidth
    win TP buys for decode.  ``hbm_bound_tok_per_s`` is the roofline
    ceiling batch*BW/bytes on the *detected* backend's HBM (§VI.D:
    decode throughput = how fast you can stream the resident state)."""
    if eng.weight_stats is not None:
        weight_bytes = int(eng.weight_stats["quantized_bytes"])
    else:
        weight_bytes = int(sum(x.nbytes for x in
                               jax.tree.leaves(eng.params)))
    kv_bytes = int(eng.kv_stats["kv_bytes"])
    per_dev = (weight_bytes + kv_bytes) / n_dev
    dm = detect_backend_model()
    bw = dm.hbm.bandwidth_Bps
    return {
        "n_devices": n_dev,
        "weight_bytes": weight_bytes,
        "kv_bytes": kv_bytes,
        "bytes_per_step_device": per_dev,
        "gbytes_per_step_device": per_dev / 1e9,
        "backend_model": dm.name,
        "hbm_GBps": bw / 1e9,
        "hbm_bound_tok_per_s": batch * bw / per_dev,
    }


def measure(quick: bool = False, kv_format: Optional[str] = None,
            decode_block: int = 16, arch: str = "gptneox-1b",
            mesh=None) -> Dict:
    """Both legs on one model; returns the artifact dict.

    ``mesh`` is a ``jax.sharding.Mesh`` (or None): both legs run
    through the same sharded engine, so the greedy-identity gate also
    certifies the mesh run against itself per-step vs fused."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # quick mode still needs enough decode steps per drive for the
    # dispatch-overhead delta to clear run-to-run noise: the fp4 leg's
    # heavier step body shrinks the overhead *fraction*, and 16-token
    # drives were observed crossing 1.0x on a loaded host
    n_req, prompt_len, new_tokens = (4, 8, 24) if quick else (8, 8, 32)
    iters, warmup = (5, 1) if quick else (5, 2)

    n_dev = int(math.prod(mesh.devices.shape)) if mesh is not None else 1
    legs: Dict[str, Dict] = {}
    streams = {}
    bandwidth: Dict = {}
    for name, block in (("per_step", 1), ("fused", decode_block)):
        eng = ServeEngine(model, params, batch=4, max_seq=128,
                          kv_format=kv_format, decode_block=block,
                          prefill_chunk=16, mesh=mesh)
        if not bandwidth:
            bandwidth = _bandwidth(eng, batch=4, n_dev=n_dev)
        n_tok = _drive(eng, n_req, prompt_len, new_tokens)
        streams[name] = [r.tokens for r in
                         sorted(eng.results, key=lambda r: r.request_id)]
        # settle the device before the timed region, and hold the timed
        # iterations to zero recompiles: the warm-up drive above already
        # built every executable, so any compile inside time_fn means a
        # shape/dtype leak is being timed as throughput
        jax.block_until_ready((eng.cache, eng.state))
        with CompileCounter() as compiles:
            t = time_fn(_drive, eng, n_req, prompt_len, new_tokens,
                        iters=iters, warmup=warmup)
        if compiles.count:
            raise AssertionError(
                f"{name} leg recompiled {compiles.count}x inside the "
                "timed region — measurement invalid (see README "
                "'Static analysis & sanitizers')")
        legs[name] = {"decode_block": block, "tokens": n_tok,
                      "median_s": t.median_s, "mean_s": t.mean_s,
                      "std_s": t.std_s,
                      "tok_per_s": n_tok / t.median_s}

    identical = streams["per_step"] == streams["fused"]
    if not identical:
        raise AssertionError(
            "fused decode_loop diverged from per-step decode (greedy "
            "streams must be bit-identical): "
            f"{streams['per_step']} vs {streams['fused']}")
    bandwidth["achieved_frac_fused"] = (
        legs["fused"]["tok_per_s"] / bandwidth["hbm_bound_tok_per_s"])
    return {
        "arch": cfg.name,
        "kv_format": kv_format or "none",
        "mesh": ("x".join(str(s) for s in mesh.devices.shape)
                 if mesh is not None else "none"),
        "requests": n_req, "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "per_step": legs["per_step"], "fused": legs["fused"],
        "speedup": legs["fused"]["tok_per_s"]
        / legs["per_step"]["tok_per_s"],
        "greedy_identical": identical,
        "bandwidth": bandwidth,
    }


def run(quick: bool = False, mesh=None) -> BenchResult:
    # one row per arch FAMILY through the same fused loop + chunked
    # pooled prefill (attn / ssm / hybrid / enc-dec), plus the quantized
    # KV leg on the attention arch
    scenarios = [
        ("attn", "gptneox-1b", None),
        ("attn", "gptneox-1b", "float4_e2m1fn"),
        ("ssm", "mamba2-2.7b", None),
        ("hybrid", "jamba-v0.1-52b", None),
        ("enc-dec", "seamless-m4t-medium", None),
    ]
    rows, csv_rows, artifacts = [], [], []
    for family, arch, kv_format in scenarios:
        art = measure(quick=quick, kv_format=kv_format, arch=arch,
                      mesh=mesh)
        art["family"] = family
        artifacts.append(art)
        bw = art["bandwidth"]
        rows.append([family, art["arch"], art["kv_format"], art["mesh"],
                     f"{art['per_step']['tok_per_s']:.1f}",
                     f"{art['fused']['tok_per_s']:.1f}",
                     f"{art['speedup']:.2f}x",
                     f"{bw['gbytes_per_step_device']:.3f}",
                     f"{bw['hbm_bound_tok_per_s']:.0f}",
                     "yes" if art["greedy_identical"] else "NO"])
        csv_rows.append(csv(
            "serve_throughput", family=family, arch=art["arch"],
            kv_format=art["kv_format"], mesh=art["mesh"],
            tok_per_s_per_step=art["per_step"]["tok_per_s"],
            tok_per_s_fused=art["fused"]["tok_per_s"],
            decode_block=art["fused"]["decode_block"],
            speedup=art["speedup"],
            n_devices=bw["n_devices"],
            gbytes_per_step_device=bw["gbytes_per_step_device"],
            hbm_bound_tok_per_s=bw["hbm_bound_tok_per_s"],
            greedy_identical=int(art["greedy_identical"])))
    md = table(["family", "arch", "kv_format", "mesh",
                "tok/s per-step", "tok/s fused (K=16)", "speedup",
                "GB/step/dev", "HBM-bound tok/s", "greedy identical"],
               rows)
    md += ("\nOne dispatch per K tokens instead of per token: the gap is "
           "pure dispatch/sync overhead, since both legs run the same "
           "jitted step body (the §IV.A overhead story applied to our "
           "own hot loop).  On this backend the per-step leg measures "
           "the Python interpreter + launch path, the fused leg the "
           "machine.  GB/step/dev is the memory-bound decode read per "
           "device (stored weights + measured KV pool, over the mesh "
           "size); the HBM-bound column is the §VI.D roofline ceiling "
           "batch*BW/bytes for the detected backend.\n")
    res = BenchResult("serve_throughput", "§IV.A/§VI.D (serving)", md,
                      csv_rows)
    res.artifacts = artifacts          # for the __main__ JSON writer
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--mesh", default=None,
                    help="serving mesh, e.g. 2x2 or 4; needs that many "
                         "devices (CPU: set XLA_FLAGS="
                         "--xla_force_host_platform_device_count first)")
    ap.add_argument("--history", default=None,
                    help="also append headline numbers to this JSONL "
                         "trajectory file (see benchmarks/run.py, which "
                         "appends to results/BENCH_history.jsonl)")
    args = ap.parse_args()

    from repro.launch.mesh import make_serving_mesh

    mesh = make_serving_mesh(args.mesh)
    rep = compat.report()
    print(rep)
    res = run(quick=args.quick, mesh=mesh)
    print(res.markdown)
    for row in res.csv_rows:
        print(row)
    payload = {
        "bench": "serve_throughput",
        "quick": args.quick,
        "mesh": args.mesh or "none",
        "compat": dataclasses.asdict(rep),
        "runs": res.artifacts,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"bench,serve_throughput,artifact={args.out}")
    if args.history:
        append_history({
            "bench": "serve_throughput", "quick": args.quick,
            "mesh": args.mesh or "none",
            "compat": dataclasses.asdict(rep),
            "serve": [{k: a[k] for k in
                       ("family", "arch", "kv_format", "mesh",
                        "speedup", "bandwidth")}
                      | {"tok_per_s_fused": a["fused"]["tok_per_s"]}
                      for a in res.artifacts],
        }, path=args.history)
        print(f"bench,serve_throughput,history={args.history}")
    # regression gate: fused must beat per-step.  The quick leg runs few
    # short iterations on shared CI hosts, so it gets a noise margin;
    # the full leg is held to a strict >1x.
    floor = 0.9 if args.quick else 1.0
    slow = [a for a in payload["runs"] if a["speedup"] <= floor]
    if slow:
        raise SystemExit(
            f"fused loop failed to beat per-step dispatch "
            f"(gate {floor}x): {slow}")


if __name__ == "__main__":
    main()
