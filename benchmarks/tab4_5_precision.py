"""Paper Tables IV/V: the datatype support matrix + which pipeline each
format actually lowers to — our compiled-HLO inspection is the SASS
(QMMA/OMMA/HMMA) analogue."""

from __future__ import annotations

from benchmarks.common import BenchResult, csv, table
from repro import compat
from repro.core.probes import precision

# Paper Tab IV/V ground truth for the two GPUs
PAPER_PIPELINE = {
    "e2m1": "GB203: QMMA (OMMA only w/ ue8m0 scales); GH100: unsupported",
    "e2m3": "GB203: QMMA; GH100: unsupported",
    "e3m2": "GB203: QMMA; GH100: unsupported",
    "e4m3": "GB203: QMMA; GH100: HMMA",
    "e5m2": "GB203: QMMA; GH100: HMMA",
    "e8m0": "scale-exponent only (not an mma input)",
}


def run(quick: bool = False) -> BenchResult:
    sup = precision.support_matrix()
    rows, csv_rows = [], []
    for s in sup:
        packed_bpe = compat.storage_bytes_per_element(s.compat_name,
                                                      packed=True)
        container_bpe = compat.storage_bytes_per_element(s.compat_name,
                                                         packed=False)
        rows.append([s.fmt, s.bits, s.max_finite,
                     "yes" if s.representable else "no",
                     f"{packed_bpe:g} / {container_bpe:g}",
                     s.pipeline, PAPER_PIPELINE.get(s.fmt, "-")])
        csv_rows.append(csv("tab4_5_precision", fmt=s.fmt, bits=s.bits,
                            representable=int(s.representable),
                            native_dot=int(s.native_dot),
                            via_convert=int(s.lowers_via_convert),
                            packed_bytes_per_elem=packed_bpe,
                            container_bytes_per_elem=container_bpe))
    md = table(["format", "bits", "max", "representable",
                "storage B/elem (packed / container)",
                "this backend lowers via", "paper (SASS)"], rows)
    md += ("\nEvery sub-bf16 format rides the wide pipeline after a "
           "convert — the same fallback the paper catches for FP4 "
           "(QMMA instead of OMMA). e8m0 is used only as the block-scale "
           "exponent, as in Tab V.  Storage B/elem is the *bit-packed* "
           "weight layout (repro.lowbits: fp4 2/byte, fp6 4 per 3 bytes "
           "— Tab V tile packing) vs the byte-aligned compute container.\n")
    # cast-error staircase (Tab V numerics)
    err_rows = []
    for fmt in ("e4m3", "e5m2", "e2m3", "e3m2", "e2m1"):
        e = precision.cast_error(fmt)
        err_rows.append([fmt, e.rel_err_mean, e.rel_err_max])
        csv_rows.append(csv("tab4_5_precision_err", fmt=fmt,
                            rms_rel=e.rel_err_mean, max_rel=e.rel_err_max))
    md += "\n**Cast error (rel)**\n\n" + table(
        ["format", "rms", "max"], err_rows)
    return BenchResult("tab4_5_precision", "Tables IV and V", md, csv_rows)
